//! End-to-end driver: REAL training through the full three-layer stack.
//!
//! Loads the AOT-compiled GCN artifact (jax/Pallas → HLO text → PJRT),
//! trains on a synthetic community graph for several epochs with the
//! HopGNN iteration semantics (global batches + gradient accumulation),
//! and logs the loss curve + validation accuracy. This is the run
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example train_e2e

use hopgnn::graph::datasets::{load_spec, DatasetSpec};
use hopgnn::partition::{partition, PartitionAlgo};
use hopgnn::runtime::{Engine, Manifest};
use hopgnn::sampler::{SampleConfig, SamplerKind};
use hopgnn::train::{OrderPolicy, Trainer};
use hopgnn::util::table::fmt_secs;

fn main() -> hopgnn::util::error::Result<()> {
    let manifest = Manifest::load_default()
        .map_err(hopgnn::util::error::Error::msg)?;
    let spec = manifest
        .find("gcn", 128, 128)
        .ok_or_else(|| hopgnn::err!("gcn artifact missing — run `make artifacts`"))?;

    // a 12k-vertex community graph (128-d features, 10 classes), the
    // largest that trains in a couple of minutes on the CPU PJRT backend
    let d = load_spec(&DatasetSpec {
        name: "e2e",
        num_vertices: 12_000,
        num_edges: 84_000,
        feat_dim: 128,
        classes: 10,
        num_communities: 100,
        train_fraction: 0.35,
        seed: 2024,
    });
    let part = partition(&d.graph, 4, PartitionAlgo::MetisLike, 3);
    println!(
        "dataset: {} vertices, {} edges; artifact: {} ({} params); platform: CPU PJRT",
        d.graph.num_vertices(),
        d.graph.num_edges(),
        spec.name,
        spec.param_count
    );

    let engine = Engine::load(spec)?;
    let sample_cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut trainer = Trainer::new(engine, sample_cfg, 3e-3, 7);

    println!("\nepoch |   loss  | train acc | val acc | wall");
    println!("------+---------+-----------+---------+---------");
    let epochs = std::env::var("E2E_EPOCHS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6usize);
    for e in 0..epochs {
        let t0 = std::time::Instant::now();
        let stats =
            trainer.train_epoch(&d, Some(&part), OrderPolicy::Global, 64)?;
        let val = trainer.evaluate(&d, &d.val_vertices)?;
        println!(
            "{e:>5} | {:>7.4} | {:>8.1}% | {:>6.1}% | {}",
            stats.mean_loss,
            stats.train_accuracy * 100.0,
            val * 100.0,
            fmt_secs(t0.elapsed().as_secs_f64())
        );
    }
    let final_val = trainer.evaluate(&d, &d.val_vertices)?;
    println!("\nfinal validation accuracy: {:.2}%", final_val * 100.0);
    hopgnn::ensure!(final_val > 0.5, "training failed to beat 50%");
    println!("e2e OK: all three layers compose (Pallas kernels -> jax fwd/bwd -> HLO -> PJRT -> rust trainer)");
    Ok(())
}
