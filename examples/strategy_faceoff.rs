//! Every coordination strategy on one workload — the paper's Fig 11/13
//! cast on a single stage, including the ablation variants and the
//! accuracy-compromising LO baseline.
//!
//!     cargo run --release --example strategy_faceoff [dataset] [model]

use hopgnn::cluster::{ModelFamily, TransferKind};
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::graph::datasets::load;
use hopgnn::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let ds = args.first().map(|s| s.as_str()).unwrap_or("products-s");
    let model = args
        .get(1)
        .and_then(|s| ModelFamily::from_str(s))
        .unwrap_or(ModelFamily::Gcn);
    let d = load(ds);
    let cfg = RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        fanout: if model.default_layers() > 3 { 2 } else { 10 },
        vmax: RunConfig::full_sim_vmax(
            model.default_layers(),
            if model.default_layers() > 3 { 2 } else { 10 },
        ),
        batch_size: 1024,
        epochs: 5,
        max_iterations: Some(6),
        ..Default::default()
    };
    println!(
        "{} / {} on 4 simulated servers (10 GbE), batch {}:\n",
        ds,
        model.name(),
        cfg.batch_size
    );
    let mut t = Table::new([
        "strategy", "epoch", "vs DGL", "feat moved", "total moved",
        "miss%", "steps/iter",
    ]);
    let mut dgl_time = None;
    for kind in [
        StrategySpec::dgl(),
        StrategySpec::p3(),
        StrategySpec::naive(),
        StrategySpec::hopgnn_mg(),
        StrategySpec::hopgnn_mg_pg(),
        StrategySpec::hopgnn(),
        StrategySpec::locality_opt(),
    ] {
        let m = run_strategy(&d, &cfg, kind);
        let base = *dgl_time.get_or_insert(m.epoch_time);
        t.row([
            kind.name().to_string(),
            fmt_secs(m.epoch_time),
            format!("{:.2}x", base / m.epoch_time),
            fmt_bytes(m.bytes(TransferKind::Feature)),
            fmt_bytes(m.total_bytes()),
            format!("{:.1}", m.miss_rate() * 100.0),
            format!("{:.1}", m.time_steps_per_iter),
        ]);
    }
    println!("{}", t.render());
    println!(
        "LO is fastest but biases the training sequence (Table 3 accuracy\n\
         drop); HopGNN gets most of LO's locality without the bias."
    );
}
