//! Explore the paper's core phenomenon (§4, Table 1): micrograph locality
//! under different partitioners, samplers, server counts and depths.
//!
//!     cargo run --release --example locality_explorer [dataset]

use hopgnn::graph::datasets::load;
use hopgnn::partition::{partition, PartitionAlgo};
use hopgnn::sampler::{sample_micrograph, SampleConfig, SamplerKind, Subgraph};
use hopgnn::util::rng::Rng;
use hopgnn::util::table::Table;

fn main() {
    let ds = std::env::args().nth(1).unwrap_or_else(|| "arxiv-s".into());
    let d = load(&ds);
    println!(
        "{}: {} vertices, {} edges\n",
        d.name,
        d.graph.num_vertices(),
        d.graph.num_edges()
    );

    let mut t = Table::new([
        "partitioner", "sampler", "#S", "layers", "R_micro%", "R_sub%",
        "ratio",
    ]);
    for algo in [
        PartitionAlgo::MetisLike,
        PartitionAlgo::Heuristic,
        PartitionAlgo::Hash,
    ] {
        for &servers in &[2usize, 4, 8] {
            let p = partition(&d.graph, servers, algo, 7);
            for kind in [SamplerKind::NodeWise, SamplerKind::LayerWise] {
                for &layers in &[2usize, 10] {
                    let cfg = SampleConfig {
                        layers,
                        fanout: if layers > 2 { 2 } else { 10 },
                        vmax: 2048,
                        kind,
                    };
                    let mut rng = Rng::new(1);
                    let mut mgs = Vec::new();
                    for _ in 0..64 {
                        let root = d.train_vertices
                            [rng.below(d.train_vertices.len())];
                        mgs.push(sample_micrograph(&d.graph, root, &cfg,
                                                   &mut rng));
                    }
                    let rm = mgs.iter().map(|m| m.locality(&p)).sum::<f64>()
                        / mgs.len() as f64;
                    let rs = Subgraph::union_of(&mgs).locality(&p);
                    t.row([
                        algo.name().to_string(),
                        format!("{kind:?}"),
                        servers.to_string(),
                        layers.to_string(),
                        format!("{:.0}", rm * 100.0),
                        format!("{:.0}", rs * 100.0),
                        format!("{:.1}x", rm / rs.max(1e-9)),
                    ]);
                }
            }
        }
    }
    println!("{}", t.render());
    println!(
        "Locality-preserving partitioners (metis/heuristic) give micrographs\n\
         far better locality than subgraphs; random hash partitioning (P3's\n\
         scheme) destroys the effect — exactly the paper's Table 1."
    );
}
