//! Quickstart: simulate DGL vs HopGNN on a small dataset and print the
//! comparison — the 30-second tour of the system.
//!
//!     cargo run --release --example quickstart

use hopgnn::cluster::TransferKind;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::graph::datasets::load;
use hopgnn::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    // arxiv-s: 60k-vertex community-structured stand-in for OGB-Arxiv
    let dataset = load("arxiv-s");
    println!(
        "loaded {}: {} vertices, {} edges, {}-d features ({} total)",
        dataset.name,
        dataset.graph.num_vertices(),
        dataset.graph.num_edges(),
        dataset.feat_dim,
        fmt_bytes(dataset.feature_volume_bytes()),
    );

    let cfg = RunConfig {
        dataset: "arxiv-s".into(),
        batch_size: 1024,
        num_servers: 4,
        epochs: 4,
        max_iterations: Some(6),
        vmax: RunConfig::full_sim_vmax(3, 10),
        ..Default::default()
    };

    let mut table = Table::new([
        "system", "epoch time", "feature bytes", "miss rate", "GPU busy",
    ]);
    for kind in [
        StrategySpec::dgl(),
        StrategySpec::p3(),
        StrategySpec::naive(),
        StrategySpec::hopgnn(),
    ] {
        let m = run_strategy(&dataset, &cfg, kind);
        table.row([
            kind.name().to_string(),
            fmt_secs(m.epoch_time),
            fmt_bytes(m.bytes(TransferKind::Feature)),
            format!("{:.1}%", m.miss_rate() * 100.0),
            format!("{:.0}%", m.gpu_busy_fraction * 100.0),
        ]);
    }
    println!("\nGCN(128), 4 simulated servers, 10 GbE model:\n");
    println!("{}", table.render());
    println!(
        "HopGNN reverses the model-centric paradigm: models migrate to the\n\
         servers that home the features (micrographs, §5.1), remote fetches\n\
         are pre-gathered once per iteration (§5.2), and time steps merge\n\
         adaptively (§5.3)."
    );
}
