//! Tier-stack parity and placement-policy locks.
//!
//! The multi-tier feature store (`featstore::tier`) generalizes the
//! single [`FeatureCache`]; these tests pin the generalization down at
//! the strategy level:
//!
//! * **legacy alias parity** — a `--cache <policy> --cache-mb <n>`
//!   config and its `--tiers dram:<n>m:<policy>+remote` spelling
//!   produce bit-identical epochs: *every* [`EpochMetrics`] field,
//!   serial and overlap, for every gather-emitting strategy;
//! * **remote-only parity** — the cache-less `remote` stack reproduces
//!   the capacity-0 legacy cache to the bit (non-serving tiers are
//!   skipped, not probed);
//! * **placement properties** — hit rate is monotone in a single
//!   tier's capacity (LRU stack inclusion) and in a static
//!   degree-pinned hierarchy's total capacity (pinned-slice unions
//!   grow); LRU promotion respects the fast tier's capacity
//!   (promoted-in minus demoted-out never exceeds it); per-tier hit
//!   slots partition the legacy aggregate counters.

use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::featstore::cache::CachePolicy;
use hopgnn::featstore::tier::{TierKind, TierSpec};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use std::sync::OnceLock;

fn dataset() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "tier-parity",
            num_vertices: 8_000,
            num_edges: 56_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 40,
            train_fraction: 0.4,
            seed: 1717,
        })
    })
}

fn base_cfg(overlap: bool) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        epochs: 2,
        max_iterations: Some(3),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        overlap,
        ..Default::default()
    }
}

fn legacy_cfg(overlap: bool, policy: CachePolicy, mb: usize) -> RunConfig {
    RunConfig {
        cache_policy: policy,
        cache_mb: mb,
        ..base_cfg(overlap)
    }
}

fn tiers_cfg(overlap: bool, spec: &str) -> RunConfig {
    RunConfig {
        tiers: Some(TierSpec::parse(spec).expect("test tier spec parses")),
        ..base_cfg(overlap)
    }
}

/// Strategies whose builders emit feature gathers (the tier-routed
/// ops); includes the adaptive full system — bit-identical epoch times
/// force its merge trajectory to be identical too.
const CACHED_KINDS: [StrategySpec; 5] = [
    StrategySpec::dgl(),
    StrategySpec::locality_opt(),
    StrategySpec::hopgnn_mg(),
    StrategySpec::hopgnn_mg_pg(),
    StrategySpec::hopgnn(),
];

macro_rules! eq_bits {
    ($a:expr, $b:expr, $what:expr, $field:ident) => {
        assert_eq!(
            $a.$field.to_bits(),
            $b.$field.to_bits(),
            "{}: {} diverged ({} vs {})",
            $what,
            stringify!($field),
            $a.$field,
            $b.$field
        );
    };
}

macro_rules! eq_exact {
    ($a:expr, $b:expr, $what:expr, $field:ident) => {
        assert_eq!(
            $a.$field, $b.$field,
            "{}: {} diverged",
            $what,
            stringify!($field)
        );
    };
}

/// Every [`EpochMetrics`] field, floats compared by bit pattern.
fn assert_every_field_identical(
    a: &EpochMetrics,
    b: &EpochMetrics,
    what: &str,
) {
    eq_bits!(a, b, what, epoch_time);
    eq_bits!(a, b, what, time_sample);
    eq_bits!(a, b, what, time_gather);
    eq_bits!(a, b, what, time_compute);
    eq_bits!(a, b, what, time_migrate);
    eq_bits!(a, b, what, time_sync);
    eq_bits!(a, b, what, time_overlap_hidden);
    eq_bits!(a, b, what, gpu_busy_fraction);
    eq_bits!(a, b, what, time_steps_per_iter);
    eq_exact!(a, b, what, bytes_by_kind);
    eq_exact!(a, b, what, remote_requests);
    eq_exact!(a, b, what, remote_vertices);
    eq_exact!(a, b, what, local_hits);
    eq_exact!(a, b, what, cache_hits);
    eq_exact!(a, b, what, cache_misses);
    eq_exact!(a, b, what, cache_hit_bytes);
    eq_exact!(a, b, what, cache_miss_bytes);
    eq_exact!(a, b, what, cache_evict_bytes);
    eq_exact!(a, b, what, tier_hits);
    eq_exact!(a, b, what, tier_hit_bytes);
    eq_exact!(a, b, what, tier_miss_bytes);
    eq_exact!(a, b, what, tier_promote_bytes);
    eq_exact!(a, b, what, tier_demote_bytes);
    eq_exact!(a, b, what, iterations);
    eq_exact!(a, b, what, dropped_roots);
    assert_eq!(
        a.per_server_busy.len(),
        b.per_server_busy.len(),
        "{what}: per_server_busy length"
    );
    for (i, (x, y)) in
        a.per_server_busy.iter().zip(&b.per_server_busy).enumerate()
    {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: per_server_busy[{i}] diverged"
        );
    }
}

#[test]
fn legacy_cache_knobs_are_bit_identical_to_their_tier_spec() {
    // the acceptance lock: `--cache lru --cache-mb 16` IS
    // `--tiers dram:16m:lru+remote`, in every field, in both lanes
    let d = dataset();
    for overlap in [false, true] {
        for kind in CACHED_KINDS {
            let legacy = run_strategy(
                d,
                &legacy_cfg(overlap, CachePolicy::Lru, 16),
                kind,
            );
            let tiered =
                run_strategy(d, &tiers_cfg(overlap, "dram:16m:lru+remote"), kind);
            assert_every_field_identical(
                &legacy,
                &tiered,
                &format!("{} overlap={overlap}", kind.name()),
            );
            assert!(legacy.cache_hits > 0, "{}: no reuse", kind.name());
        }
    }
}

#[test]
fn every_policy_aliases_its_tier_spelling() {
    let d = dataset();
    for (policy, spec) in [
        (CachePolicy::Lru, "dram:4m:lru+remote"),
        (CachePolicy::Degree, "dram:4m:degree+remote"),
        (CachePolicy::Precomputed, "dram:4m:schedule+remote"),
    ] {
        let legacy =
            run_strategy(d, &legacy_cfg(false, policy, 4), StrategySpec::dgl());
        let tiered =
            run_strategy(d, &tiers_cfg(false, spec), StrategySpec::dgl());
        assert_every_field_identical(&legacy, &tiered, policy.name());
    }
}

#[test]
fn remote_only_stack_matches_capacity_zero_to_the_bit() {
    // non-serving tiers are skipped, not probed: an explicit `remote`
    // stack, a capacity-0 LRU, and a capacity-0 tier segment are all
    // the same machine
    let d = dataset();
    for overlap in [false, true] {
        for kind in [StrategySpec::dgl(), StrategySpec::hopgnn()] {
            let zero = run_strategy(
                d,
                &legacy_cfg(overlap, CachePolicy::Lru, 0),
                kind,
            );
            let remote =
                run_strategy(d, &tiers_cfg(overlap, "remote"), kind);
            let zero_seg =
                run_strategy(d, &tiers_cfg(overlap, "dram:0:lru+remote"), kind);
            let what = format!("{} overlap={overlap}", kind.name());
            assert_every_field_identical(&zero, &remote, &what);
            assert_every_field_identical(&zero, &zero_seg, &what);
            assert_eq!(remote.cache_hits, 0, "{what}");
            assert_eq!(
                remote.tier_hits[TierKind::Remote.index()],
                remote.cache_misses,
                "{what}: backstop fetches must fill the remote slot"
            );
        }
    }
}

#[test]
fn hit_rate_monotone_in_single_tier_capacity() {
    // LRU stack inclusion: a bigger tier serves a superset of requests
    let d = dataset();
    let mut prev = -1.0f64;
    for mb in [1usize, 2, 8, 32] {
        let m = run_strategy(
            d,
            &tiers_cfg(false, &format!("dram:{mb}m:lru+remote")),
            StrategySpec::dgl(),
        );
        let rate = m.cache_hit_rate();
        assert!(
            rate + 1e-12 >= prev,
            "hit rate fell from {prev} to {rate} at {mb} MiB"
        );
        prev = rate;
    }
    assert!(prev > 0.0, "largest capacity never hit");
}

#[test]
fn degree_hierarchy_hit_rate_monotone_in_capacity() {
    // static degree tiers pin disjoint slices of one global ranking, so
    // the union pinned by a (c, 4c) hierarchy grows with c
    let d = dataset();
    let mut prev = -1.0f64;
    for (h, dr) in [(1usize, 2usize), (2, 4), (4, 8)] {
        let spec = format!("hbm:{h}m:degree+dram:{dr}m:degree+remote");
        let m = run_strategy(
            d,
            &tiers_cfg(false, &spec),
            StrategySpec::dgl(),
        );
        let rate = m.cache_hit_rate();
        assert!(
            rate + 1e-12 >= prev,
            "{spec}: hit rate fell from {prev} to {rate}"
        );
        prev = rate;
    }
    assert!(prev > 0.0, "largest hierarchy never hit");
}

#[test]
fn promotion_respects_the_fast_tier_capacity() {
    // occupancy bound: bytes entering hbm (promotions + admissions)
    // minus bytes displaced down into dram can never exceed the hbm
    // capacity — so promoted-in is bounded by demoted-out + capacity
    let d = dataset();
    let hbm_bytes: u64 = 1 << 20;
    // one epoch: the reported metrics are exact, not epoch-averaged
    let cfg = RunConfig {
        epochs: 1,
        ..tiers_cfg(false, "hbm:1m:lru+dram:8m:lru+remote")
    };
    let m = run_strategy(d, &cfg, StrategySpec::dgl());
    let hi = TierKind::Hbm.index();
    let di = TierKind::Dram.index();
    assert!(m.tier_hits[di] > 0, "no lower-tier hits to promote");
    assert!(m.tier_promote_bytes[hi] > 0, "no promotions happened");
    assert!(
        m.tier_promote_bytes[hi] <= m.tier_demote_bytes[di] + hbm_bytes,
        "promotion overfilled hbm: {} promoted in, {} demoted out, {} cap",
        m.tier_promote_bytes[hi],
        m.tier_demote_bytes[di],
        hbm_bytes
    );
}

#[test]
fn tier_slots_partition_the_aggregate_counters() {
    let d = dataset();
    let fb = 64 * 4; // feat_dim 64 × f32
    for spec in [
        "dram:8m:lru+remote",
        "hbm:2m:lru+dram:8m:lru+remote",
        "hbm:2m:degree+dram:8m:degree+remote",
        "dram:2m:lru+ssd:8m:lru+remote",
    ] {
        // one epoch: epoch-averaging floors every counter separately,
        // which would break the exact multiplicative relations below
        let cfg = RunConfig {
            epochs: 1,
            ..tiers_cfg(false, spec)
        };
        let m = run_strategy(d, &cfg, StrategySpec::dgl());
        let ri = TierKind::Remote.index();
        let cache_tier_hits: u64 = m.tier_hits[..ri].iter().sum();
        assert_eq!(cache_tier_hits, m.cache_hits, "{spec}");
        assert_eq!(m.tier_hits[ri], m.cache_misses, "{spec}");
        let hit_bytes: u64 = m.tier_hit_bytes.iter().sum();
        assert_eq!(
            hit_bytes,
            m.cache_hit_bytes + m.cache_miss_bytes,
            "{spec}: tier hit bytes must partition the request volume"
        );
        for k in 0..m.tier_hits.len() {
            assert_eq!(
                m.tier_hit_bytes[k],
                m.tier_hits[k] * fb,
                "{spec}: tier {k} bytes != rows × feat_bytes"
            );
        }
    }
}
