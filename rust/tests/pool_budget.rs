//! Thread-budget lock: total live worker threads under
//! `bench sweep --jobs N` never exceed the budget, regardless of lane
//! parallelism.
//!
//! The sweep engine splits one `--jobs` budget deterministically:
//! `runners = budget.min(cells).max(1)` cell runners, each granting
//! its per-cell `EpochDriver`s a lane allowance of `budget / runners`.
//! Callers participate everywhere (the sweep caller is cell runner #0,
//! a lane pool's dispatcher claims lanes too), so *spawned* threads —
//! what `util::pool`'s worker accounting counts — must stay at or
//! under `budget - 1`.
//!
//! This suite lives in its own integration-test file on purpose: the
//! live/peak worker counters are process-global, so it must not share
//! a test binary (= a process) with suites that spawn workers
//! concurrently, and the `HOPGNN_PARALLEL_THRESHOLD` override below
//! must be set before the engine first reads it.

use hopgnn::bench::sweep::{Axis, SweepSpec};
use hopgnn::config::RunConfig;
use hopgnn::coordinator::StrategySpec;
use hopgnn::util::pool;

#[test]
fn sweep_thread_count_never_exceeds_the_jobs_budget() {
    // force every multi-lane fragment onto the parallel path so the
    // lane pools are guaranteed to engage (the default work threshold
    // could otherwise route tiny test fragments serially and leave
    // the nested path unexercised)
    std::env::set_var("HOPGNN_PARALLEL_THRESHOLD", "0");
    pool::reset_peak_workers();

    // 2 cells under a budget of 6: runners = 2, lane share = 3 each,
    // so each cell runner sizes a lane pool of min(4 servers, 3) = 3
    // claim threads = 2 spawned workers. Worst-case spawned threads:
    // 1 extra cell runner + 2 x 2 lane workers = 5 = budget - 1.
    let budget = 6;
    let strategies = [StrategySpec::dgl(), StrategySpec::hopgnn()];
    let grid = SweepSpec::new(
        RunConfig {
            dataset: "arxiv-s".into(),
            batch_size: 256,
            epochs: 2,
            max_iterations: Some(2),
            fanout: 5,
            vmax: RunConfig::full_sim_vmax(3, 5),
            seed: 77,
            parallel_lanes: true,
            ..Default::default()
        },
        StrategySpec::dgl(),
    )
    .axis(Axis::strategies(&strategies))
    .jobs(budget)
    .run()
    .expect("budgeted sweep");
    assert_eq!(grid.cells.len(), 2, "grid shape");

    let peak = pool::peak_workers();
    assert!(
        peak <= budget - 1,
        "spawned threads exceeded the --jobs budget: peak {peak} \
         workers + 1 caller > {budget}"
    );
    assert!(
        peak >= 3,
        "lane pools never engaged under the budget split (peak {peak} \
         spawned workers; expected at least 1 cell runner + 2 lane \
         workers) — did the parallel threshold override get read too \
         late?"
    );
    assert_eq!(
        pool::live_workers(),
        0,
        "worker threads leaked past the sweep (pools must join on drop)"
    );
}
