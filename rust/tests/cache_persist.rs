//! Cross-epoch cache persistence locks (`--cache-persist`).
//!
//! With the flag off, every epoch's driver session builds cold caches —
//! the exact behavior of the cache-subsystem PR, locked bit-identically
//! here. With the flag on, strategies hand their warm caches to the
//! next epoch's session: later epochs hit rows fetched in earlier ones,
//! byte conservation still holds per epoch, and runs stay
//! deterministic.

use hopgnn::cluster::network::NUM_KINDS;
use hopgnn::cluster::TransferKind;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{SimEnv, Strategy, StrategySpec};
use hopgnn::featstore::cache::CachePolicy;
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use std::sync::OnceLock;

fn dataset() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "cache-persist",
            num_vertices: 8_000,
            num_edges: 56_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 40,
            train_fraction: 0.4,
            seed: 3131,
        })
    })
}

fn cfg(persist: bool) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        epochs: 3,
        max_iterations: Some(3),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        cache_policy: CachePolicy::Lru,
        cache_mb: 64,
        cache_persist: persist,
        ..Default::default()
    }
}

/// Per-epoch metrics for `kind` under the given persistence setting.
fn epochs_of(kind: StrategySpec, persist: bool) -> Vec<EpochMetrics> {
    let d = dataset();
    let mut env = SimEnv::new(d, cfg(persist));
    let mut strat = kind.build();
    strat.run(&mut env, 3)
}

/// Cached fixed-schedule strategies (capacity-invariant request
/// streams, so per-epoch requested bytes are comparable).
const KINDS: [StrategySpec; 3] = [
    StrategySpec::dgl(),
    StrategySpec::locality_opt(),
    StrategySpec::hopgnn_mg_pg(),
];

#[test]
fn persistence_off_is_bit_identical_to_per_epoch_caches() {
    // the flag default must change nothing: same strategy object, same
    // epochs, every counter and every second identical
    for kind in KINDS {
        let base = epochs_of(kind, false);
        let off = epochs_of(kind, false);
        for (a, b) in base.iter().zip(&off) {
            assert_eq!(a.epoch_time.to_bits(), b.epoch_time.to_bits());
            assert_eq!(a.cache_hits, b.cache_hits);
        }
    }
}

#[test]
fn warm_epochs_hit_more_and_move_less() {
    for kind in KINDS {
        let cold = epochs_of(kind, false);
        let warm = epochs_of(kind, true);
        // epoch 0 is identical: there is no earlier cache to inherit
        assert_eq!(
            cold[0].epoch_time.to_bits(),
            warm[0].epoch_time.to_bits(),
            "{}: first epoch must not change",
            kind.name()
        );
        assert_eq!(cold[0].cache_hits, warm[0].cache_hits);
        // epochs 1+ reuse residency from the previous epochs
        for e in 1..3 {
            assert!(
                warm[e].cache_hits >= cold[e].cache_hits,
                "{} epoch {e}: warm hits {} < cold hits {}",
                kind.name(),
                warm[e].cache_hits,
                cold[e].cache_hits
            );
        }
        let warm_feat: u64 =
            warm.iter().map(|m| m.bytes(TransferKind::Feature)).sum();
        let cold_feat: u64 =
            cold.iter().map(|m| m.bytes(TransferKind::Feature)).sum();
        assert!(
            warm_feat < cold_feat,
            "{}: persistence must cut feature bytes ({warm_feat} !< \
             {cold_feat})",
            kind.name()
        );
    }
}

#[test]
fn byte_conservation_holds_per_epoch_with_persistence() {
    // requested = hit + miss per epoch, even when the hits come from a
    // previous epoch's fills
    for kind in KINDS {
        let cold = epochs_of(kind, false);
        let warm = epochs_of(kind, true);
        for e in 0..3 {
            assert_eq!(
                warm[e].cache_hit_bytes + warm[e].cache_miss_bytes,
                cold[e].cache_hit_bytes + cold[e].cache_miss_bytes,
                "{} epoch {e}: requested bytes must be persistence-\
                 invariant",
                kind.name()
            );
            assert_eq!(
                warm[e].cache_miss_bytes,
                warm[e].bytes(TransferKind::Feature),
                "{} epoch {e}: misses are exactly the bytes moved",
                kind.name()
            );
        }
    }
}

#[test]
fn persistent_runs_replay_deterministically() {
    for kind in KINDS {
        let a = epochs_of(kind, true);
        let b = epochs_of(kind, true);
        for (x, y) in a.iter().zip(&b) {
            for k in 0..NUM_KINDS {
                assert_eq!(x.bytes_by_kind[k], y.bytes_by_kind[k]);
            }
            assert_eq!(x.epoch_time.to_bits(), y.epoch_time.to_bits());
            assert_eq!(x.cache_hits, y.cache_hits);
            assert_eq!(x.cache_evict_bytes, y.cache_evict_bytes);
        }
    }
}
