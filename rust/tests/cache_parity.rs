//! Feature-cache parity and accounting locks.
//!
//! The cache tier (`featstore::cache`) sits in front of every
//! strategy's gather resolution, so it must be *provably* inert when it
//! holds nothing and *exactly* byte-conserving when it does not. These
//! tests pin that contract at the strategy level, on top of the
//! op-level locks in `coordinator::engine`:
//!
//! * **capacity-0 parity** — with any policy configured but 0 MiB of
//!   capacity, the `CacheFetch` path reproduces the PR 1 uncached
//!   driver bit-identically (epoch time, busy fraction, every byte
//!   counter), in both serial and overlap modes;
//! * **byte conservation** — `cache_hit_bytes` is exactly (total
//!   requested − transferred): what a warm cache saves is accounted,
//!   never invented;
//! * **determinism** — hit/evict trajectories replay bit-identically
//!   across repeat runs and across parallel vs sequential lanes, for
//!   every eviction policy.

use hopgnn::cluster::network::NUM_KINDS;
use hopgnn::cluster::TransferKind;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::featstore::cache::{ALL_CACHE_POLICIES, CachePolicy};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use std::sync::OnceLock;

fn dataset() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "cache-parity",
            num_vertices: 8_000,
            num_edges: 56_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 40,
            train_fraction: 0.4,
            seed: 1717,
        })
    })
}

fn cfg(overlap: bool, policy: CachePolicy, mb: usize) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        epochs: 2,
        max_iterations: Some(3),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        overlap,
        cache_policy: policy,
        cache_mb: mb,
        ..Default::default()
    }
}

/// Every strategy whose builder emits feature gathers (the cache-routed
/// ops); includes the adaptive full system — at capacity 0 its epoch
/// times are bit-identical, so its merge trajectory must be too.
const CACHED_KINDS: [StrategySpec; 5] = [
    StrategySpec::dgl(),
    StrategySpec::locality_opt(),
    StrategySpec::hopgnn_mg(),
    StrategySpec::hopgnn_mg_pg(),
    StrategySpec::hopgnn(),
];

fn assert_bit_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    for k in 0..NUM_KINDS {
        assert_eq!(
            a.bytes_by_kind[k], b.bytes_by_kind[k],
            "{what}: byte totals diverged for kind index {k}"
        );
    }
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
    assert_eq!(
        a.epoch_time.to_bits(),
        b.epoch_time.to_bits(),
        "{what}: epoch time must be bit-identical ({} vs {})",
        a.epoch_time,
        b.epoch_time
    );
    assert_eq!(
        a.gpu_busy_fraction.to_bits(),
        b.gpu_busy_fraction.to_bits(),
        "{what}: busy fraction diverged"
    );
    assert_eq!(
        a.time_gather.to_bits(),
        b.time_gather.to_bits(),
        "{what}: gather time diverged"
    );
}

#[test]
fn capacity_zero_cache_is_bit_identical_to_uncached_driver() {
    let d = dataset();
    for overlap in [false, true] {
        for kind in CACHED_KINDS {
            let base =
                run_strategy(d, &cfg(overlap, CachePolicy::None, 64), kind);
            let zero =
                run_strategy(d, &cfg(overlap, CachePolicy::Lru, 0), kind);
            assert_bit_identical(
                &base,
                &zero,
                &format!("{} overlap={overlap}", kind.name()),
            );
            assert_eq!(zero.cache_hits, 0, "{}", kind.name());
            assert_eq!(zero.cache_hit_bytes, 0, "{}", kind.name());
        }
    }
}

#[test]
fn capacity_zero_parity_holds_for_every_policy() {
    // the static policies' empty pin sets must bypass exactly like LRU's
    // empty recency map (DGL exercises the single-step gather path)
    let d = dataset();
    let base =
        run_strategy(d, &cfg(false, CachePolicy::None, 64), StrategySpec::dgl());
    for policy in ALL_CACHE_POLICIES {
        let zero = run_strategy(d, &cfg(false, policy, 0), StrategySpec::dgl());
        assert_bit_identical(&base, &zero, policy.name());
    }
}

#[test]
fn hit_bytes_sum_to_total_minus_transferred() {
    let d = dataset();
    for kind in [StrategySpec::dgl(), StrategySpec::hopgnn_mg_pg()] {
        let base = run_strategy(d, &cfg(false, CachePolicy::None, 64), kind);
        let warm = run_strategy(d, &cfg(false, CachePolicy::Lru, 64), kind);
        assert!(warm.cache_hits > 0, "{}: no reuse to cache", kind.name());
        // total requested is schedule-determined, so it equals what the
        // uncached run transferred; hits are exactly the bytes saved
        assert_eq!(
            warm.cache_hit_bytes + warm.cache_miss_bytes,
            base.bytes(TransferKind::Feature),
            "{}",
            kind.name()
        );
        assert_eq!(
            warm.cache_hit_bytes,
            base.bytes(TransferKind::Feature)
                - warm.bytes(TransferKind::Feature),
            "{}: hit bytes != total - transferred",
            kind.name()
        );
        assert_eq!(
            warm.cache_miss_bytes,
            warm.bytes(TransferKind::Feature),
            "{}: miss bytes must equal the feature bytes moved",
            kind.name()
        );
        assert!(
            warm.epoch_time < base.epoch_time,
            "{}: a warm cache must not slow the epoch ({} !< {})",
            kind.name(),
            warm.epoch_time,
            base.epoch_time
        );
    }
}

#[test]
fn overlap_mode_changes_no_cached_byte() {
    // with a warm cache, enabling overlap still only re-times exposure
    let d = dataset();
    for policy in ALL_CACHE_POLICIES {
        let serial =
            run_strategy(d, &cfg(false, policy, 16), StrategySpec::dgl());
        let over = run_strategy(d, &cfg(true, policy, 16), StrategySpec::dgl());
        for k in 0..NUM_KINDS {
            assert_eq!(
                serial.bytes_by_kind[k], over.bytes_by_kind[k],
                "{}: overlap changed cached byte accounting",
                policy.name()
            );
        }
        assert_eq!(serial.cache_hits, over.cache_hits, "{}", policy.name());
        assert_eq!(
            serial.cache_hit_bytes,
            over.cache_hit_bytes,
            "{}",
            policy.name()
        );
        assert!(
            over.epoch_time <= serial.epoch_time * (1.0 + 1e-12),
            "{}: overlap slowed the cached epoch",
            policy.name()
        );
    }
}

#[test]
fn cached_runs_replay_bit_identically_for_every_policy() {
    // eviction determinism at the full-strategy level: 1 MiB per server
    // is smaller than the per-server remote working set, so LRU evicts
    let d = dataset();
    for policy in ALL_CACHE_POLICIES {
        let a = run_strategy(d, &cfg(false, policy, 1), StrategySpec::dgl());
        let b = run_strategy(d, &cfg(false, policy, 1), StrategySpec::dgl());
        assert_bit_identical(&a, &b, policy.name());
        assert_eq!(a.cache_hits, b.cache_hits, "{}", policy.name());
        assert_eq!(a.cache_misses, b.cache_misses, "{}", policy.name());
        assert_eq!(
            a.cache_evict_bytes,
            b.cache_evict_bytes,
            "{}",
            policy.name()
        );
    }
}

#[test]
fn legacy_cache_knobs_alias_the_tier_grammar() {
    // `--cache <policy> --cache-mb 16` must be indistinguishable from
    // `--tiers dram:16m:<policy>+remote` set through the config
    // grammar (the full-field lock lives in tests/tier_parity.rs; this
    // pins the `cfg.set("tiers", ...)` round trip at run level)
    let d = dataset();
    for policy in ALL_CACHE_POLICIES {
        let legacy = run_strategy(d, &cfg(true, policy, 16), StrategySpec::dgl());
        let mut tiered_cfg = cfg(true, CachePolicy::None, 0);
        tiered_cfg
            .set("tiers", &format!("dram:16m:{}+remote", policy.name()))
            .expect("tier spec parses through the config grammar");
        let tiered = run_strategy(d, &tiered_cfg, StrategySpec::dgl());
        assert_bit_identical(&legacy, &tiered, policy.name());
        assert_eq!(legacy.cache_hits, tiered.cache_hits, "{}", policy.name());
        assert_eq!(
            legacy.cache_evict_bytes,
            tiered.cache_evict_bytes,
            "{}",
            policy.name()
        );
    }
}

#[test]
fn parallel_lanes_match_sequential_with_cache_on() {
    let d = dataset();
    for policy in ALL_CACHE_POLICIES {
        let mut seq_cfg = cfg(false, policy, 16);
        seq_cfg.parallel_lanes = false;
        let par_cfg = cfg(false, policy, 16);
        let seq = run_strategy(d, &seq_cfg, StrategySpec::dgl());
        let par = run_strategy(d, &par_cfg, StrategySpec::dgl());
        assert_bit_identical(&seq, &par, policy.name());
        assert_eq!(seq.cache_hits, par.cache_hits, "{}", policy.name());
        assert_eq!(
            seq.cache_evict_bytes,
            par.cache_evict_bytes,
            "{}",
            policy.name()
        );
    }
}
