//! Exhaustive grammar locks for the composable `StrategySpec`.
//!
//! * the **full axis product** (7 bases × 2 micrograph × 2 pregather ×
//!   4 merge = 112 combos) is partitioned by `validate()` into exactly
//!   the documented legal set (14 specs), every legal spec's canonical
//!   `Display` string parses back to the same value, and every illegal
//!   combo's string is rejected by `FromStr`;
//! * property test: emitting a legal spec's modifiers *explicitly* and
//!   in any order parses back to the same spec (the canonical string is
//!   just one spelling among many).

use hopgnn::coordinator::{
    Base, Merge, StrategySpec, ALL_BASES, ALL_LEGACY_SPECS, ALL_MERGES,
};
use hopgnn::util::prop;
use hopgnn::util::rng::Rng;

/// Every point of the raw axis product, legal or not.
fn full_product() -> Vec<StrategySpec> {
    let mut out = Vec::new();
    for base in ALL_BASES {
        for micrograph in [false, true] {
            for pregather in [false, true] {
                for merge in ALL_MERGES {
                    out.push(StrategySpec {
                        base,
                        micrograph,
                        pregather,
                        merge,
                    });
                }
            }
        }
    }
    out
}

#[test]
fn exhaustive_product_partitions_into_14_legal_specs() {
    let all = full_product();
    assert_eq!(all.len(), 7 * 2 * 2 * 4);
    let legal: Vec<StrategySpec> = all
        .iter()
        .copied()
        .filter(|s| s.validate().is_ok())
        .collect();
    // hopgnn: micrograph forced on, free pregather x merge = 8;
    // the six fixed-schedule bases admit only the all-off point
    assert_eq!(legal.len(), 14, "legal set changed: {legal:?}");
    for base in ALL_BASES {
        let per_base =
            legal.iter().filter(|s| s.base == base).count();
        let expect = if base == Base::HopGnn { 8 } else { 1 };
        assert_eq!(per_base, expect, "{base:?}");
    }
    // every legacy spec is inside the legal set
    for spec in ALL_LEGACY_SPECS {
        assert!(legal.contains(&spec), "{spec} missing from legal set");
    }
}

#[test]
fn exhaustive_display_from_str_round_trip() {
    for spec in full_product() {
        let text = spec.to_string();
        match spec.validate() {
            Ok(()) => {
                let back: StrategySpec = text.parse().unwrap_or_else(|e| {
                    panic!("canonical '{text}' failed to parse: {e}")
                });
                assert_eq!(back, spec, "round-trip of '{text}'");
                // the canonical string re-displays identically
                assert_eq!(back.to_string(), text);
            }
            Err(rule) => match text.parse::<StrategySpec>() {
                Err(e) => assert_eq!(
                    e,
                    format!("invalid strategy '{text}': {rule}"),
                    "parse error must carry the violated rule"
                ),
                Ok(other) => {
                    // Display is not injective over *illegal* values:
                    // a handful collide with legacy aliases (e.g.
                    // "hopgnn-mg"). Parsing must still never yield an
                    // invalid spec — and never this illegal one.
                    other.validate().unwrap_or_else(|e| {
                        panic!("FromStr returned an invalid spec: {e}")
                    });
                    assert_ne!(
                        other, spec,
                        "the illegal combo itself must be unreachable"
                    );
                }
            },
        }
    }
}

#[test]
fn prop_modifier_order_is_irrelevant_for_explicit_spellings() {
    // spell every axis explicitly (+/-mg, +/-pg, merge token) in a
    // random order behind the base; any ordering must parse back to
    // the same spec
    prop::check(
        "spec-grammar-order",
        60,
        |r| ((r.below(7), r.below(2)), (r.below(4), r.next_u64())),
        |&((base_i, pg_i), (merge_i, seed))| {
            let base = ALL_BASES[base_i];
            // force legality: hopgnn keeps micrograph on, other bases
            // get the all-off point with random spelling order only
            let spec = if base == Base::HopGnn {
                StrategySpec::hopgnn()
                    .pregather(pg_i == 1)
                    .merge(ALL_MERGES[merge_i])
            } else {
                StrategySpec::base_default(base)
            };
            let mut tokens = vec![
                format!("{}mg", if spec.micrograph { '+' } else { '-' }),
                format!("{}pg", if spec.pregather { '+' } else { '-' }),
                match spec.merge {
                    Merge::Off => "-merge".to_string(),
                    m => format!("+{}", m.token()),
                },
            ];
            let mut rng = Rng::new(seed);
            rng.shuffle(&mut tokens);
            let text =
                format!("{}{}", spec.base.token(), tokens.join(""));
            let parsed = text
                .parse::<StrategySpec>()
                .map_err(|e| format!("'{text}': {e}"))?;
            if parsed != spec {
                return Err(format!(
                    "'{text}' parsed to {parsed:?}, expected {spec:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn canonical_strings_of_the_legacy_specs_are_stable() {
    let canon: Vec<String> =
        ALL_LEGACY_SPECS.iter().map(|s| s.to_string()).collect();
    assert_eq!(
        canon,
        [
            "dgl",
            "p3",
            "naive",
            "hopgnn",
            "hopgnn-merge-pg",
            "hopgnn-merge",
            "hopgnn+rd",
            "hopgnn+fa",
            "lo",
            "ns",
            "dgl-fb"
        ]
    );
}
