//! Property suite for the memory-bounded chunk-streamed generator
//! (`graph::generator::community_graph_chunked`).
//!
//! Three properties, over randomized specs (`util::prop`):
//!
//! 1. **Chunk-size invariance** — the chunk is a buffering knob only:
//!    1 k-edge and 1 M-edge chunks (and a random size) produce
//!    bit-identical CSR arrays and community labels, all equal to the
//!    in-memory generator (the one-chunk special case).
//! 2. **Edge-count conservation** — symmetry (degree sum = 2·E) and
//!    edge-count equality hold across chunk sizes.
//! 3. **Degree-tail exponent** — the generated degree distribution's
//!    Hill estimate tracks the requested power-law `alpha` (generous
//!    tolerance; the sharp assertion is ordering: heavier-tailed specs
//!    estimate heavier).

use hopgnn::graph::generator::{
    community_graph, community_graph_chunked, rmat_graph,
    rmat_graph_chunked, CommunityGraphSpec,
};
use hopgnn::util::prop::{check, Shrink};
use hopgnn::util::rng::Rng;

#[derive(Clone, Debug)]
struct SpecCase {
    spec: CommunityGraphSpec,
    chunk: usize,
}

impl Shrink for SpecCase {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.spec.num_vertices > 500 {
            let mut s = self.clone();
            s.spec.num_vertices /= 2;
            s.spec.num_edges /= 2;
            out.push(s);
        }
        if self.chunk > 1 {
            let mut s = self.clone();
            s.chunk /= 2;
            out.push(s);
        }
        out
    }
}

fn gen_case(rng: &mut Rng) -> SpecCase {
    let num_vertices = rng.range(500, 4000);
    SpecCase {
        spec: CommunityGraphSpec {
            num_vertices,
            num_edges: num_vertices * rng.range(3, 7),
            num_communities: rng.range(4, 40),
            p_intra: 0.5 + rng.f64() * 0.45,
            alpha: 2.0 + rng.f64(),
            seed: rng.next_u64(),
        },
        chunk: rng.range(1, 5000),
    }
}

#[test]
fn prop_chunk_size_invariance_1k_vs_1m() {
    check("chunk_invariance", 12, gen_case, |case| {
        let base = community_graph(&case.spec);
        for chunk in [1_000usize, 1_000_000, case.chunk] {
            let g = community_graph_chunked(&case.spec, chunk);
            if g.graph != base.graph {
                return Err(format!("CSR diverged at chunk={chunk}"));
            }
            if g.community != base.community {
                return Err(format!("communities diverged at chunk={chunk}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_edge_count_conservation() {
    check("edge_conservation", 12, gen_case, |case| {
        let small = community_graph_chunked(&case.spec, case.chunk).graph;
        let large = community_graph_chunked(&case.spec, 1_000_000).graph;
        if small.num_edges() != large.num_edges() {
            return Err(format!(
                "edge counts diverged: {} vs {}",
                small.num_edges(),
                large.num_edges()
            ));
        }
        // symmetrized storage: degree sum is exactly twice the count
        let degree_sum: usize = (0..small.num_vertices() as u32)
            .map(|v| small.degree(v))
            .sum();
        if degree_sum != 2 * small.num_edges() {
            return Err(format!(
                "degree sum {degree_sum} != 2 x {} edges",
                small.num_edges()
            ));
        }
        Ok(())
    });
}

#[test]
fn rmat_chunked_matches_unchunked_across_sizes() {
    let base = rmat_graph(11, 20_000, 9);
    for chunk in [1_000, 1_000_000] {
        assert_eq!(
            rmat_graph_chunked(11, 20_000, 9, chunk),
            base,
            "chunk={chunk}"
        );
    }
}

/// Hill estimator of the power-law exponent from the top-`k` degrees:
/// for degree density ~ d^-alpha the tail index is alpha - 1, and
/// alpha_hat = 1 + k / sum(ln(d_i / d_(k+1))).
fn hill_alpha(graph: &hopgnn::graph::CsrGraph, k: usize) -> f64 {
    let mut degs: Vec<f64> = (0..graph.num_vertices() as u32)
        .map(|v| graph.degree(v) as f64)
        .filter(|&d| d > 0.0)
        .collect();
    degs.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap());
    assert!(degs.len() > k + 1, "not enough vertices for the tail");
    let cutoff = degs[k];
    let log_sum: f64 = degs[..k].iter().map(|d| (d / cutoff).ln()).sum();
    1.0 + k as f64 / log_sum
}

#[test]
fn degree_tail_exponent_tracks_alpha() {
    // moderate average degree and weak communities keep dedup
    // collisions (which truncate the tail) rare
    let spec_for = |alpha: f64| CommunityGraphSpec {
        num_vertices: 40_000,
        num_edges: 200_000,
        num_communities: 100,
        p_intra: 0.3,
        alpha,
        seed: 4242,
    };
    let est_low =
        hill_alpha(&community_graph_chunked(&spec_for(2.1), 8192).graph, 300);
    let est_high =
        hill_alpha(&community_graph_chunked(&spec_for(3.5), 8192).graph, 300);
    // generous absolute band: stub rounding, dedup, and the +1 degree
    // shift all bias the estimate, but not by a full unit
    assert!(
        (est_low - 2.1).abs() < 1.0,
        "alpha=2.1 estimated {est_low}"
    );
    // the sharp property: a heavier requested tail must estimate
    // heavier than a lighter one
    assert!(
        est_low + 0.3 < est_high,
        "tail ordering violated: alpha=2.1 -> {est_low}, \
         alpha=3.5 -> {est_high}"
    );
}

/// The billion-edge acceptance path at one-tenth scale, kept out of the
/// default suite (minutes of single-core RNG streaming):
/// `cargo test --release -- --ignored generator_scale`. Peak RSS stays
/// within the generator's stated `16 V + 8 E + chunk` budget because
/// the unsorted edge list never materializes.
#[test]
#[ignore = "multi-minute: 1e8-edge chunk-streamed build"]
fn hundred_million_edge_graph_builds_chunked() {
    let spec = CommunityGraphSpec {
        num_vertices: 10_000_000,
        num_edges: 100_000_000,
        num_communities: 25_000,
        p_intra: 0.93,
        alpha: 2.1,
        seed: 1,
    };
    let g = community_graph_chunked(&spec, 4 << 20).graph;
    assert_eq!(g.num_vertices(), 10_000_000);
    assert!(g.num_edges() > 60_000_000, "edges {}", g.num_edges());
}
