//! Cross-strategy integration tests: the paper's qualitative claims must
//! hold on the simulated cluster (ordering, byte relations, invariants).

use hopgnn::cluster::TransferKind;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use std::sync::OnceLock;

/// One shared 60k-vertex dataset: big enough that a 256-root batch with
/// fanout 5 samples well under 20% of the graph (the no-overlap regime
/// the paper operates in), small enough to build once in seconds.
fn dataset(_case: u64) -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "strat-int",
            num_vertices: 60_000,
            num_edges: 450_000,
            feat_dim: 128,
            classes: 10,
            num_communities: 150,
            train_fraction: 0.3,
            seed: 901,
        })
    })
}

fn cfg() -> RunConfig {
    RunConfig {
        batch_size: 256,
        num_servers: 4,
        epochs: 4,
        max_iterations: Some(4),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        // high-dim features put the tests in the gather-dominated regime
        // the paper operates in (its graphs move GBs of features per
        // epoch; at unit-test scale launch/barrier overheads would
        // otherwise dominate)
        feat_dim_override: Some(600),
        ..Default::default()
    }
}

#[test]
fn headline_ordering_hopgnn_beats_dgl_and_p3() {
    let d = dataset(1);
    let c = cfg();
    let dgl = run_strategy(d, &c, StrategySpec::dgl());
    let p3 = run_strategy(d, &c, StrategySpec::p3());
    let hop = run_strategy(d, &c, StrategySpec::hopgnn());
    assert!(
        hop.epoch_time < dgl.epoch_time,
        "HopGNN {} !< DGL {}",
        hop.epoch_time,
        dgl.epoch_time
    );
    // at unit-test scale HopGNN's fixed per-step overheads (launches,
    // barriers) weigh more than at paper scale, so assert shape-level
    // competitiveness here; the full-scale fig11 run asserts dominance
    assert!(
        hop.epoch_time < p3.epoch_time * 1.6,
        "HopGNN {} not competitive with P3 {}",
        hop.epoch_time,
        p3.epoch_time
    );
}

#[test]
fn ablation_monotone_improvement() {
    // Fig 13: each technique improves (or at least does not hurt) epoch
    // time: DGL >= +MG >= +PG >= All (allowing small noise).
    let d = dataset(2);
    let c = cfg();
    let dgl = run_strategy(d, &c, StrategySpec::dgl()).epoch_time;
    let mg = run_strategy(d, &c, StrategySpec::hopgnn_mg()).epoch_time;
    let pg = run_strategy(d, &c, StrategySpec::hopgnn_mg_pg()).epoch_time;
    let all = run_strategy(d, &c, StrategySpec::hopgnn()).epoch_time;
    assert!(mg < dgl, "+MG {mg} !< DGL {dgl}");
    assert!(pg <= mg * 1.02, "+PG {pg} !<= +MG {mg}");
    assert!(all <= pg * 1.05, "All {all} !<= +PG {pg} (merging reverts)");
}

#[test]
fn miss_rate_drops_with_micrographs() {
    // Fig 14's direction: micrograph training slashes the miss rate.
    let d = dataset(3);
    let c = cfg();
    let dgl = run_strategy(d, &c, StrategySpec::dgl());
    let mg = run_strategy(d, &c, StrategySpec::hopgnn_mg());
    assert!(dgl.miss_rate() > 0.6, "DGL miss {}", dgl.miss_rate());
    assert!(
        mg.miss_rate() < dgl.miss_rate() * 0.6,
        "+MG miss {} vs DGL {}",
        mg.miss_rate(),
        dgl.miss_rate()
    );
}

#[test]
fn p3_hidden_dim_sensitivity() {
    // Fig 11/12's P3 story: P3 beats DGL at h16, loses its edge at h128.
    let d = dataset(4);
    let mut c = cfg();
    c.hidden = 16;
    let p3_16 = run_strategy(d, &c, StrategySpec::p3()).epoch_time;
    let dgl_16 = run_strategy(d, &c, StrategySpec::dgl()).epoch_time;
    c.hidden = 128;
    let p3_128 = run_strategy(d, &c, StrategySpec::p3()).epoch_time;
    let dgl_128 = run_strategy(d, &c, StrategySpec::dgl()).epoch_time;
    let edge_16 = dgl_16 / p3_16;
    let edge_128 = dgl_128 / p3_128;
    assert!(edge_16 > 1.0, "P3 should win at h16 ({edge_16:.2}x)");
    assert!(
        edge_128 < edge_16,
        "P3 edge must shrink with hidden dim: {edge_16:.2} -> {edge_128:.2}"
    );
}

#[test]
fn gpu_busy_fraction_ordering() {
    // Fig 20: HopGNN keeps the GPU busier than DGL.
    let d = dataset(5);
    let c = cfg();
    let dgl = run_strategy(d, &c, StrategySpec::dgl());
    let hop = run_strategy(d, &c, StrategySpec::hopgnn());
    assert!(
        hop.gpu_busy_fraction > dgl.gpu_busy_fraction,
        "busy: hop {} !> dgl {}",
        hop.gpu_busy_fraction,
        dgl.gpu_busy_fraction
    );
}

#[test]
fn feature_centric_strategies_move_fewer_feature_bytes() {
    let d = dataset(6);
    let c = cfg();
    let dgl = run_strategy(d, &c, StrategySpec::dgl());
    let hop = run_strategy(d, &c, StrategySpec::hopgnn());
    let lo = run_strategy(d, &c, StrategySpec::locality_opt());
    assert!(hop.bytes(TransferKind::Feature) < dgl.bytes(TransferKind::Feature));
    assert!(lo.bytes(TransferKind::Feature) <= hop.bytes(TransferKind::Feature));
    // P3 moves no raw features at all
    let p3 = run_strategy(d, &c, StrategySpec::p3());
    assert_eq!(p3.bytes(TransferKind::Feature), 0);
    assert!(p3.bytes(TransferKind::Hidden) > 0);
}

#[test]
fn full_batch_ordering() {
    // Fig 21: HopGNN-FB <= NeutronStar <= DGL-FB in epoch time.
    use hopgnn::coordinator::neutronstar::{FullBatchMode, NeutronStar};
    use hopgnn::coordinator::{SimEnv, Strategy};
    let d = dataset(7);
    let c = cfg();
    let run = |mode| {
        let mut env = SimEnv::new(&d, c.clone());
        NeutronStar::with_mode(mode).run_epoch(&mut env).epoch_time
    };
    let dgl_fb = run(FullBatchMode::DglFb);
    let ns = run(FullBatchMode::Hybrid);
    let hop_fb = run(FullBatchMode::HopFb);
    assert!(ns <= dgl_fb, "NS {ns} !<= DGL-FB {dgl_fb}");
    assert!(hop_fb < dgl_fb, "HopFB {hop_fb} !< DGL-FB {dgl_fb}");
}

#[test]
fn more_servers_hopgnn_still_wins() {
    // Fig 23b's direction: HopGNN keeps its advantage as machines scale
    // (merging absorbs the extra per-step overheads). The growth trend is
    // asserted at full scale by the fig23 reproduction.
    let d = dataset(8);
    let mut c = cfg();
    c.epochs = 6; // give the merge controller room to converge at N=6
    // weak scaling (as in the paper): per-server batch share stays fixed,
    // so per-(model, server) root groups stay statistically balanced
    c.num_servers = 2;
    c.batch_size = 128 * 2;
    let s2 = run_strategy(d, &c, StrategySpec::dgl()).epoch_time
        / run_strategy(d, &c, StrategySpec::hopgnn()).epoch_time;
    c.num_servers = 6;
    c.batch_size = 128 * 6;
    let s6 = run_strategy(d, &c, StrategySpec::dgl()).epoch_time
        / run_strategy(d, &c, StrategySpec::hopgnn()).epoch_time;
    assert!(s2 > 1.2, "2 servers: speedup {s2:.2}x");
    assert!(s6 > 1.0, "6 servers: speedup {s6:.2}x");
}
