//! The `StrategyKind` → `StrategySpec` redesign parity lock.
//!
//! The closed strategy enum was replaced by the composable
//! `StrategySpec` (axes: base × micrograph × pregather × merge). This
//! suite replays the *pre-redesign dispatch* — the exact constructor
//! arms and steady-state reporting the deleted `StrategyKind::build` /
//! `run_strategy(kind)` pair used — and locks every legacy alias,
//! parsed through the new spec grammar and run through the new
//! `run_strategy(spec)` path, to bit-identical `EpochMetrics`: every
//! integer counter equal, every float equal to the bit, on two datasets
//! in both serial and overlap modes.

use hopgnn::config::RunConfig;
use hopgnn::coordinator::hopgnn::HopGnn;
use hopgnn::coordinator::locality_opt::LocalityOpt;
use hopgnn::coordinator::model_centric::ModelCentric;
use hopgnn::coordinator::naive_fc::NaiveFc;
use hopgnn::coordinator::neutronstar::NeutronStar;
use hopgnn::coordinator::p3::P3;
use hopgnn::coordinator::{
    run_strategy, SimEnv, Strategy, StrategySpec, ALL_LEGACY_SPECS,
};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use hopgnn::partition::PartitionAlgo;
use std::sync::OnceLock;

/// The 11 pre-redesign kinds by their primary CLI aliases, in the old
/// enum's presentation order.
const LEGACY_ALIASES: [&str; 11] = [
    "dgl", "p3", "naive", "hopgnn", "+mg", "+pg", "rd", "fa", "lo", "ns",
    "dgl-fb",
];

/// The pre-redesign `StrategyKind::build` arms, reproduced verbatim on
/// the strategy constructors (which predate the redesign).
fn legacy_build(alias: &str) -> Box<dyn Strategy> {
    match alias {
        "dgl" => Box::new(ModelCentric::new()),
        "p3" => Box::new(P3::new()),
        "naive" => Box::new(NaiveFc::new()),
        "hopgnn" => Box::new(HopGnn::full()),
        "+mg" => Box::new(HopGnn::mg_only()),
        "+pg" => Box::new(HopGnn::mg_pg()),
        "rd" => Box::new(HopGnn::random_merge()),
        "fa" => Box::new(HopGnn::fabric_aware()),
        "lo" => Box::new(LocalityOpt::new()),
        "ns" => Box::new(NeutronStar::new(false)),
        "dgl-fb" => Box::new(NeutronStar::new(true)),
        other => panic!("not a legacy alias: {other}"),
    }
}

/// The pre-redesign `adapts_across_epochs` (HopGNN full / RD / FA).
fn legacy_adapts(alias: &str) -> bool {
    matches!(alias, "hopgnn" | "rd" | "fa")
}

/// The pre-redesign `run_strategy(dataset, cfg, kind)`, replayed.
fn legacy_run(d: &Dataset, cfg: &RunConfig, alias: &str) -> EpochMetrics {
    let mut cfg = cfg.clone();
    if alias == "p3" {
        // StrategyKind::preferred_partition: P3 requires hash
        cfg.partition_algo = PartitionAlgo::Hash;
    }
    let epochs = cfg.epochs;
    let mut env = SimEnv::new(d, cfg);
    let mut strat = legacy_build(alias);
    let per_epoch = strat.run(&mut env, epochs);
    let steady = if per_epoch.len() > 2 && legacy_adapts(alias) {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

fn dataset_a() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "spec-parity-a",
            num_vertices: 6_000,
            num_edges: 42_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 30,
            train_fraction: 0.4,
            seed: 6161,
        })
    })
}

fn dataset_b() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "spec-parity-b",
            num_vertices: 9_000,
            num_edges: 54_000,
            feat_dim: 32,
            classes: 6,
            num_communities: 45,
            train_fraction: 0.35,
            seed: 7272,
        })
    })
}

fn cfg(overlap: bool) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        // 3 epochs > 2: exercises the adapting strategies' steady-state
        // (last frozen epoch) reporting path on both dispatches
        epochs: 3,
        max_iterations: Some(2),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        overlap,
        ..Default::default()
    }
}

/// Every field of `EpochMetrics`, integers equal and floats equal to
/// the bit.
fn assert_bit_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    assert_eq!(a.bytes_by_kind, b.bytes_by_kind, "{what}: bytes_by_kind");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
    assert_eq!(a.cache_hits, b.cache_hits, "{what}");
    assert_eq!(a.cache_misses, b.cache_misses, "{what}");
    assert_eq!(a.cache_hit_bytes, b.cache_hit_bytes, "{what}");
    assert_eq!(a.cache_miss_bytes, b.cache_miss_bytes, "{what}");
    assert_eq!(a.cache_evict_bytes, b.cache_evict_bytes, "{what}");
    assert_eq!(a.iterations, b.iterations, "{what}");
    assert_eq!(a.dropped_roots, b.dropped_roots, "{what}");
    for (x, y, field) in [
        (a.epoch_time, b.epoch_time, "epoch_time"),
        (a.time_sample, b.time_sample, "time_sample"),
        (a.time_gather, b.time_gather, "time_gather"),
        (a.time_compute, b.time_compute, "time_compute"),
        (a.time_migrate, b.time_migrate, "time_migrate"),
        (a.time_sync, b.time_sync, "time_sync"),
        (
            a.time_overlap_hidden,
            b.time_overlap_hidden,
            "time_overlap_hidden",
        ),
        (a.gpu_busy_fraction, b.gpu_busy_fraction, "gpu_busy_fraction"),
        (
            a.time_steps_per_iter,
            b.time_steps_per_iter,
            "time_steps_per_iter",
        ),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: {field} diverged ({x} vs {y})"
        );
    }
    assert_eq!(
        a.per_server_busy.len(),
        b.per_server_busy.len(),
        "{what}: per_server_busy length"
    );
    for (s, (x, y)) in
        a.per_server_busy.iter().zip(&b.per_server_busy).enumerate()
    {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: per_server_busy[{s}] diverged"
        );
    }
}

#[test]
fn every_legacy_alias_matches_the_pre_redesign_dispatch() {
    for d in [dataset_a(), dataset_b()] {
        for overlap in [false, true] {
            let c = cfg(overlap);
            for alias in LEGACY_ALIASES {
                let old = legacy_run(d, &c, alias);
                let spec: StrategySpec = alias.parse().unwrap();
                let new = run_strategy(d, &c, spec);
                assert_bit_identical(
                    &old,
                    &new,
                    &format!(
                        "{alias} (spec {spec}) overlap={overlap} on {}",
                        d.name
                    ),
                );
            }
        }
    }
}

#[test]
fn the_alias_list_covers_exactly_the_legacy_spec_table() {
    // the 11 aliases parse to the 11 legacy specs, in order
    let parsed: Vec<StrategySpec> = LEGACY_ALIASES
        .iter()
        .map(|a| a.parse().unwrap())
        .collect();
    assert_eq!(parsed, ALL_LEGACY_SPECS);
}

#[test]
fn new_compositions_run_without_legacy_equivalents() {
    // the point of the redesign: combinations the enum could not
    // express execute end to end (fabric-aware merge without
    // pre-gathering, min-load merge without pre-gathering)
    let d = dataset_a();
    let c = cfg(false);
    for spec_str in ["hopgnn+fa-pg", "hopgnn-pg", "hopgnn+rd-pg"] {
        let spec: StrategySpec = spec_str.parse().unwrap();
        assert!(
            !ALL_LEGACY_SPECS.contains(&spec),
            "{spec_str} should be a new combination"
        );
        let m = run_strategy(d, &c, spec);
        assert!(m.epoch_time > 0.0, "{spec_str}: no epoch simulated");
        assert!(m.total_bytes() > 0, "{spec_str}: nothing moved");
    }
}
