//! Epoch-sample memo parity lock: sampling through the cross-cell tape
//! memo (`bench::memo`, `RunConfig::memo_samples`) must be bit-identical
//! to sampling live — in *all three* tape modes.
//!
//! The first memoized run **records** each epoch's sampling stream
//! (live sampling plus a copy into the tape), every later identically-
//! keyed run **replays** it, and a run with the flag off never touches
//! the memo. The tape key (`bench::memo::SampleKey`) deliberately
//! excludes the axes that only price the sampled work — fabric, cache
//! policy/capacity, overlap, lane parallelism — so sweep cells varying
//! those axes share one tape. This suite locks every `EpochMetrics`
//! field across all of it: integers exactly, floats to the bit (the
//! `tests/spec_parity.rs` idiom).
//!
//! The memoized runs take their dataset from `bench::memo::dataset`
//! (the process-lifetime lease) because the tape key includes the
//! dataset address — exactly the invariant `bench::memo::run` relies
//! on.

use hopgnn::bench::memo;
use hopgnn::cluster::FabricSpec;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{SimEnv, StrategySpec};
use hopgnn::featstore::cache::CachePolicy;
use hopgnn::metrics::EpochMetrics;

fn base_cfg(seed: u64) -> RunConfig {
    RunConfig {
        dataset: "arxiv-s".into(),
        batch_size: 128,
        epochs: 3,
        max_iterations: Some(2),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed,
        ..Default::default()
    }
}

/// Run `spec` for `cfg.epochs` epochs and return the per-epoch metrics.
fn run_epochs(cfg: &RunConfig, spec: StrategySpec) -> Vec<EpochMetrics> {
    let d = memo::dataset(&cfg.dataset);
    let mut cfg = cfg.clone();
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let epochs = cfg.epochs;
    let mut env = SimEnv::new(d, cfg);
    spec.build().run(&mut env, epochs)
}

/// Every field of `EpochMetrics`, integers equal and floats equal to
/// the bit (mirrors `tests/spec_parity.rs::assert_bit_identical`).
fn assert_bit_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    assert_eq!(a.bytes_by_kind, b.bytes_by_kind, "{what}: bytes_by_kind");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
    assert_eq!(a.cache_hits, b.cache_hits, "{what}");
    assert_eq!(a.cache_misses, b.cache_misses, "{what}");
    assert_eq!(a.cache_hit_bytes, b.cache_hit_bytes, "{what}");
    assert_eq!(a.cache_miss_bytes, b.cache_miss_bytes, "{what}");
    assert_eq!(a.cache_evict_bytes, b.cache_evict_bytes, "{what}");
    assert_eq!(a.iterations, b.iterations, "{what}");
    assert_eq!(a.dropped_roots, b.dropped_roots, "{what}");
    for (x, y, field) in [
        (a.epoch_time, b.epoch_time, "epoch_time"),
        (a.time_sample, b.time_sample, "time_sample"),
        (a.time_gather, b.time_gather, "time_gather"),
        (a.time_compute, b.time_compute, "time_compute"),
        (a.time_migrate, b.time_migrate, "time_migrate"),
        (a.time_sync, b.time_sync, "time_sync"),
        (
            a.time_overlap_hidden,
            b.time_overlap_hidden,
            "time_overlap_hidden",
        ),
        (a.gpu_busy_fraction, b.gpu_busy_fraction, "gpu_busy_fraction"),
        (
            a.time_steps_per_iter,
            b.time_steps_per_iter,
            "time_steps_per_iter",
        ),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: {field} diverged ({x} vs {y})"
        );
    }
    assert_eq!(
        a.per_server_busy.len(),
        b.per_server_busy.len(),
        "{what}: per_server_busy length"
    );
    for (s, (x, y)) in
        a.per_server_busy.iter().zip(&b.per_server_busy).enumerate()
    {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: per_server_busy[{s}] diverged"
        );
    }
}

fn assert_epochs_identical(
    a: &[EpochMetrics],
    b: &[EpochMetrics],
    what: &str,
) {
    assert_eq!(a.len(), b.len(), "{what}: epoch count");
    for (e, (x, y)) in a.iter().zip(b).enumerate() {
        assert_bit_identical(x, y, &format!("{what} epoch {e}"));
    }
}

/// Live / record / replay runs of one spec are indistinguishable.
#[test]
fn memoized_sampling_is_bit_identical_per_epoch() {
    for (spec, name) in [
        (StrategySpec::dgl(), "dgl"),
        (StrategySpec::locality_opt(), "lo"),
        (StrategySpec::hopgnn_mg(), "hopgnn+mg"),
        (StrategySpec::hopgnn_mg_pg(), "hopgnn+mg+pg"),
        (StrategySpec::hopgnn(), "hopgnn"),
    ] {
        let live = base_cfg(9100);
        let memoized = RunConfig {
            memo_samples: true,
            ..live.clone()
        };
        let off = run_epochs(&live, spec);
        // first memoized run records the tapes...
        let record = run_epochs(&memoized, spec);
        // ...the second replays them
        let replay = run_epochs(&memoized, spec);
        assert_epochs_identical(&off, &record, &format!("{name} record"));
        assert_epochs_identical(&off, &replay, &format!("{name} replay"));
    }
}

/// The sweep-sharing property: cells that differ only in pricing axes
/// (overlap, fabric, cache) share one tape, and each replayed cell is
/// bit-identical to its own live-sampled twin.
#[test]
fn pricing_axes_share_one_tape_without_observable_effect() {
    let spec = StrategySpec::hopgnn();
    let cells = [
        base_cfg(9200),
        RunConfig {
            overlap: true,
            ..base_cfg(9200)
        },
        RunConfig {
            fabric: FabricSpec::HeteroMix,
            ..base_cfg(9200)
        },
        RunConfig {
            cache_policy: CachePolicy::Lru,
            cache_mb: 16,
            ..base_cfg(9200)
        },
    ];
    // the first memoized cell records; every later cell with the same
    // sampling inputs replays its tape (same seed + dataset + sampler
    // config — only pricing differs)
    for (i, cell) in cells.iter().enumerate() {
        let live = run_epochs(cell, spec);
        let memoized = run_epochs(
            &RunConfig {
                memo_samples: true,
                ..cell.clone()
            },
            spec,
        );
        assert_epochs_identical(
            &live,
            &memoized,
            &format!("pricing cell {i}"),
        );
    }
}

/// The public entry point (`bench::memo::run`, which the sweep engine
/// uses per cell) matches the uncached `run_strategy` reporting path.
#[test]
fn memo_run_matches_run_strategy() {
    let cfg = base_cfg(9300);
    for spec in [
        StrategySpec::dgl(),
        StrategySpec::hopgnn(),
        StrategySpec::locality_opt(),
    ] {
        let d = memo::dataset(&cfg.dataset);
        let uncached =
            hopgnn::coordinator::run_strategy(d, &cfg, spec);
        let cached = memo::run(&cfg, spec);
        // run twice so both the record and the replay path are covered
        let replayed = memo::run(&cfg, spec);
        assert_bit_identical(
            &uncached,
            &cached,
            &format!("memo::run record ({})", spec.name()),
        );
        assert_bit_identical(
            &uncached,
            &replayed,
            &format!("memo::run replay ({})", spec.name()),
        );
    }
}
