//! Fabric-layer locks: the topology-aware refactor must be invisible
//! on a uniform cluster and well-behaved on every named topology.
//!
//! * **uniform parity** — `--fabric uniform` performs exactly the same
//!   float operations on exactly the same values as the legacy scalar
//!   `NetworkModel`, so every strategy's run is *bit-identical* to the
//!   pre-fabric simulator (also cross-checked via `rack:1`, which
//!   degenerates to uniform).
//! * **constructor properties** — all topologies are symmetric and
//!   strictly positive off the diagonal; `rack:<k>` applies exactly
//!   the documented oversubscription ratio.
//! * **heterogeneity is observable** — non-uniform fabrics slow the
//!   epoch without moving a single extra byte, and the straggler's
//!   compute multiplier shows up in the observed per-server lane
//!   times.

use hopgnn::cluster::fabric::{
    rack_of, RACK_CROSS_LATENCY_FACTOR, RACK_OVERSUBSCRIPTION,
    STRAGGLER_COMPUTE_FACTOR,
};
use hopgnn::cluster::network::NUM_KINDS;
use hopgnn::cluster::{Fabric, FabricSpec, NetworkModel};
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec, ALL_LEGACY_SPECS};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use hopgnn::util::prop;
use hopgnn::util::rng::Rng;
use std::sync::OnceLock;

fn dataset() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "fabric-parity",
            num_vertices: 8_000,
            num_edges: 56_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 40,
            train_fraction: 0.4,
            seed: 2424,
        })
    })
}

fn cfg(fabric: FabricSpec) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        epochs: 2,
        max_iterations: Some(3),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        fabric,
        ..Default::default()
    }
}

fn cfg_overlap(fabric: FabricSpec) -> RunConfig {
    RunConfig {
        overlap: true,
        ..cfg(fabric)
    }
}

fn assert_bit_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    for k in 0..NUM_KINDS {
        assert_eq!(
            a.bytes_by_kind[k], b.bytes_by_kind[k],
            "{what}: byte totals diverged for kind index {k}"
        );
    }
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
    assert_eq!(
        a.epoch_time.to_bits(),
        b.epoch_time.to_bits(),
        "{what}: epoch time must be bit-identical ({} vs {})",
        a.epoch_time,
        b.epoch_time
    );
    assert_eq!(
        a.gpu_busy_fraction.to_bits(),
        b.gpu_busy_fraction.to_bits(),
        "{what}: busy fraction diverged"
    );
}

fn random_net(rng: &mut Rng) -> NetworkModel {
    NetworkModel {
        latency: 1e-6 * (1 + rng.below(500)) as f64,
        bandwidth: 1e8 * (1 + rng.below(100)) as f64,
    }
}

#[test]
fn prop_uniform_fabric_is_bitwise_the_scalar_model() {
    // the pre-refactor scalar path still exists as
    // NetworkModel::transfer_time; the uniform fabric must reproduce it
    // bit for bit on every link, for arbitrary rates and sizes
    prop::check(
        "uniform-fabric-parity",
        50,
        |r| (2 + r.below(7), r.next_u64()),
        |&(n, seed)| {
            let mut rng = Rng::new(seed);
            let net = random_net(&mut rng);
            let f = Fabric::uniform(n, net);
            for _ in 0..20 {
                let bytes = rng.next_u64() % (1 << 32);
                let src = rng.below(n);
                let dst = rng.below(n);
                if f.transfer_time(src, dst, bytes).to_bits()
                    != net.transfer_time(bytes).to_bits()
                {
                    return Err(format!(
                        "link ({src},{dst}) diverged at {bytes} bytes"
                    ));
                }
            }
            for s in 0..n {
                if f.compute_speed(s) != 1.0 {
                    return Err(format!("server {s} not at full speed"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fabrics_are_symmetric_and_positive() {
    prop::check(
        "fabric-symmetry",
        40,
        |r| (2 + r.below(7), r.next_u64()),
        |&(n, seed)| {
            let mut rng = Rng::new(seed);
            let net = random_net(&mut rng);
            let specs = [
                FabricSpec::Uniform,
                FabricSpec::Rack {
                    racks: 1 + rng.below(n),
                },
                FabricSpec::HeteroMix,
                FabricSpec::Straggler {
                    server: rng.below(n),
                },
            ];
            for spec in specs {
                let f = spec.build(n, net);
                for src in 0..n {
                    if f.compute_speed(src) <= 0.0 {
                        return Err(format!(
                            "{}: non-positive speed on {src}",
                            spec.name()
                        ));
                    }
                    for dst in 0..n {
                        if src == dst {
                            continue;
                        }
                        let ab = f.transfer_time(src, dst, 1 << 20);
                        let ba = f.transfer_time(dst, src, 1 << 20);
                        if ab.to_bits() != ba.to_bits() {
                            return Err(format!(
                                "{}: asymmetric link ({src},{dst})",
                                spec.name()
                            ));
                        }
                        if !(ab > 0.0 && ab.is_finite()) {
                            return Err(format!(
                                "{}: bad link time {ab}",
                                spec.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rack_oversubscription_ratio_is_exact() {
    let net = NetworkModel::default();
    for n in [4usize, 6, 8] {
        for racks in [2usize, 3] {
            let f = Fabric::rack(n, net, racks);
            for src in 0..n {
                for dst in 0..n {
                    if src == dst {
                        continue;
                    }
                    let cross =
                        rack_of(src, n, racks) != rack_of(dst, n, racks);
                    let ratio =
                        net.bandwidth / f.link_bandwidth(src, dst);
                    let lat_ratio =
                        f.link_latency(src, dst) / net.latency;
                    if cross {
                        assert_eq!(ratio, RACK_OVERSUBSCRIPTION);
                        assert_eq!(lat_ratio, RACK_CROSS_LATENCY_FACTOR);
                    } else {
                        assert_eq!(ratio, 1.0);
                        assert_eq!(lat_ratio, 1.0);
                    }
                }
            }
        }
    }
}

#[test]
fn uniform_fabric_runs_every_strategy_bit_identically_to_rack1() {
    // rack:1 builds the identical link matrix through the non-uniform
    // constructor path — a whole-simulator equivalence check
    let d = dataset();
    for kind in ALL_LEGACY_SPECS {
        let uni = run_strategy(d, &cfg(FabricSpec::Uniform), kind);
        let rack1 =
            run_strategy(d, &cfg(FabricSpec::Rack { racks: 1 }), kind);
        assert_bit_identical(&uni, &rack1, &kind.name());
    }
    // and the same holds with the overlap lanes engaged
    for kind in [
        StrategySpec::dgl(),
        StrategySpec::hopgnn_mg_pg(),
        StrategySpec::hopgnn(),
    ] {
        let uni = run_strategy(d, &cfg_overlap(FabricSpec::Uniform), kind);
        let rack1 = run_strategy(
            d,
            &cfg_overlap(FabricSpec::Rack { racks: 1 }),
            kind,
        );
        assert_bit_identical(
            &uni,
            &rack1,
            &format!("{} (overlap)", kind.name()),
        );
    }
}

#[test]
fn heterogeneous_fabrics_change_time_not_bytes() {
    let d = dataset();
    for kind in [StrategySpec::dgl(), StrategySpec::p3(), StrategySpec::naive()] {
        let uni = run_strategy(d, &cfg(FabricSpec::Uniform), kind);
        for spec in [
            FabricSpec::Rack { racks: 2 },
            FabricSpec::HeteroMix,
            FabricSpec::Straggler { server: 0 },
        ] {
            let het = run_strategy(d, &cfg(spec), kind);
            for k in 0..NUM_KINDS {
                assert_eq!(
                    uni.bytes_by_kind[k],
                    het.bytes_by_kind[k],
                    "{} on {}: fabric changed byte accounting",
                    kind.name(),
                    spec.name()
                );
            }
            assert!(
                het.epoch_time > uni.epoch_time,
                "{} on {}: {} !> uniform {}",
                kind.name(),
                spec.name(),
                het.epoch_time,
                uni.epoch_time
            );
        }
    }
}

#[test]
fn straggler_compute_shows_in_observed_lane_times() {
    let d = dataset();
    let m = run_strategy(
        d,
        &cfg(FabricSpec::Straggler { server: 2 }),
        StrategySpec::dgl(),
    );
    assert_eq!(m.per_server_busy.len(), 4);
    let fast_mean = (m.per_server_busy[0]
        + m.per_server_busy[1]
        + m.per_server_busy[3])
        / 3.0;
    let ratio = m.per_server_busy[2] / fast_mean;
    // same expected work per server, half speed on the straggler
    assert!(
        ratio > 0.7 * STRAGGLER_COMPUTE_FACTOR
            && ratio < 1.3 * STRAGGLER_COMPUTE_FACTOR,
        "straggler busy ratio {ratio} not near {STRAGGLER_COMPUTE_FACTOR}"
    );
}

#[test]
fn fabric_runs_are_deterministic_with_parallel_lanes() {
    let d = dataset();
    for spec in [
        FabricSpec::Rack { racks: 2 },
        FabricSpec::Straggler { server: 0 },
    ] {
        let a = run_strategy(d, &cfg(spec), StrategySpec::hopgnn_fa());
        let b = run_strategy(d, &cfg(spec), StrategySpec::hopgnn_fa());
        assert_bit_identical(&a, &b, &spec.name());
    }
}
