//! Allocation-budget lock for the iteration hot path: after a warm-up
//! pass, steady-state iterations — scratch-based sampling, pooled
//! program building, buffer-reusing gather planning, and sequential
//! lane execution — must perform **zero** heap allocations.
//!
//! The test installs the counting global allocator
//! (`util::alloc::CountingAlloc`) and drives the exact per-iteration
//! shape the strategy schedule builders emit: sample into a pooled
//! payload buffer, emit `Sample`/`Gather`/`GatherMerged`/`Compute`
//! ops, `take()` the program, execute it on the shared `EpochDriver`,
//! and `recycle()` the program back into the builder pools. The RNG is
//! re-seeded per iteration so every iteration touches the same key
//! set — exactly the steady state the generation-stamped scratch
//! containers are warmed for.
//!
//! Scope (mirrors the documented zero-alloc envelope): sequential
//! lanes (`parallel_lanes: false` — the persistent lane pool's
//! dispatch path is allocation-free too once the pool exists, but its
//! lazy construction plus worker wakeups inside the measured window
//! would make the count scheduling-dependent, so the lock pins the
//! serial path), a *static* tier stack configured (`degree`-pinned hbm +
//! dram tiers — the `CacheFetch` walk fills the pinned sets during
//! warm-up and then runs allocation-free; LRU tiers are excluded
//! because their recency list is tree-backed), memo off (recording
//! copies tapes by design). This file is its own test binary with a
//! single `#[test]`, so no concurrent test thread can contribute
//! allocation events to the measured window.

use hopgnn::config::RunConfig;
use hopgnn::coordinator::{EpochDriver, Op, ProgramBuilder, SimEnv};
use hopgnn::featstore::tier::TierSpec;
use hopgnn::graph::datasets::tiny_test_dataset;
use hopgnn::sampler::{sample_batch_into, SampleScratch};
use hopgnn::serve::{LaneOut, ServeLane, ServeOpts, ServeSchedule, WorkloadSpec};
use hopgnn::util::alloc::{allocation_count, CountingAlloc};
use hopgnn::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_iterations_allocate_nothing() {
    let d = tiny_test_dataset(77);
    let cfg = RunConfig {
        num_servers: 4,
        layers: 2,
        fanout: 4,
        vmax: 32,
        parallel_lanes: false,
        // static degree hierarchy: pinned sets fill on first touch and
        // never churn, so the tier walk stays allocation-free once warm
        tiers: Some(
            TierSpec::parse("hbm:4k:degree+dram:16k:degree+remote")
                .expect("static tier spec parses"),
        ),
        ..Default::default()
    };
    let n = cfg.num_servers;
    let env = SimEnv::new(&d, cfg);
    let scfg = env.cfg.sample_config();

    // fixed per-server root groups (the schedule part of an iteration
    // is allocated per epoch by the strategies, not per iteration)
    let groups: Vec<Vec<u32>> = (0..n)
        .map(|s| {
            d.train_vertices
                .iter()
                .copied()
                .skip(s * 16)
                .take(16)
                .collect()
        })
        .collect();

    let mut driver = EpochDriver::new(&env);
    let mut scratch = SampleScratch::new();
    let mut b = ProgramBuilder::new(n);

    let mut run_iteration =
        |b: &mut ProgramBuilder,
         driver: &mut EpochDriver,
         scratch: &mut SampleScratch| {
            // identical draws every iteration: the steady state the
            // stamped scratch containers warm up to
            let mut rng = Rng::new(7);
            for (s, roots) in groups.iter().enumerate() {
                // plain gather path (FeatureStore::plan_into)
                let mut verts = b.vbuf();
                let stats = sample_batch_into(
                    &d.graph,
                    roots,
                    &scfg,
                    &mut rng,
                    scratch,
                    &mut verts,
                );
                b.op(s, Op::Sample {
                    vertices: stats.vertices,
                });
                b.op(s, Op::Gather {
                    vertices: verts,
                    overlap: true,
                });
                // merged pre-gather path (PregatherPlan::build_into)
                let mut steps = b.sbuf();
                let mut step = b.vbuf();
                let pre = sample_batch_into(
                    &d.graph,
                    roots,
                    &scfg,
                    &mut rng,
                    scratch,
                    &mut step,
                );
                steps.push(step);
                b.op(s, Op::GatherMerged {
                    steps,
                    overlap: true,
                });
                // tiered fetch path (TierStack::resolve_into walking
                // the static hbm+dram hierarchy)
                let mut csteps = b.sbuf();
                let mut cstep = b.vbuf();
                let tier = sample_batch_into(
                    &d.graph,
                    roots,
                    &scfg,
                    &mut rng,
                    scratch,
                    &mut cstep,
                );
                csteps.push(cstep);
                b.op(s, Op::gather_merged(true, csteps, true));
                b.op(s, Op::Compute {
                    v: stats.vertices + pre.vertices + tier.vertices,
                    e: stats.edges + pre.edges + tier.edges,
                });
            }
            b.barrier();
            b.allreduce();
            let program = b.take();
            driver.exec(&program);
            b.recycle(program);
        };

    // warm-up: fill the stamped containers, pool buffers, and lane
    // vectors to their steady-state capacities
    for _ in 0..3 {
        run_iteration(&mut b, &mut driver, &mut scratch);
    }

    let before = allocation_count();
    for _ in 0..5 {
        run_iteration(&mut b, &mut driver, &mut scratch);
    }
    let after = allocation_count();

    assert_eq!(
        after - before,
        0,
        "steady-state iterations must not allocate \
         ({} events across 5 iterations)",
        after - before
    );

    // the session still closes with coherent accounting
    let m = driver.finish();
    assert!(m.epoch_time > 0.0);
    assert!(m.total_bytes() > 0);

    // --- the serving request loop shares the envelope: a warmed
    // (ServeLane, LaneOut) pair replays a schedule with zero heap
    // allocations. Same static degree hierarchy as above (LRU tiers
    // are excluded for the same tree-backed-recency reason); the lane
    // RNG is re-derived per run, so every replay touches the same
    // sampled keys and the stamped scratch stays at steady capacity.
    let wl = WorkloadSpec::parse("poisson:rate=400,dur=0.2,seed=19")
        .expect("workload spec parses");
    let schedule = ServeSchedule::generate(&env, &wl);
    let opts = ServeOpts::default();
    let mut lane = ServeLane::new(&env, 0, &opts);
    let mut out = LaneOut::new(n, schedule.per_server[0].len());
    for _ in 0..3 {
        lane.run(&schedule, &mut out);
    }
    let before = allocation_count();
    for _ in 0..5 {
        lane.run(&schedule, &mut out);
    }
    let after = allocation_count();
    assert_eq!(
        after - before,
        0,
        "steady-state serve-lane replays must not allocate \
         ({} events across 5 replays)",
        after - before
    );
    assert!(!out.completions.is_empty(), "lane 0 served its share");
    assert_eq!(out.dropped, 0, "an unloaded lane drops nothing");
}
