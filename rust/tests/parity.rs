//! Byte-accounting parity locks for the EpochDriver refactor.
//!
//! The coordinator strategies were rewritten from eager per-strategy
//! epoch loops into op-stream builders executed by the shared
//! `EpochDriver`. These tests pin the properties that refactor must
//! preserve, for every `StrategySpec` at a fixed seed:
//!
//! * with `overlap` off, per-`TransferKind` byte totals are
//!   bit-identical across parallel vs sequential lane execution and
//!   across repeat runs (the driver path is exact, not approximate);
//! * enabling `overlap` never changes a single byte — it only re-times
//!   exposure — and never makes an epoch slower;
//! * the phase-time decomposition stays internally consistent.
//!
//! Parity with the deleted eager loops themselves was established by an
//! op-for-op trace during the refactor (every `stats.record` call maps
//! to exactly one op with the same src/dst/kind/bytes); the qualitative
//! byte relations the eager loops satisfied stay pinned by
//! `tests/strategies.rs`. This suite locks the driver path from here
//! forward — any accounting drift shows up as a cross-mode or
//! cross-run mismatch.

use hopgnn::cluster::network::NUM_KINDS;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec, ALL_LEGACY_SPECS};
use hopgnn::graph::datasets::{load_spec, Dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use std::sync::OnceLock;

fn dataset() -> &'static Dataset {
    static D: OnceLock<Dataset> = OnceLock::new();
    D.get_or_init(|| {
        load_spec(&DatasetSpec {
            name: "parity",
            num_vertices: 8_000,
            num_edges: 56_000,
            feat_dim: 64,
            classes: 8,
            num_communities: 40,
            train_fraction: 0.4,
            seed: 4242,
        })
    })
}

fn cfg(overlap: bool, parallel: bool) -> RunConfig {
    RunConfig {
        batch_size: 128,
        num_servers: 4,
        // exactly 2 epochs: the merge controller's first time-dependent
        // branch (merge vs revert on epoch_time) only affects epoch 3+,
        // so byte totals stay schedule-independent across overlap modes
        // and the cross-mode equality asserts below are sound. Raising
        // this would let overlap legitimately change HopGnn/RD merge
        // trajectories (and therefore bytes).
        epochs: 2,
        max_iterations: Some(3),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        overlap,
        parallel_lanes: parallel,
        ..Default::default()
    }
}

fn assert_bytes_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    for k in 0..NUM_KINDS {
        assert_eq!(
            a.bytes_by_kind[k], b.bytes_by_kind[k],
            "{what}: byte totals diverged for kind index {k}"
        );
    }
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
}

#[test]
fn parallel_lanes_match_sequential_for_every_strategy() {
    let d = dataset();
    for kind in ALL_LEGACY_SPECS {
        let seq = run_strategy(d, &cfg(false, false), kind);
        let par = run_strategy(d, &cfg(false, true), kind);
        assert_bytes_identical(&seq, &par, &kind.name());
        assert_eq!(
            seq.epoch_time.to_bits(),
            par.epoch_time.to_bits(),
            "{}: epoch time must be bit-identical across lane modes \
             ({} vs {})",
            kind.name(),
            seq.epoch_time,
            par.epoch_time
        );
        assert_eq!(
            seq.gpu_busy_fraction.to_bits(),
            par.gpu_busy_fraction.to_bits(),
            "{}: busy fraction diverged",
            kind.name()
        );
    }
}

#[test]
fn repeat_runs_are_deterministic_with_parallel_lanes() {
    let d = dataset();
    for kind in ALL_LEGACY_SPECS {
        let a = run_strategy(d, &cfg(false, true), kind);
        let b = run_strategy(d, &cfg(false, true), kind);
        assert_bytes_identical(&a, &b, &kind.name());
        assert_eq!(a.epoch_time.to_bits(), b.epoch_time.to_bits(),
                   "{}: nondeterministic epoch time", kind.name());
    }
}

#[test]
fn overlap_moves_no_extra_bytes_and_never_slows() {
    let d = dataset();
    for kind in ALL_LEGACY_SPECS {
        let serial = run_strategy(d, &cfg(false, true), kind);
        let over = run_strategy(d, &cfg(true, true), kind);
        assert_bytes_identical(&serial, &over, &kind.name());
        assert!(
            over.epoch_time <= serial.epoch_time * (1.0 + 1e-12),
            "{}: overlap slowed the epoch ({} > {})",
            kind.name(),
            over.epoch_time,
            serial.epoch_time
        );
        // hidden time is bounded by total gather work
        assert!(
            over.time_overlap_hidden
                <= over.time_gather + over.time_migrate + 1e-12,
            "{}: hidden {} exceeds transfer work",
            kind.name(),
            over.time_overlap_hidden
        );
    }
}

#[test]
fn communication_bound_strategies_gain_from_overlap() {
    let d = dataset();
    for kind in [StrategySpec::dgl(), StrategySpec::hopgnn_mg_pg()] {
        let serial = run_strategy(d, &cfg(false, true), kind);
        let over = run_strategy(d, &cfg(true, true), kind);
        assert!(
            over.time_overlap_hidden > 0.0,
            "{}: expected some transfer time hidden",
            kind.name()
        );
        assert!(
            over.epoch_time < serial.epoch_time,
            "{}: overlap should help a gather-bound strategy \
             ({} !< {})",
            kind.name(),
            over.epoch_time,
            serial.epoch_time
        );
    }
}

#[test]
fn phase_times_remain_consistent() {
    let d = dataset();
    for kind in ALL_LEGACY_SPECS {
        let m = run_strategy(d, &cfg(false, true), kind);
        assert!(m.epoch_time.is_finite() && m.epoch_time > 0.0,
                "{}: bad epoch time", kind.name());
        let phases = m.time_sample + m.time_gather + m.time_compute
            + m.time_migrate + m.time_sync;
        assert!(phases > 0.0, "{}: no phase time", kind.name());
        assert_eq!(m.time_overlap_hidden, 0.0,
                   "{}: hidden time without overlap", kind.name());
        assert!((0.0..=1.0).contains(&m.miss_rate()), "{}", kind.name());
    }
}
