//! Real-PJRT integration: load the AOT artifacts, run actual train steps
//! from Rust, and verify the numerics (init loss ≈ ln C for a balanced
//! random classifier, loss decreases under Adam, determinism, accuracy
//! learnable above chance). Requires `make artifacts` to have run and
//! the `pjrt` feature (the default build's engine is a stub).
#![cfg(feature = "pjrt")]

use hopgnn::graph::datasets::{load_spec, DatasetSpec};
use hopgnn::partition::{partition, PartitionAlgo};
use hopgnn::runtime::{BatchBuffers, Engine, Manifest, ParamSet};
use hopgnn::sampler::{sample_micrograph, SampleConfig, SamplerKind};
use hopgnn::train::{OrderPolicy, Trainer};
use hopgnn::util::rng::Rng;

fn manifest() -> Manifest {
    Manifest::load_default().expect("run `make artifacts` before cargo test")
}

/// A dataset matching the gcn_l3_h128_f128 artifact (feat_dim 128, 10
/// classes) but small enough for fast tests.
fn mini_dataset(seed: u64) -> hopgnn::graph::datasets::Dataset {
    load_spec(&DatasetSpec {
        name: "mini-f128",
        num_vertices: 2_000,
        num_edges: 14_000,
        feat_dim: 128,
        classes: 10,
        num_communities: 25,
        train_fraction: 0.4,
        seed,
    })
}

#[test]
fn engine_loads_and_initial_loss_is_ln_c() {
    let m = manifest();
    let spec = m.find("gcn", 128, 128).expect("gcn artifact");
    let mut engine = Engine::load(spec).unwrap();
    let d = mini_dataset(1);
    let params = ParamSet::init(spec, 7);

    let cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut rng = Rng::new(3);
    let mgs: Vec<_> = (0..spec.batch)
        .map(|i| {
            sample_micrograph(&d.graph, (i * 37) as u32, &cfg, &mut rng)
        })
        .collect();
    let mut buf = BatchBuffers::for_artifact(spec);
    assert_eq!(buf.pack(&mgs, &d), spec.batch);

    let out = engine.train_step(&params, &buf).unwrap();
    // untrained 10-class classifier: loss should be near ln(10) = 2.30 up
    // to the scale of the (unnormalized, class-separated) input features
    assert!(
        (1.0..14.0).contains(&(out.loss as f64)),
        "init loss {} implausible for an untrained classifier",
        out.loss
    );
    assert!(out.correct >= 0 && out.correct as usize <= spec.batch);
    assert_eq!(out.grads.len(), spec.params.len());
    // gradients are finite and not all zero
    let gsum: f64 = out
        .grads
        .iter()
        .flat_map(|g| g.iter())
        .map(|&x| (x as f64).abs())
        .sum();
    assert!(gsum.is_finite() && gsum > 0.0, "gradient sum {gsum}");
}

#[test]
fn train_step_is_deterministic() {
    let m = manifest();
    let spec = m.find("gcn", 128, 128).unwrap();
    let mut engine = Engine::load(spec).unwrap();
    let d = mini_dataset(2);
    let params = ParamSet::init(spec, 11);
    let cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut rng = Rng::new(5);
    let mgs: Vec<_> = (0..spec.batch)
        .map(|i| sample_micrograph(&d.graph, (i * 17) as u32, &cfg, &mut rng))
        .collect();
    let mut buf = BatchBuffers::for_artifact(spec);
    buf.pack(&mgs, &d);
    let a = engine.train_step(&params, &buf).unwrap();
    let b = engine.train_step(&params, &buf).unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(a.grads[0], b.grads[0]);
}

#[test]
fn loss_decreases_and_beats_chance() {
    let m = manifest();
    let spec = m.find("gcn", 128, 128).unwrap();
    let engine = Engine::load(spec).unwrap();
    let d = mini_dataset(3);
    let cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut trainer = Trainer::new(engine, cfg, 3e-3, 13);
    let first = trainer
        .train_epoch(&d, None, OrderPolicy::Global, 64)
        .unwrap();
    let mut last = first.mean_loss;
    for _ in 0..2 {
        last = trainer
            .train_epoch(&d, None, OrderPolicy::Global, 64)
            .unwrap()
            .mean_loss;
    }
    assert!(
        last < first.mean_loss * 0.8,
        "loss {} -> {last} did not drop",
        first.mean_loss
    );
    let acc = trainer.evaluate(&d, &d.val_vertices).unwrap();
    assert!(acc > 0.3, "val accuracy {acc} not above chance (0.1)");
}

#[test]
fn lo_policy_trains_with_partition() {
    let m = manifest();
    let spec = m.find("gcn", 128, 128).unwrap();
    let engine = Engine::load(spec).unwrap();
    let d = mini_dataset(4);
    let p = partition(&d.graph, 4, PartitionAlgo::MetisLike, 9);
    let cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut trainer = Trainer::new(engine, cfg, 3e-3, 17);
    let stats = trainer
        .train_epoch(&d, Some(&p), OrderPolicy::LocalityOpt, 64)
        .unwrap();
    assert!(stats.steps > 0);
    assert!(stats.mean_loss.is_finite());
}

#[test]
fn deep_artifacts_execute() {
    let m = manifest();
    for (model, hidden) in [("deepgcn", 64), ("film", 64)] {
        let spec = m.find(model, hidden, 128).expect(model);
        let mut engine = Engine::load(spec).unwrap();
        let d = mini_dataset(5);
        let params = ParamSet::init(spec, 23);
        let cfg = SampleConfig {
            layers: spec.layers,
            fanout: 2,
            vmax: spec.vmax,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(29);
        let mgs: Vec<_> = (0..spec.batch)
            .map(|i| {
                sample_micrograph(&d.graph, (i * 13) as u32, &cfg, &mut rng)
            })
            .collect();
        let mut buf = BatchBuffers::for_artifact(spec);
        buf.pack(&mgs, &d);
        let out = engine.train_step(&params, &buf).unwrap();
        assert!(
            out.loss.is_finite() && out.loss > 0.0,
            "{model} loss {}",
            out.loss
        );
    }
}
