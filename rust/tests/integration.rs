//! System-level integration: determinism, byte-conservation oracles,
//! config plumbing, pipeline composition (generator → partitioner →
//! sampler → feature store → metrics).

use hopgnn::cluster::{
    Clocks, CostModel, Fabric, NetStats, NetworkModel, TransferKind,
};
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, SimEnv, StrategySpec};
use hopgnn::featstore::FeatureStore;
use hopgnn::graph::datasets::{load_spec, tiny_test_dataset, DatasetSpec};
use hopgnn::metrics::EpochMetrics;
use hopgnn::partition::{partition, PartitionAlgo};
use hopgnn::sampler::{sample_micrograph, SampleConfig, SamplerKind};
use hopgnn::util::prop;
use hopgnn::util::rng::Rng;

#[test]
fn whole_sim_is_deterministic_across_processes_worth_of_state() {
    // same config, fresh state -> byte-identical metrics
    let d = tiny_test_dataset(100);
    let cfg = RunConfig {
        batch_size: 40,
        num_servers: 4,
        max_iterations: Some(3),
        epochs: 2,
        ..Default::default()
    };
    let runs: Vec<EpochMetrics> = (0..2)
        .map(|_| run_strategy(&d, &cfg, StrategySpec::hopgnn()))
        .collect();
    assert_eq!(runs[0].total_bytes(), runs[1].total_bytes());
    assert_eq!(runs[0].remote_vertices, runs[1].remote_vertices);
    assert!((runs[0].epoch_time - runs[1].epoch_time).abs() < 1e-12);
}

#[test]
fn brute_force_byte_oracle_model_centric() {
    // One hand-checkable iteration: bytes recorded == sum over remote
    // vertices of feature size, computed by an independent oracle.
    let d = tiny_test_dataset(101);
    let p = partition(&d.graph, 2, PartitionAlgo::Hash, 5);
    let store = FeatureStore::new(&d, &p);
    let cfg = SampleConfig {
        layers: 2,
        fanout: 3,
        vmax: 64,
        kind: SamplerKind::NodeWise,
    };
    let mut rng = Rng::new(9);
    let mgs: Vec<_> = (0..10)
        .map(|i| sample_micrograph(&d.graph, i * 7, &cfg, &mut rng))
        .collect();
    let sub = hopgnn::sampler::Subgraph::union_of(&mgs);

    // oracle: count unique remote vertices by brute force
    let server = 0usize;
    let mut uniq: Vec<u32> = sub.vertices.clone();
    uniq.sort_unstable();
    uniq.dedup();
    let remote_oracle: u64 = uniq
        .iter()
        .filter(|&&v| p.home(v) as usize != server)
        .count() as u64;

    let fabric = Fabric::uniform(2, NetworkModel::default());
    let cost = CostModel::default();
    let mut clocks = Clocks::new(2);
    let mut stats = NetStats::new(2);
    let mut m = EpochMetrics::default();
    let plan = store.plan(server, sub.vertices.iter().copied());
    store.execute_sim(&plan, &fabric, &cost, &mut clocks, &mut stats, &mut m);

    assert_eq!(m.remote_vertices, remote_oracle);
    assert_eq!(
        stats.bytes(TransferKind::Feature),
        remote_oracle * d.feature_bytes()
    );
    stats.validate().unwrap();
}

#[test]
fn config_file_drives_simulation() {
    let dir = std::env::temp_dir().join("hopgnn-int-cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.cfg");
    std::fs::write(
        &path,
        "model = gat\nservers = 2\nbatch_size = 32\nmax_iterations = 2\n",
    )
    .unwrap();
    let cfg = RunConfig::from_kv_file(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.num_servers, 2);
    let d = tiny_test_dataset(102);
    let m = run_strategy(&d, &cfg, StrategySpec::dgl());
    assert!(m.epoch_time > 0.0);
    assert_eq!(m.iterations, 2);
}

#[test]
fn prop_epoch_bytes_conserved_across_strategies() {
    // For any strategy and seed: per-kind byte totals equal per-link
    // totals (NetStats::validate runs inside each strategy), and metrics
    // are internally consistent.
    let d = load_spec(&DatasetSpec {
        name: "prop-int",
        num_vertices: 1_500,
        num_edges: 9_000,
        feat_dim: 24,
        classes: 4,
        num_communities: 12,
        train_fraction: 0.4,
        seed: 500,
    });
    prop::check(
        "strategy-consistency",
        10,
        |r| (r.below(5), r.next_u64()),
        |&(which, seed)| {
            let kind = [
                StrategySpec::dgl(),
                StrategySpec::p3(),
                StrategySpec::naive(),
                StrategySpec::hopgnn(),
                StrategySpec::locality_opt(),
            ][which];
            let cfg = RunConfig {
                batch_size: 64,
                num_servers: 4,
                max_iterations: Some(2),
                epochs: 1,
                seed,
                ..Default::default()
            };
            let m = run_strategy(&d, &cfg, kind);
            if !m.epoch_time.is_finite() || m.epoch_time <= 0.0 {
                return Err(format!("{kind:?}: bad epoch time"));
            }
            let phases = m.time_sample
                + m.time_gather
                + m.time_compute
                + m.time_migrate
                + m.time_sync;
            if phases <= 0.0 {
                return Err(format!("{kind:?}: no phase time recorded"));
            }
            if m.miss_rate() < 0.0 || m.miss_rate() > 1.0 {
                return Err(format!("{kind:?}: bad miss rate"));
            }
            Ok(())
        },
    );
}

#[test]
fn simenv_respects_feature_override() {
    let d = tiny_test_dataset(103);
    let mut cfg = RunConfig {
        batch_size: 40,
        num_servers: 2,
        max_iterations: Some(2),
        epochs: 1,
        ..Default::default()
    };
    let base = run_strategy(&d, &cfg, StrategySpec::dgl());
    cfg.feat_dim_override = Some(d.feat_dim * 8);
    let wide = run_strategy(&d, &cfg, StrategySpec::dgl());
    let ratio = wide.bytes(TransferKind::Feature) as f64
        / base.bytes(TransferKind::Feature) as f64;
    assert!((7.0..9.0).contains(&ratio), "feature bytes ratio {ratio}");
}

#[test]
fn env_iterations_honor_batch_and_cap() {
    let d = tiny_test_dataset(104);
    let cfg = RunConfig {
        batch_size: 24,
        num_servers: 4,
        max_iterations: Some(5),
        ..Default::default()
    };
    let mut env = SimEnv::new(&d, cfg);
    let iters = env.epoch_iterations();
    assert!(iters.len() <= 5);
    for it in &iters {
        let total: usize = it.iter().map(|mb| mb.len()).sum();
        assert_eq!(total, 24);
    }
}
