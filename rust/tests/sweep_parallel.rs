//! Parallel-sweep determinism lock: `--jobs 1` and `--jobs N` must
//! produce bit-identical grids.
//!
//! The sweep engine executes grid cells on a scoped worker pool
//! (`util::pool`) and writes results back in row-major grid order;
//! every cell seeds its own RNG from its config, so worker interleaving
//! can change wall-clock only — never metrics. This suite replays the
//! two real grid shapes (the `hetero` fabric sweep and the `cachesweep`
//! policy × capacity ladder) serially and with 4 workers, and asserts
//! every `EpochMetrics` field equal — integers exactly, floats to the
//! bit (the `tests/spec_parity.rs` idiom). `SweepCell::wall_secs` is
//! the one documented non-deterministic field and is deliberately not
//! compared.

use hopgnn::bench::sweep::{Axis, SweepSpec};
use hopgnn::cluster::FabricSpec;
use hopgnn::config::RunConfig;
use hopgnn::coordinator::StrategySpec;
use hopgnn::featstore::cache::ALL_CACHE_POLICIES;
use hopgnn::metrics::EpochMetrics;

fn tiny_base() -> RunConfig {
    RunConfig {
        dataset: "arxiv-s".into(),
        batch_size: 128,
        epochs: 2,
        max_iterations: Some(2),
        fanout: 5,
        vmax: RunConfig::full_sim_vmax(3, 5),
        seed: 77,
        ..Default::default()
    }
}

/// Every field of `EpochMetrics`, integers equal and floats equal to
/// the bit (mirrors `tests/spec_parity.rs::assert_bit_identical`).
fn assert_bit_identical(a: &EpochMetrics, b: &EpochMetrics, what: &str) {
    assert_eq!(a.bytes_by_kind, b.bytes_by_kind, "{what}: bytes_by_kind");
    assert_eq!(a.remote_requests, b.remote_requests, "{what}");
    assert_eq!(a.remote_vertices, b.remote_vertices, "{what}");
    assert_eq!(a.local_hits, b.local_hits, "{what}");
    assert_eq!(a.cache_hits, b.cache_hits, "{what}");
    assert_eq!(a.cache_misses, b.cache_misses, "{what}");
    assert_eq!(a.cache_hit_bytes, b.cache_hit_bytes, "{what}");
    assert_eq!(a.cache_miss_bytes, b.cache_miss_bytes, "{what}");
    assert_eq!(a.cache_evict_bytes, b.cache_evict_bytes, "{what}");
    assert_eq!(a.iterations, b.iterations, "{what}");
    assert_eq!(a.dropped_roots, b.dropped_roots, "{what}");
    for (x, y, field) in [
        (a.epoch_time, b.epoch_time, "epoch_time"),
        (a.time_sample, b.time_sample, "time_sample"),
        (a.time_gather, b.time_gather, "time_gather"),
        (a.time_compute, b.time_compute, "time_compute"),
        (a.time_migrate, b.time_migrate, "time_migrate"),
        (a.time_sync, b.time_sync, "time_sync"),
        (
            a.time_overlap_hidden,
            b.time_overlap_hidden,
            "time_overlap_hidden",
        ),
        (a.gpu_busy_fraction, b.gpu_busy_fraction, "gpu_busy_fraction"),
        (
            a.time_steps_per_iter,
            b.time_steps_per_iter,
            "time_steps_per_iter",
        ),
    ] {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: {field} diverged ({x} vs {y})"
        );
    }
    assert_eq!(
        a.per_server_busy.len(),
        b.per_server_busy.len(),
        "{what}: per_server_busy length"
    );
    for (s, (x, y)) in
        a.per_server_busy.iter().zip(&b.per_server_busy).enumerate()
    {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: per_server_busy[{s}] diverged"
        );
    }
}

/// Run the same spec at jobs=1 and jobs=4 and lock the grids together.
fn assert_jobs_parity(spec: impl Fn() -> SweepSpec, what: &str) {
    let serial = spec().jobs(1).run().expect("serial sweep");
    let parallel = spec().jobs(4).run().expect("parallel sweep");
    assert_eq!(
        serial.cells.len(),
        parallel.cells.len(),
        "{what}: cell count"
    );
    for (ca, cb) in serial.cells.iter().zip(&parallel.cells) {
        assert_eq!(ca.index, cb.index, "{what}: grid order must be stable");
        assert_eq!(ca.strategy, cb.strategy, "{what}: strategy at {:?}", ca.index);
        assert_eq!(
            ca.cfg.dataset, cb.cfg.dataset,
            "{what}: config at {:?}",
            ca.index
        );
        assert_bit_identical(
            &ca.metrics,
            &cb.metrics,
            &format!("{what} cell {:?} ({})", ca.index, ca.strategy),
        );
    }
}

#[test]
fn hetero_grid_is_jobs_invariant() {
    // the hetero experiment's shape: fabric x strategy x overlap
    let fabrics = [
        FabricSpec::Uniform,
        FabricSpec::HeteroMix,
        FabricSpec::Straggler { server: 0 },
    ];
    let strategies = [
        StrategySpec::dgl(),
        StrategySpec::hopgnn_mg_pg(),
        StrategySpec::hopgnn(),
    ];
    assert_jobs_parity(
        || {
            SweepSpec::new(tiny_base(), StrategySpec::dgl())
                .axis(Axis::fabrics(&fabrics))
                .axis(Axis::strategies(&strategies))
                .axis(Axis::overlap(&[false, true]))
        },
        "hetero grid",
    );
}

#[test]
fn cachesweep_grid_is_jobs_invariant() {
    // the cachesweep shape: policy x strategy x capacity; the cache
    // tier's eviction bookkeeping is the stateful path most likely to
    // betray accidental cross-cell sharing
    let strategies = [StrategySpec::dgl(), StrategySpec::locality_opt()];
    assert_jobs_parity(
        || {
            SweepSpec::new(
                RunConfig {
                    overlap: true,
                    ..tiny_base()
                },
                StrategySpec::dgl(),
            )
            .axis(Axis::cache_policies(&ALL_CACHE_POLICIES))
            .axis(Axis::strategies(&strategies))
            .axis(Axis::cache_capacities_mb(&[0, 2, 8]))
        },
        "cachesweep grid",
    );
}

#[test]
fn nested_lane_parallelism_is_jobs_invariant() {
    // the nested path: one --jobs budget split between cell runners
    // and epoch lanes. The reference grid runs jobs=1 with serial
    // lanes; the nested grids run parallel_lanes on under budgets that
    // land on both sides of the split. With 2 cells, jobs=2 gives each
    // runner a lane share of 1 (lane pools decline — the budget is
    // honored by staying serial inside cells), while jobs=8 gives a
    // share of 4 (real lane pools engage). Either way every
    // EpochMetrics field must be bit-identical to the serial
    // reference: the pool's server-order reduction is deterministic by
    // construction, and this is the lock on that claim.
    let strategies = [StrategySpec::dgl(), StrategySpec::hopgnn()];
    let grid = |parallel_lanes: bool, jobs: usize| {
        SweepSpec::new(
            RunConfig {
                batch_size: 256,
                parallel_lanes,
                ..tiny_base()
            },
            StrategySpec::dgl(),
        )
        .axis(Axis::strategies(&strategies))
        .jobs(jobs)
        .run()
        .expect("nested sweep")
    };
    let reference = grid(false, 1);
    for jobs in [2usize, 8] {
        let nested = grid(true, jobs);
        assert_eq!(
            reference.cells.len(),
            nested.cells.len(),
            "nested jobs={jobs}: cell count"
        );
        for (ca, cb) in reference.cells.iter().zip(&nested.cells) {
            assert_eq!(
                ca.index, cb.index,
                "nested jobs={jobs}: grid order must be stable"
            );
            assert_bit_identical(
                &ca.metrics,
                &cb.metrics,
                &format!(
                    "nested jobs={jobs} cell {:?} ({})",
                    ca.index, ca.strategy
                ),
            );
        }
    }
}

#[test]
fn multi_dataset_grid_is_jobs_invariant() {
    // distinct datasets make racing first-touch loads through the
    // memo's per-key entry locks the interesting case: two workers may
    // load arxiv-s and a synth: dataset concurrently
    assert_jobs_parity(
        || {
            SweepSpec::new(tiny_base(), StrategySpec::dgl())
                .axis(Axis::key(
                    "dataset",
                    &["arxiv-s", "synth:v=2000,e=8000,d=16,c=4,seed=5"],
                ))
                .axis(Axis::strategies(&[
                    StrategySpec::dgl(),
                    StrategySpec::hopgnn(),
                ]))
        },
        "multi-dataset grid",
    );
}
