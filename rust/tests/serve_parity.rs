//! Serving-subsystem parity locks:
//!
//! 1. a seeded workload spec generates the bit-identical arrival
//!    stream on every replay, and a full serve run over it digests
//!    identically;
//! 2. `--jobs 1` vs `--jobs N` lane execution produce bit-identical
//!    serve metrics (the lane split is deterministic by construction —
//!    requests are routed serially, lanes never communicate, results
//!    merge in server order);
//! 3. the streaming P² quantile estimator stays within tolerance of
//!    exact sort-based quantiles on adversarial inputs (bimodal with a
//!    100x mode gap, heavy-tailed Pareto), not just on smooth uniform
//!    streams;
//! 4. overloaded runs fail `validate()` instead of reporting a
//!    truncated latency distribution.

use hopgnn::config::RunConfig;
use hopgnn::coordinator::SimEnv;
use hopgnn::featstore::tier::TierSpec;
use hopgnn::graph::datasets::tiny_test_dataset;
use hopgnn::serve::{serve, ServeOpts, WorkloadSpec};
use hopgnn::util::pool::LaneAllowanceGuard;
use hopgnn::util::rng::Rng;
use hopgnn::util::stats::P2Quantile;

fn serve_cfg(seed: u64, tiers: &str) -> RunConfig {
    RunConfig {
        num_servers: 4,
        layers: 2,
        fanout: 4,
        vmax: 64,
        seed,
        tiers: Some(TierSpec::parse(tiers).expect("tier spec parses")),
        ..Default::default()
    }
}

fn wl(s: &str) -> WorkloadSpec {
    WorkloadSpec::parse(s).expect("workload spec parses")
}

const ALL_KINDS: [&str; 3] = [
    "poisson:rate=600,dur=0.2,seed=13",
    "bursty:rate=300,mult=6,dwell=0.03,dur=0.2,seed=13",
    "diurnal:rate=600,period=0.1,depth=0.8,dur=0.2,seed=13",
];

#[test]
fn seeded_streams_replay_bit_identical() {
    for s in ALL_KINDS {
        let spec = wl(s);
        let a = spec.arrival_times();
        let b = spec.arrival_times();
        assert_eq!(a.len(), b.len(), "{s}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "{s}: stream diverged");
        }
    }
}

#[test]
fn serve_replays_digest_identically_for_every_arrival_kind() {
    let d = tiny_test_dataset(41);
    let env = SimEnv::new(&d, serve_cfg(7, "dram:2m:lru+remote"));
    for s in ALL_KINDS {
        let spec = wl(s);
        let a = serve(&env, &spec, &ServeOpts::default());
        let b = serve(&env, &spec, &ServeOpts::default());
        assert_eq!(
            a.metrics.digest(),
            b.metrics.digest(),
            "{s}: replay must be bit-identical"
        );
        a.metrics.validate().unwrap_or_else(|e| panic!("{s}: {e}"));
    }
}

#[test]
fn lane_parallelism_is_bit_identical_to_serial() {
    let d = tiny_test_dataset(42);
    let env = SimEnv::new(&d, serve_cfg(11, "dram:2m:lru+remote"));
    let spec = wl("bursty:rate=500,mult=5,dwell=0.02,dur=0.3,seed=21");
    let serial = {
        let _g = LaneAllowanceGuard::set(1);
        serve(&env, &spec, &ServeOpts::default())
    };
    let parallel = {
        let _g = LaneAllowanceGuard::set(4);
        serve(&env, &spec, &ServeOpts::default())
    };
    let (a, b) = (&serial.metrics, &parallel.metrics);
    assert_eq!(a.served, b.served);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.sum_total.to_bits(), b.sum_total.to_bits());
    assert_eq!(a.sum_queue.to_bits(), b.sum_queue.to_bits());
    assert_eq!(a.sum_gather.to_bits(), b.sum_gather.to_bits());
    assert_eq!(a.sum_compute.to_bits(), b.sum_compute.to_bits());
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
    assert_eq!(a.p50().to_bits(), b.p50().to_bits());
    assert_eq!(a.p95().to_bits(), b.p95().to_bits());
    assert_eq!(a.p99().to_bits(), b.p99().to_bits());
    assert_eq!(a.transport.total_bytes(), b.transport.total_bytes());
    assert_eq!(
        a.digest(),
        b.digest(),
        "serial vs parallel lanes must agree bit for bit"
    );
}

/// Fraction of `sorted` at or below `x` — the realized rank of an
/// estimate. Rank error is the right yardstick for adversarial
/// distributions: a bimodal gap makes *value* error meaningless (any
/// point in the gap has the same rank), while a correct estimator must
/// still land at the right position in the sample.
fn rank_of(sorted: &[f64], x: f64) -> f64 {
    sorted.partition_point(|&v| v <= x) as f64 / sorted.len() as f64
}

fn check_ranks(label: &str, samples: &[f64], tol: f64) {
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for p in [0.50, 0.95, 0.99] {
        let mut q = P2Quantile::new(p);
        for &x in samples {
            q.observe(x);
        }
        let rank = rank_of(&sorted, q.value());
        assert!(
            (rank - p).abs() <= tol,
            "{label}: p{:.0} estimate {} lands at rank {rank:.4} \
             (tolerance {tol})",
            p * 100.0,
            q.value()
        );
    }
}

#[test]
fn p2_tracks_exact_quantiles_on_adversarial_streams() {
    let n = 20_000usize;
    // bimodal with a 100x gap: 90% around 10, 10% around 1000 — the
    // p95 marker sits right at the mode boundary
    let mut rng = Rng::new(51);
    let bimodal: Vec<f64> = (0..n)
        .map(|_| {
            if rng.f64() < 0.9 {
                10.0 + rng.normal()
            } else {
                1000.0 + 50.0 * rng.normal()
            }
        })
        .collect();
    check_ranks("bimodal", &bimodal, 0.03);
    // heavy tail: Pareto(alpha=1.5) by inverse transform — infinite
    // variance, so the tail markers see occasional enormous jumps
    let mut rng = Rng::new(52);
    let pareto: Vec<f64> = (0..n)
        .map(|_| (1.0 - rng.f64()).max(1e-12).powf(-1.0 / 1.5))
        .collect();
    check_ranks("pareto", &pareto, 0.03);
}

#[test]
fn p2_is_tight_on_uniform_streams() {
    let n = 20_000usize;
    let mut rng = Rng::new(53);
    let uniform: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
    let mut sorted = uniform.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    for p in [0.50, 0.95, 0.99] {
        let mut q = P2Quantile::new(p);
        for &x in &uniform {
            q.observe(x);
        }
        let exact = sorted[((n - 1) as f64 * p).round() as usize];
        assert!(
            (q.value() - exact).abs() < 0.02,
            "uniform p{:.0}: estimate {} vs exact {exact}",
            p * 100.0,
            q.value()
        );
    }
}

#[test]
fn overload_fails_validation_instead_of_truncating() {
    let d = tiny_test_dataset(43);
    let env = SimEnv::new(&d, serve_cfg(17, "remote"));
    let r = serve(
        &env,
        &wl("bursty:rate=30000,mult=10,dwell=0.02,dur=0.1,seed=29"),
        &ServeOpts {
            window: 0.0,
            queue_cap: 1,
            max_batch: 1,
        },
    );
    assert!(r.metrics.dropped > 0, "overload must drop at cap 1");
    assert_eq!(
        r.metrics.served + r.metrics.dropped,
        r.metrics.offered,
        "every request is accounted, served or dropped"
    );
    let e = r.metrics.validate().unwrap_err();
    assert!(e.contains("dropped"), "{e}");
    assert!(e.contains("queue-cap"), "{e}");
}
