//! The distributed training coordinator — the paper's system layer.
//!
//! Six strategies over the same cluster substrate:
//!
//! | strategy            | paradigm        | paper role                  |
//! |---------------------|-----------------|------------------------------|
//! | [`model_centric`]   | features → model| DGL baseline                 |
//! | [`p3`]              | hybrid parallel | P³ (state of the art)        |
//! | [`naive_fc`]        | model → features| §3.2 strawman                |
//! | [`hopgnn`]          | model → features| the contribution (§5)        |
//! | [`locality_opt`]    | no migration    | LO, accuracy-compromising    |
//! | [`neutronstar`]     | full-batch      | §7.7 comparison              |
//!
//! Every strategy consumes a [`SimEnv`] and emits [`EpochMetrics`]; byte
//! counts are exact, times come from the cluster cost models. The real
//! (PJRT) trainer reuses the HopGNN/DGL/LO schedules — see `train/`.

pub mod hopgnn;
pub mod locality_opt;
pub mod merge;
pub mod model_centric;
pub mod naive_fc;
pub mod neutronstar;
pub mod p3;

use crate::cluster::{Clocks, ModelShape, NetStats, TransferKind};
use crate::config::RunConfig;
use crate::featstore::FeatureStore;
use crate::graph::datasets::Dataset;
use crate::metrics::EpochMetrics;
use crate::partition::{partition, Partition, PartitionAlgo};
use crate::sampler::{sample_micrograph, Micrograph};
use crate::util::rng::Rng;

/// Everything a strategy needs to simulate (or drive) one training run.
pub struct SimEnv<'a> {
    pub dataset: &'a Dataset,
    pub partition: Partition,
    pub cfg: RunConfig,
    pub shape: ModelShape,
    /// Feature bytes per vertex (honors `feat_dim_override`).
    pub feat_bytes: u64,
    pub rng: Rng,
}

impl<'a> SimEnv<'a> {
    /// Build an env. P³ requires hash partitioning (its design); other
    /// strategies use `cfg.partition_algo`.
    pub fn new(dataset: &'a Dataset, cfg: RunConfig) -> Self {
        let part = partition(
            &dataset.graph,
            cfg.num_servers,
            cfg.partition_algo,
            cfg.seed ^ 0x9A27,
        );
        Self::with_partition(dataset, cfg, part)
    }

    pub fn with_partition(
        dataset: &'a Dataset,
        cfg: RunConfig,
        part: Partition,
    ) -> Self {
        let feat_dim = cfg.feat_dim_override.unwrap_or(dataset.feat_dim);
        let shape = cfg.model_shape(feat_dim, dataset.classes);
        let rng = Rng::new(cfg.seed);
        Self {
            dataset,
            partition: part,
            cfg,
            shape,
            feat_bytes: (feat_dim * 4) as u64,
            rng,
        }
    }

    pub fn num_servers(&self) -> usize {
        self.cfg.num_servers
    }

    pub fn store(&self) -> FeatureStore<'_> {
        FeatureStore::with_feat_bytes(
            self.dataset,
            &self.partition,
            self.feat_bytes,
        )
    }

    /// Iteration schedule for one epoch: shuffled train roots, chunked
    /// into global batches, each split into one mini-batch per model.
    /// Returns `iterations[iter][model] = roots`.
    pub fn epoch_iterations(&mut self) -> Vec<Vec<Vec<u32>>> {
        let mut roots = self.dataset.train_vertices.clone();
        self.rng.shuffle(&mut roots);
        let n = self.num_servers();
        let bs = self.cfg.batch_size.max(n);
        let mut iters = Vec::new();
        for chunk in roots.chunks(bs) {
            if chunk.len() < n {
                break; // drop ragged tail (DGL's drop_last)
            }
            let per = chunk.len() / n;
            let mut mini = Vec::with_capacity(n);
            for d in 0..n {
                mini.push(chunk[d * per..(d + 1) * per].to_vec());
            }
            iters.push(mini);
            if let Some(cap) = self.cfg.max_iterations {
                if iters.len() >= cap {
                    break;
                }
            }
        }
        iters
    }

    /// Sample micrographs for a root set; charges sampling time on
    /// `server` and returns the micrographs.
    pub fn sample_batch(
        &self,
        roots: &[u32],
        rng: &mut Rng,
        server: usize,
        clocks: &mut Clocks,
        metrics: &mut EpochMetrics,
    ) -> Vec<Micrograph> {
        let scfg = self.cfg.sample_config();
        let mgs: Vec<Micrograph> = roots
            .iter()
            .map(|&r| sample_micrograph(&self.dataset.graph, r, &scfg, rng))
            .collect();
        let sampled: u64 = mgs.iter().map(|m| m.num_vertices() as u64).sum();
        let dt = self.cfg.cost.sample_time(sampled);
        clocks.advance(server, dt);
        metrics.time_sample += dt;
        mgs
    }

    /// Ring allreduce of gradients across all servers (the iteration-end
    /// synchronization every strategy pays). Charges time on every server
    /// and records Gradient bytes on the ring links.
    pub fn allreduce_grads(
        &self,
        clocks: &mut Clocks,
        stats: &mut NetStats,
        metrics: &mut EpochMetrics,
    ) {
        let n = self.num_servers();
        let pb = self.shape.param_bytes();
        if n > 1 {
            // ring: 2(n-1) rounds of pb/n chunks per server
            let chunk = pb / n as u64;
            let mut dt_total = 0.0;
            for round in 0..2 * (n - 1) {
                for s in 0..n {
                    let dst = (s + 1) % n;
                    let t = stats.record(
                        &self.cfg.net,
                        s,
                        dst,
                        chunk,
                        TransferKind::Gradient,
                    );
                    if round == 0 {
                        // time: all rounds proceed in parallel across the
                        // ring; total time = rounds * per-chunk time,
                        // charged uniformly below.
                        dt_total = t;
                    }
                }
            }
            let per_server = dt_total * 2.0 * (n as f64 - 1.0);
            for s in 0..n {
                clocks.advance(s, per_server);
            }
            metrics.time_sync += per_server;
        }
        let t = clocks.barrier();
        let _ = t;
        for s in 0..n {
            clocks.advance(s, self.cfg.cost.t_sync);
        }
        metrics.time_sync += self.cfg.cost.t_sync;
    }

    /// Group roots by their home server: `groups[s] = roots homed at s`.
    pub fn group_by_home(&self, roots: &[u32]) -> Vec<Vec<u32>> {
        let mut groups = vec![Vec::new(); self.num_servers()];
        for &r in roots {
            groups[self.partition.home(r) as usize].push(r);
        }
        groups
    }
}

/// A distributed training strategy: simulates epochs, keeps cross-epoch
/// state (HopGNN's merge controller adapts between epochs).
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics;

    /// Run `epochs` epochs and return per-epoch metrics.
    fn run(&mut self, env: &mut SimEnv, epochs: usize) -> Vec<EpochMetrics> {
        (0..epochs).map(|_| self.run_epoch(env)).collect()
    }
}

/// Strategy selector for CLI / harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    Dgl,
    P3,
    Naive,
    HopGnn,
    HopGnnMgOnly,
    HopGnnMgPg,
    LocalityOpt,
    NeutronStar,
    DglFullBatch,
}

impl StrategyKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "dgl" | "model-centric" => Some(Self::Dgl),
            "p3" => Some(Self::P3),
            "naive" | "naive-fc" => Some(Self::Naive),
            "hopgnn" | "all" => Some(Self::HopGnn),
            "hopgnn-mg" | "+mg" => Some(Self::HopGnnMgOnly),
            "hopgnn-mg-pg" | "+pg" => Some(Self::HopGnnMgPg),
            "lo" | "locality-opt" => Some(Self::LocalityOpt),
            "neutronstar" | "ns" => Some(Self::NeutronStar),
            "dgl-fb" => Some(Self::DglFullBatch),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Dgl => "DGL",
            Self::P3 => "P3",
            Self::Naive => "Naive",
            Self::HopGnn => "HopGNN",
            Self::HopGnnMgOnly => "+MG",
            Self::HopGnnMgPg => "+PG",
            Self::LocalityOpt => "LO",
            Self::NeutronStar => "NeutronStar",
            Self::DglFullBatch => "DGL-FB",
        }
    }

    pub fn build(&self) -> Box<dyn Strategy> {
        match self {
            Self::Dgl => Box::new(model_centric::ModelCentric::new()),
            Self::P3 => Box::new(p3::P3::new()),
            Self::Naive => Box::new(naive_fc::NaiveFc::new()),
            Self::HopGnn => Box::new(hopgnn::HopGnn::full()),
            Self::HopGnnMgOnly => Box::new(hopgnn::HopGnn::mg_only()),
            Self::HopGnnMgPg => Box::new(hopgnn::HopGnn::mg_pg()),
            Self::LocalityOpt => Box::new(locality_opt::LocalityOpt::new()),
            Self::NeutronStar => {
                Box::new(neutronstar::NeutronStar::new(false))
            }
            Self::DglFullBatch => {
                Box::new(neutronstar::NeutronStar::new(true))
            }
        }
    }

    /// P³'s design requires hash partitioning; everything else defaults
    /// to the config's partitioner.
    pub fn preferred_partition(&self) -> Option<PartitionAlgo> {
        match self {
            Self::P3 => Some(PartitionAlgo::Hash),
            _ => None,
        }
    }
}

/// Convenience: run a (strategy, config) pair end to end and return the
/// average epoch (the paper's reporting convention).
pub fn run_strategy(
    dataset: &Dataset,
    cfg: &RunConfig,
    kind: StrategyKind,
) -> EpochMetrics {
    let mut cfg = cfg.clone();
    if let Some(pa) = kind.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let epochs = cfg.epochs;
    let mut env = SimEnv::new(dataset, cfg);
    let mut strat = kind.build();
    let per_epoch = strat.run(&mut env, epochs);
    // skip epoch 0 when the strategy adapts (HopGNN's merging probe)
    // HopGNN adapts its schedule across epochs (merging probe); report
    // the final (frozen) epoch as steady state, like the paper's
    // "remainder of the training" framing in Fig 17.
    let steady = if per_epoch.len() > 2 && kind == StrategyKind::HopGnn {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_test_dataset;

    #[test]
    fn epoch_iterations_partition_roots() {
        let d = tiny_test_dataset(9);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let iters = env.epoch_iterations();
        assert!(!iters.is_empty());
        for it in &iters {
            assert_eq!(it.len(), 4);
            for mb in it {
                assert_eq!(mb.len(), 10);
            }
        }
        // all roots distinct within an iteration
        let flat: Vec<u32> = iters[0].iter().flatten().copied().collect();
        let mut s = flat.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), flat.len());
    }

    #[test]
    fn group_by_home_is_partitioning() {
        let d = tiny_test_dataset(10);
        let cfg = RunConfig {
            num_servers: 4,
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let roots: Vec<u32> = (0..100).collect();
        let groups = env.group_by_home(&roots);
        assert_eq!(groups.iter().map(|g| g.len()).sum::<usize>(), 100);
        for (s, g) in groups.iter().enumerate() {
            for &r in g {
                assert_eq!(env.partition.home(r) as usize, s);
            }
        }
    }

    #[test]
    fn allreduce_charges_everyone() {
        let d = tiny_test_dataset(11);
        let cfg = RunConfig {
            num_servers: 4,
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let mut clocks = Clocks::new(4);
        let mut stats = NetStats::new(4);
        let mut m = EpochMetrics::default();
        env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        assert!(clocks.now(0) > 0.0);
        assert!(stats.bytes(TransferKind::Gradient) > 0);
        assert!(m.time_sync > 0.0);
        stats.validate().unwrap();
    }

    #[test]
    fn strategy_kind_parsing() {
        assert_eq!(StrategyKind::from_str("dgl"), Some(StrategyKind::Dgl));
        assert_eq!(
            StrategyKind::from_str("hopgnn"),
            Some(StrategyKind::HopGnn)
        );
        assert_eq!(StrategyKind::from_str("bogus"), None);
    }
}
