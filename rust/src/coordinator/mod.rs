//! The distributed training coordinator — the paper's system layer.
//!
//! ## Architecture: schedule builders over a shared execution engine
//!
//! Every strategy is a *schedule builder*: it compiles its epoch into a
//! typed per-server op stream ([`ops::Program`] — `Sample`, `Gather`,
//! `Compute`, `Migrate`, `Barrier`, `Allreduce`, ...) and hands it to
//! the shared [`engine::EpochDriver`], which executes the ops against
//! the cluster substrate ([`crate::cluster::Clocks`] /
//! [`crate::cluster::NetStats`] / [`crate::metrics::EpochMetrics`]) in
//! one place. The driver owns the epoch lifecycle, runs independent
//! per-server lanes on worker threads (bit-identical to sequential
//! execution), models gather/compute overlap when
//! [`crate::config::RunConfig::overlap`] is on, and owns one
//! [`crate::featstore::tier::TierStack`] per lane so cache-routed
//! gathers ([`ops::Op::CacheFetch`]) can serve hot remote rows from
//! the configured memory tiers ([`crate::config::RunConfig::tiers`],
//! or the legacy `cache_policy`/`cache_mb` two-tier alias).
//!
//! ## Strategy specs: the ablation space as a product of axes
//!
//! Strategies are selected by a composable [`StrategySpec`] — a value
//! with one field per orthogonal axis (`base`, `micrograph`,
//! `pregather`, `merge`) instead of a closed enum of hand-written
//! crosses. Specs parse from a canonical string grammar
//! (`hopgnn+fa-pg` = fabric-aware merging without pre-gathering) and
//! from every legacy alias (`dgl`, `rd`, `+mg`, …); see [`spec`] for
//! the grammar, the builder API, and the combination rules.
//!
//! | base (`StrategySpec`) | schedule it builds                          | paper role                |
//! |-----------------------|---------------------------------------------|---------------------------|
//! | [`model_centric`] (`dgl`) | sample → gather → compute per server    | DGL baseline              |
//! | [`p3`] (`p3`)         | MP layer-1 + hidden push-pull, then DP      | P³ (state of the art)     |
//! | [`naive_fc`] (`naive`)| model walk dragging intermediate state      | §3.2 strawman             |
//! | [`hopgnn`] (`hopgnn`) | redistribute → pre-gather → T migration steps| the contribution (§5)    |
//! | [`locality_opt`] (`lo`)| redistribute only, no migration            | LO, accuracy-compromising |
//! | [`neutronstar`] (`ns`, `dgl-fb`) | full-batch boundary exchange / hybrid | §7.7 comparison     |
//!
//! The `hopgnn` base composes with the micrograph/pre-gather/merge
//! axes; the paper's ablation points are just named specs
//! ([`StrategySpec::hopgnn_mg`], [`StrategySpec::hopgnn_mg_pg`], …)
//! and new combinations need no new code.
//!
//! ## The cluster fabric
//!
//! Every transfer and every compute op is priced by the env's
//! [`crate::cluster::Fabric`] — per-(src, dst)-link latency/bandwidth
//! matrices plus per-server compute-speed multipliers, built from
//! [`crate::config::RunConfig::fabric`] (`uniform`, `rack:<k>`,
//! `hetero-mix`, `straggler:<s>`). Byte and message counts are exact
//! (recorded per link and per [`crate::cluster::TransferKind`], with
//! conservation validated at the end of every driver session); times
//! come from the fabric's link matrix and the cost model scaled by the
//! server's compute multiplier. [`SimEnv::allreduce_grads`] charges
//! every ring round at its *slowest* link, so heterogeneous fabrics
//! gate gradient sync on the weakest hop. The `uniform` fabric
//! reproduces the historical scalar-model accounting bit for bit —
//! locked by `tests/parity.rs` and `tests/fabric_parity.rs`. HopGNN's
//! merge controller additionally has a fabric-aware mode
//! ([`spec::Merge::FabricAware`], `--strategy hopgnn+fa`) that weights per-worker micrograph
//! counts by observed lane compute times, so merging load-balances
//! under heterogeneous compute (see [`merge`]). The real (PJRT)
//! trainer reuses the HopGNN/DGL/LO schedules — see `train/`.

pub mod engine;
pub mod hopgnn;
pub mod locality_opt;
pub mod merge;
pub mod model_centric;
pub mod naive_fc;
pub mod neutronstar;
pub mod ops;
pub mod p3;
pub mod spec;

pub use engine::{DriverBuilder, EpochDriver, LaneDispatch, SessionState};
pub use ops::{Op, Phase, Program, ProgramBuilder};
pub use spec::{
    Base, Merge, StrategySpec, ALL_BASES, ALL_LEGACY_SPECS, ALL_MERGES,
};

use crate::bench::memo::{self, EpochTape, SampleGroup, SampleKey, TapeEntry};
use crate::cluster::{Clocks, Fabric, ModelShape, NetStats, TransferKind};
use crate::config::RunConfig;
use crate::featstore::cache::{self, CachePolicy};
use crate::featstore::tier::{self, TierStack};
use crate::featstore::FeatureStore;
use crate::graph::datasets::Dataset;
use crate::metrics::EpochMetrics;
use crate::partition::{partition, Partition};
use crate::sampler::{
    sample_batch_into, sample_micrograph, Micrograph, SampleScratch,
};
use crate::util::rng::Rng;
use std::sync::{Arc, OnceLock};

/// Everything a strategy needs to simulate (or drive) one training run.
pub struct SimEnv<'a> {
    pub dataset: &'a Dataset,
    pub partition: Partition,
    pub cfg: RunConfig,
    pub shape: ModelShape,
    /// The materialized cluster topology (from `cfg.fabric` + `cfg.net`):
    /// prices every transfer per link and scales compute per server.
    pub fabric: Fabric,
    /// Feature bytes per vertex (honors `feat_dim_override`).
    pub feat_bytes: u64,
    pub rng: Rng,
    /// Roots discarded by the most recent [`Self::epoch_iterations`]
    /// call (the DGL-style `drop_last` ragged tail plus uneven-split
    /// remainders) — strategies report this in
    /// [`EpochMetrics::dropped_roots`] instead of silently losing it.
    pub dropped_roots: u64,
    /// Global vertex rankings backing the static tier policies, built
    /// once per env (each ranking depends only on config + dataset, so
    /// every epoch's tier stacks pin identical sets). A multi-tier
    /// spec can mix policies, so both rankings are cached separately
    /// and computed only if some tier actually uses them.
    degree_rank: OnceLock<Vec<u32>>,
    profile_rank: OnceLock<Vec<u32>>,
}

impl<'a> SimEnv<'a> {
    /// Build an env. P³ requires hash partitioning (its design); other
    /// strategies use `cfg.partition_algo`.
    pub fn new(dataset: &'a Dataset, cfg: RunConfig) -> Self {
        let part = partition(
            &dataset.graph,
            cfg.num_servers,
            cfg.partition_algo,
            cfg.seed ^ 0x9A27,
        );
        Self::with_partition(dataset, cfg, part)
    }

    pub fn with_partition(
        dataset: &'a Dataset,
        cfg: RunConfig,
        part: Partition,
    ) -> Self {
        let feat_dim = cfg.feat_dim_override.unwrap_or(dataset.feat_dim);
        let shape = cfg.model_shape(feat_dim, dataset.classes);
        let rng = Rng::new(cfg.seed);
        let fabric = cfg.fabric.build(cfg.num_servers, cfg.net);
        Self {
            dataset,
            partition: part,
            cfg,
            shape,
            fabric,
            feat_bytes: (feat_dim * 4) as u64,
            rng,
            dropped_roots: 0,
            degree_rank: OnceLock::new(),
            profile_rank: OnceLock::new(),
        }
    }

    pub fn num_servers(&self) -> usize {
        self.cfg.num_servers
    }

    pub fn store(&self) -> FeatureStore<'_> {
        FeatureStore::with_feat_bytes(
            self.dataset,
            &self.partition,
            self.feat_bytes,
        )
    }

    /// Build one feature tier stack per server lane for an epoch
    /// session (stacks are per-epoch state owned by the `EpochDriver`;
    /// the static pin rankings are computed once per env and shared).
    /// The spec comes from [`RunConfig::effective_tiers`], so `--tiers`
    /// and the legacy `--cache`/`--cache-mb` aliases take one path.
    pub fn build_tiers(&self) -> Vec<TierStack> {
        let spec = self.cfg.effective_tiers();
        let degree = spec
            .uses_policy(CachePolicy::Degree)
            .then(|| self.degree_rank().as_slice());
        let profile = spec
            .uses_policy(CachePolicy::Precomputed)
            .then(|| self.profile_rank().as_slice());
        tier::build_stacks(
            &spec,
            self.feat_bytes,
            &self.partition,
            degree,
            profile,
        )
    }

    fn degree_rank(&self) -> &Vec<u32> {
        self.degree_rank
            .get_or_init(|| cache::rank_by_degree(&self.dataset.graph))
    }

    fn profile_rank(&self) -> &Vec<u32> {
        self.profile_rank.get_or_init(|| {
            cache::rank_by_profile(&self.sampler_profile(), &self.dataset.graph)
        })
    }

    /// The RapidGNN-style profiling pass: replay one epoch's worth of
    /// the deterministic sampling schedule (own RNG stream, so the
    /// training epochs are untouched) and count how often each vertex
    /// is requested. The counts rank the `Precomputed` pin sets.
    fn sampler_profile(&self) -> Vec<u32> {
        let mut counts = vec![0u32; self.dataset.graph.num_vertices()];
        let mut rng = Rng::new(self.cfg.seed ^ 0xCAC4E);
        let mut roots = self.dataset.train_vertices.clone();
        rng.shuffle(&mut roots);
        let bs = self.cfg.batch_size.max(self.num_servers());
        // profile one epoch's worth of roots with 2x slack: the real
        // epochs draw different shuffles, so the pin set should cover
        // the hot neighborhood structure, not one specific root draw
        let budget = self
            .cfg
            .max_iterations
            .map(|it| 2 * it * bs)
            .unwrap_or(roots.len())
            .min(roots.len());
        let scfg = self.cfg.sample_config();
        for &r in &roots[..budget] {
            let mg = sample_micrograph(&self.dataset.graph, r, &scfg, &mut rng);
            for &v in &mg.vertices {
                counts[v as usize] += 1;
            }
        }
        counts
    }

    /// Iteration schedule for one epoch: shuffled train roots, chunked
    /// into global batches, each split into one mini-batch per model.
    /// Returns `iterations[iter][model] = roots`; roots the schedule
    /// discards (the DGL `drop_last` ragged tail and uneven-split
    /// remainders — *not* iterations cut by the `max_iterations` sim
    /// budget) are counted in [`Self::dropped_roots`].
    pub fn epoch_iterations(&mut self) -> Vec<Vec<Vec<u32>>> {
        let mut roots = self.dataset.train_vertices.clone();
        self.rng.shuffle(&mut roots);
        let n = self.num_servers();
        let bs = self.cfg.batch_size.max(n);
        let mut iters = Vec::new();
        self.dropped_roots = 0;
        for chunk in roots.chunks(bs) {
            if chunk.len() < n {
                // drop ragged tail (DGL's drop_last)
                self.dropped_roots += chunk.len() as u64;
                break;
            }
            let per = chunk.len() / n;
            self.dropped_roots += (chunk.len() - per * n) as u64;
            let mut mini = Vec::with_capacity(n);
            for d in 0..n {
                mini.push(chunk[d * per..(d + 1) * per].to_vec());
            }
            iters.push(mini);
            if let Some(cap) = self.cfg.max_iterations {
                if iters.len() >= cap {
                    break;
                }
            }
        }
        iters
    }

    /// Sample micrographs for a root set. Pure with respect to the
    /// simulation: time is charged by the [`Op::Sample`] op the builder
    /// emits alongside (the driver owns all clocks).
    pub fn sample_micrographs(
        &self,
        roots: &[u32],
        rng: &mut Rng,
    ) -> Vec<Micrograph> {
        let scfg = self.cfg.sample_config();
        roots
            .iter()
            .map(|&r| sample_micrograph(&self.dataset.graph, r, &scfg, rng))
            .collect()
    }

    /// Ring allreduce of gradients across all servers (the iteration-end
    /// synchronization every strategy pays). Charges time on every server
    /// and records Gradient bytes on the ring links.
    pub fn allreduce_grads(
        &self,
        clocks: &mut Clocks,
        stats: &mut NetStats,
        metrics: &mut EpochMetrics,
    ) {
        let n = self.num_servers();
        let pb = self.shape.param_bytes();
        if n > 1 {
            // ring: 2(n-1) rounds of pb/n chunks per server
            let chunk = pb / n as u64;
            let mut dt_round = 0.0f64;
            for round in 0..2 * (n - 1) {
                for s in 0..n {
                    let dst = (s + 1) % n;
                    let t = stats.record(
                        &self.fabric,
                        s,
                        dst,
                        chunk,
                        TransferKind::Gradient,
                    );
                    if round == 0 {
                        // all links of a round proceed in parallel, so
                        // the round costs its *slowest* link — every
                        // round reuses the same ring links, so the
                        // round-0 max is the true per-round gate. On a
                        // uniform fabric all links tie; a straggler or
                        // oversubscribed hop gates the whole ring.
                        // Total time = rounds x per-round time, charged
                        // uniformly below.
                        dt_round = dt_round.max(t);
                    }
                }
            }
            let per_server = dt_round * 2.0 * (n as f64 - 1.0);
            for s in 0..n {
                clocks.advance(s, per_server);
            }
            metrics.time_sync += per_server;
        }
        clocks.barrier();
        for s in 0..n {
            clocks.advance(s, self.cfg.cost.t_sync);
        }
        metrics.time_sync += self.cfg.cost.t_sync;
    }

    /// Group roots by their home server: `groups[s] = roots homed at s`.
    pub fn group_by_home(&self, roots: &[u32]) -> Vec<Vec<u32>> {
        let mut groups = vec![Vec::new(); self.num_servers()];
        for &r in roots {
            groups[self.partition.home(r) as usize].push(r);
        }
        groups
    }
}

/// Record/replay state for one epoch's sampling stream — the strategy
/// side of the cross-cell epoch-sample memo (`bench::memo`).
///
/// All three modes are bit-identical by construction: `Record` is live
/// sampling plus a copy into the tape, and `Replay` returns exactly
/// what an identically-keyed `Record` run produced. In `Replay` the
/// strategy's forked sampling RNG is simply never drawn from — the fork
/// itself still happens, so the parent env stream (which the iteration
/// shuffles consume) is untouched; the forked stream is private to the
/// epoch, so leaving it unconsumed is unobservable.
pub(crate) enum SampleTape {
    /// Sample live, record nothing (memo off or over budget).
    Off,
    /// Sample live and copy each group into a tape to publish.
    Record { entry: TapeEntry, tape: EpochTape },
    /// Serve every group from a previously recorded tape.
    Replay { tape: Arc<EpochTape>, cursor: usize },
}

impl SampleTape {
    /// Resolve this epoch's tape: replay if an identically-keyed cell
    /// already recorded it, record if the memo admits the key,
    /// otherwise sample live.
    pub(crate) fn for_epoch(
        env: &SimEnv,
        salt: u64,
        epoch: u64,
        schedule: u64,
    ) -> Self {
        if !env.cfg.memo_samples {
            return SampleTape::Off;
        }
        let key = SampleKey::for_epoch(env, salt, epoch, schedule);
        match memo::epoch_tape_entry(key) {
            None => SampleTape::Off,
            Some(entry) => match entry.get() {
                Some(tape) => SampleTape::Replay {
                    tape: Arc::clone(tape),
                    cursor: 0,
                },
                None => SampleTape::Record {
                    entry,
                    tape: EpochTape::default(),
                },
            },
        }
    }

    /// Publish a recorded tape (first same-key committer wins; `Off`
    /// and `Replay` are no-ops).
    pub(crate) fn finish(self) {
        if let SampleTape::Record { entry, tape } = self {
            memo::commit_tape(&entry, tape);
        }
    }
}

/// Sample one root group's micrographs — or replay them from the epoch
/// tape. Appends the flattened micrograph vertices (sampling order,
/// duplicates preserved) to `out` and returns the group's summed
/// `(vertices, edges)`; content and order are identical across all
/// three tape modes.
pub(crate) fn sample_group(
    env: &SimEnv,
    roots: &[u32],
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    tape: &mut SampleTape,
    out: &mut Vec<u32>,
) -> (u64, u64) {
    if let SampleTape::Replay { tape, cursor } = tape {
        let g = tape.groups.get(*cursor).unwrap_or_else(|| {
            panic!(
                "epoch tape exhausted at group {} (key collision?)",
                *cursor
            )
        });
        *cursor += 1;
        out.extend_from_slice(&g.verts);
        return (g.verts.len() as u64, g.edges);
    }
    let scfg = env.cfg.sample_config();
    let start = out.len();
    let stats =
        sample_batch_into(&env.dataset.graph, roots, &scfg, rng, scratch, out);
    if let SampleTape::Record { tape, .. } = tape {
        tape.groups.push(SampleGroup {
            verts: out[start..].to_vec(),
            edges: stats.edges,
        });
    }
    (stats.vertices, stats.edges)
}

/// Summed vertex count across micrographs (pre-dedup).
pub fn mg_vertices(mgs: &[Micrograph]) -> u64 {
    mgs.iter().map(|m| m.num_vertices() as u64).sum()
}

/// Summed edge count across micrographs.
pub fn mg_edges(mgs: &[Micrograph]) -> u64 {
    mgs.iter().map(|m| m.edges.len() as u64).sum()
}

/// A distributed training strategy: builds one epoch's op-stream
/// schedule, runs it through the shared [`EpochDriver`], and keeps
/// cross-epoch state (HopGNN's merge controller adapts between epochs).
pub trait Strategy {
    fn name(&self) -> &'static str;
    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics;

    /// Run `epochs` epochs and return per-epoch metrics.
    fn run(&mut self, env: &mut SimEnv, epochs: usize) -> Vec<EpochMetrics> {
        (0..epochs).map(|_| self.run_epoch(env)).collect()
    }
}

/// Convenience: run a (strategy spec, config) pair end to end and
/// return the average epoch (the paper's reporting convention).
pub fn run_strategy(
    dataset: &Dataset,
    cfg: &RunConfig,
    spec: StrategySpec,
) -> EpochMetrics {
    let mut cfg = cfg.clone();
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let epochs = cfg.epochs;
    let mut env = SimEnv::new(dataset, cfg);
    let mut strat = spec.build();
    let per_epoch = strat.run(&mut env, epochs);
    // HopGNN adapts its schedule across epochs (merging probe); report
    // the final (frozen) epoch as steady state, like the paper's
    // "remainder of the training" framing in Fig 17.
    let steady = if per_epoch.len() > 2 && spec.adapts_across_epochs() {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_test_dataset;

    #[test]
    fn epoch_iterations_partition_roots() {
        let d = tiny_test_dataset(9);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let iters = env.epoch_iterations();
        assert!(!iters.is_empty());
        for it in &iters {
            assert_eq!(it.len(), 4);
            for mb in it {
                assert_eq!(mb.len(), 10);
            }
        }
        // all roots distinct within an iteration
        let flat: Vec<u32> = iters[0].iter().flatten().copied().collect();
        let mut s = flat.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), flat.len());
    }

    #[test]
    fn group_by_home_is_partitioning() {
        let d = tiny_test_dataset(10);
        let cfg = RunConfig {
            num_servers: 4,
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let roots: Vec<u32> = (0..100).collect();
        let groups = env.group_by_home(&roots);
        assert_eq!(groups.iter().map(|g| g.len()).sum::<usize>(), 100);
        for (s, g) in groups.iter().enumerate() {
            for &r in g {
                assert_eq!(env.partition.home(r) as usize, s);
            }
        }
    }

    #[test]
    fn allreduce_charges_everyone() {
        let d = tiny_test_dataset(11);
        let cfg = RunConfig {
            num_servers: 4,
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let mut clocks = Clocks::new(4);
        let mut stats = NetStats::new(4);
        let mut m = EpochMetrics::default();
        env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        assert!(clocks.now(0) > 0.0);
        assert!(stats.bytes(TransferKind::Gradient) > 0);
        assert!(m.time_sync > 0.0);
        stats.validate().unwrap();
    }

    #[test]
    fn allreduce_ring_charges_slowest_link_per_round() {
        // uniform network: per-round time equals any link's time; the
        // max-over-links fix must not change the uniform-case total
        let d = tiny_test_dataset(12);
        let cfg = RunConfig {
            num_servers: 4,
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let mut clocks = Clocks::new(4);
        let mut stats = NetStats::new(4);
        let mut m = EpochMetrics::default();
        env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        let pb = env.shape.param_bytes();
        let chunk = pb / 4;
        let per_round = env.cfg.net.transfer_time(chunk);
        let expect = per_round * 6.0 + env.cfg.cost.t_sync; // 2(n-1) rounds
        assert!(
            (clocks.now(0) - expect).abs() < 1e-12,
            "ring time {} != expected {expect}",
            clocks.now(0)
        );
        // ring moves 2(n-1) * n chunks in total
        assert_eq!(stats.bytes(TransferKind::Gradient), chunk * 24);
    }

    #[test]
    fn allreduce_ring_is_gated_by_the_slowest_fabric_link() {
        // straggler fabric: the ring's slow hop gates every round
        use crate::cluster::FabricSpec;
        let d = tiny_test_dataset(13);
        let cfg = RunConfig {
            num_servers: 4,
            fabric: FabricSpec::Straggler { server: 0 },
            ..Default::default()
        };
        let env = SimEnv::new(&d, cfg);
        let mut clocks = Clocks::new(4);
        let mut stats = NetStats::new(4);
        let mut m = EpochMetrics::default();
        env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        let chunk = env.shape.param_bytes() / 4;
        let slowest = (0..4)
            .map(|s| env.fabric.transfer_time(s, (s + 1) % 4, chunk))
            .fold(0.0f64, f64::max);
        let expect = slowest * 6.0 + env.cfg.cost.t_sync;
        assert!(
            (clocks.now(0) - expect).abs() < 1e-12,
            "hetero ring time {} != slowest-link bound {expect}",
            clocks.now(0)
        );
        // and it really is slower than the uniform ring
        let uni = env.cfg.net.transfer_time(chunk) * 6.0
            + env.cfg.cost.t_sync;
        assert!(clocks.now(0) > uni);
        stats.validate().unwrap();
    }

    #[test]
    fn epoch_iterations_count_dropped_tail_roots() {
        let d = tiny_test_dataset(14);
        let total = d.train_vertices.len() as u64;
        // 200 train roots, batch 66: three 66-chunks each lose a 2-root
        // uneven-split remainder, and the 2-root tail is dropped whole
        let cfg = RunConfig {
            batch_size: 66,
            num_servers: 4,
            max_iterations: None,
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let iters = env.epoch_iterations();
        let used: u64 = iters
            .iter()
            .map(|it| it.iter().map(|mb| mb.len() as u64).sum::<u64>())
            .sum();
        assert!(env.dropped_roots > 0, "this schedule must drop roots");
        assert_eq!(
            used + env.dropped_roots,
            total,
            "every train root is either scheduled or counted dropped"
        );
        // capped runs do not count the budget cut as dropped
        let cfg = RunConfig {
            batch_size: 48,
            num_servers: 4,
            max_iterations: Some(1),
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let iters = env.epoch_iterations();
        assert_eq!(iters.len(), 1);
        assert_eq!(env.dropped_roots, 0);
    }

    #[test]
    fn run_strategy_accepts_parsed_specs() {
        let d = tiny_test_dataset(15);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            epochs: 1,
            max_iterations: Some(2),
            ..Default::default()
        };
        let spec: StrategySpec = "hopgnn-merge".parse().unwrap();
        let m = run_strategy(&d, &cfg, spec);
        assert!(m.epoch_time > 0.0);
        assert_eq!(m.iterations, 2);
    }
}
