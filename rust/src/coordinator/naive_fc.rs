//! The naive feature-centric strawman (§3.2, Fig 6-7).
//!
//! The model migrates to wherever missing features live, layer by layer,
//! dragging its parameters *and* all intermediate state (partial
//! aggregations at input width + saved activations for backward) along.
//! With a subgraph scattered over many servers this moves up to 2.59× the
//! bytes of model-centric training (Fig 7) — the motivation for
//! micrographs.
//!
//! Accounting model: for each mini-batch's subgraph, the model visits
//! every server holding any of the subgraph's features (home servers in
//! descending feature-count order, Fig 6's walk), consuming local
//! features at each stop. Carried state:
//!   params + partial aggregation [V_sub × F] + activations so far.

use super::{SimEnv, Strategy};
use crate::cluster::{Clocks, NetStats, TransferKind};
use crate::metrics::EpochMetrics;
use crate::sampler::Subgraph;

pub struct NaiveFc {
    epoch_idx: u64,
}

impl NaiveFc {
    pub fn new() -> Self {
        Self { epoch_idx: 0 }
    }
}

impl Default for NaiveFc {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for NaiveFc {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let mut clocks = Clocks::new(n);
        let mut stats = NetStats::new(n);
        let mut m = EpochMetrics::default();
        let mut rng = env.rng.fork(0x4A1 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        m.iterations = iterations.len() as u64;
        let param_bytes = env.shape.param_bytes();
        let feat_bytes = env.feat_bytes;
        let hid_bytes = (env.shape.hidden * 4) as u64;
        let mut steps_accum = 0f64;

        for minibatches in &iterations {
            for (d, roots) in minibatches.iter().enumerate() {
                let mgs = env.sample_batch(roots, &mut rng, d, &mut clocks,
                                           &mut m);
                let sub = Subgraph::union_of(&mgs);
                let v_sub = sub.vertices.len() as u64;
                // rows with open aggregations = non-leaf vertices (leaves
                // are pure feature sources, consumed where they live)
                let nonleaf_flat: u64 = mgs
                    .iter()
                    .flat_map(|g| g.depth.iter())
                    .filter(|&&dep| (dep as usize) < env.cfg.layers)
                    .count() as u64;
                let summed: u64 =
                    mgs.iter().map(|g| g.num_vertices() as u64).sum();
                let dedup = if summed == 0 {
                    1.0
                } else {
                    v_sub as f64 / summed as f64
                };
                let open_rows = (nonleaf_flat as f64 * dedup) as u64;

                // which servers hold this subgraph's features, and how many
                let mut counts = vec![0u64; n];
                for &v in &sub.vertices {
                    counts[env.partition.home(v) as usize] += 1;
                }
                // visit order: model's own server first, then descending
                let mut order: Vec<usize> =
                    (0..n).filter(|&s| counts[s] > 0).collect();
                order.sort_by_key(|&s| {
                    (if s == d { 0 } else { 1 }, u64::MAX - counts[s])
                });

                // the walk: consume local features at each stop. Carried
                // state = params + partial aggregations of rows whose
                // neighborhoods are not yet fully consumed (shrinks as
                // the walk progresses) + activations kept for backward.
                let mut cur = d;
                let mut consumed = 0u64;
                for (hop, &s) in order.iter().enumerate() {
                    if s != cur {
                        // open-row partial sums shrink as features are
                        // consumed; activations accumulate for backward
                        let visited_frac =
                            consumed as f64 / v_sub.max(1) as f64;
                        let remaining = (open_rows as f64
                            * (1.0 - visited_frac)) as u64;
                        let state = param_bytes
                            + remaining * feat_bytes        // open agg rows
                            + open_rows * hid_bytes;        // saved acts
                        let mut dt = stats.record(
                            &env.cfg.net, cur, s,
                            param_bytes.min(state),
                            TransferKind::ModelParams,
                        );
                        dt += stats.record(
                            &env.cfg.net, cur, s,
                            state.saturating_sub(param_bytes),
                            TransferKind::Intermediate,
                        );
                        clocks.advance(s, dt);
                        m.time_migrate += dt;
                        cur = s;
                        steps_accum += 1.0;
                    }
                    // local feature read: host staging only
                    let dt = env.cfg.cost.stage_time(counts[s] * feat_bytes);
                    clocks.advance(s, dt);
                    m.time_gather += dt;
                    m.local_hits += counts[s];
                    consumed += counts[s];
                    // partial compute proportional to consumed share
                    let frac = counts[s] as f64 / v_sub.max(1) as f64;
                    let e: u64 = mgs.iter().map(|g| g.edges.len() as u64).sum();
                    let dt = env.cfg.cost.train_time(
                        &env.shape,
                        (v_sub as f64 * frac) as u64,
                        (e as f64 * frac) as u64,
                    );
                    clocks.advance_busy(cur, dt);
                    m.time_compute += dt;
                    let _ = hop;
                }
                // return home for the update (bwd completes along the way)
                if cur != d {
                    let state = param_bytes + open_rows * hid_bytes;
                    let mut dt = stats.record(&env.cfg.net, cur, d,
                                              param_bytes,
                                              TransferKind::ModelParams);
                    dt += stats.record(&env.cfg.net, cur, d,
                                       state - param_bytes,
                                       TransferKind::Intermediate);
                    clocks.advance(d, dt);
                    m.time_migrate += dt;
                    steps_accum += 1.0;
                }
            }
            env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        }

        stats.validate().expect("byte accounting");
        m.absorb_net(&stats);
        m.epoch_time = clocks.max();
        m.gpu_busy_fraction = clocks.busy_fraction();
        m.time_steps_per_iter = if m.iterations == 0 {
            0.0
        } else {
            steps_accum / m.iterations as f64
        };
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::model_centric::ModelCentric;
    use crate::graph::datasets::tiny_test_dataset;

    fn cfg(feat_dim: Option<usize>) -> RunConfig {
        RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(4),
            feat_dim_override: feat_dim,
            ..Default::default()
        }
    }

    #[test]
    fn naive_moves_intermediate_state_not_features() {
        let d = tiny_test_dataset(50);
        let m = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(None)));
        assert_eq!(m.bytes(TransferKind::Feature), 0, "no remote features");
        assert!(m.bytes(TransferKind::Intermediate) > 0);
        assert!(m.bytes(TransferKind::ModelParams) > 0);
    }

    #[test]
    fn naive_can_move_more_bytes_than_dgl() {
        // Fig 7: with small features (low-dim) and scattered subgraphs the
        // intermediate state dwarfs what model-centric would have moved.
        let d = tiny_test_dataset(51);
        let dgl = ModelCentric::new()
            .run_epoch(&mut SimEnv::new(&d, cfg(Some(16))));
        let nv = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(Some(16))));
        assert!(
            nv.total_bytes() > dgl.total_bytes(),
            "naive {} !> dgl {}",
            nv.total_bytes(),
            dgl.total_bytes()
        );
    }

    #[test]
    fn multiple_migrations_per_iteration() {
        let d = tiny_test_dataset(52);
        let m = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(None)));
        assert!(
            m.time_steps_per_iter > 2.0,
            "walk length {}",
            m.time_steps_per_iter
        );
    }
}
