//! The naive feature-centric strawman (§3.2, Fig 6-7).
//!
//! The model migrates to wherever missing features live, layer by layer,
//! dragging its parameters *and* all intermediate state (partial
//! aggregations at input width + saved activations for backward) along.
//! With a subgraph scattered over many servers this moves up to 2.59× the
//! bytes of model-centric training (Fig 7) — the motivation for
//! micrographs.
//!
//! Accounting model: for each mini-batch's subgraph, the model visits
//! every server holding any of the subgraph's features (home servers in
//! descending feature-count order, Fig 6's walk), consuming local
//! features at each stop. Carried state:
//!   params + partial aggregation [V_sub × F] + activations so far.
//!
//! The walk is inherently serial — the model cannot compute at stop k+1
//! before its state arrives from stop k — so none of its transfers are
//! overlap-eligible; the op stream simply threads the migrations through
//! the visited servers' lanes.
//!
//! The strawman also sits outside the feature-cache tier
//! (`featstore::cache`): it consumes every feature *where it lives*
//! (no remote feature fetches to cache) and what it ships instead —
//! params plus per-mini-batch intermediate state — is unique to each
//! iteration, so the builder emits no gather ops and `--cache` is a
//! no-op here.

use super::ops::{Op, Phase, ProgramBuilder};
use super::{mg_edges, mg_vertices, EpochDriver, SimEnv, Strategy};
use crate::cluster::TransferKind;
use crate::metrics::EpochMetrics;
use crate::sampler::Subgraph;

pub struct NaiveFc {
    epoch_idx: u64,
}

impl NaiveFc {
    pub fn new() -> Self {
        Self { epoch_idx: 0 }
    }
}

impl Default for NaiveFc {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for NaiveFc {
    fn name(&self) -> &'static str {
        "Naive"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let mut rng = env.rng.fork(0x4A1 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        let param_bytes = env.shape.param_bytes();
        let feat_bytes = env.feat_bytes;
        let hid_bytes = (env.shape.hidden * 4) as u64;
        let mut steps_accum = 0f64;
        let mut driver = EpochDriver::new(env);

        for minibatches in &iterations {
            let mut b = ProgramBuilder::new(n);
            for (d, roots) in minibatches.iter().enumerate() {
                let mgs = env.sample_micrographs(roots, &mut rng);
                b.op(d, Op::Sample {
                    vertices: mg_vertices(&mgs),
                });
                let sub = Subgraph::union_of(&mgs);
                let v_sub = sub.vertices.len() as u64;
                // rows with open aggregations = non-leaf vertices (leaves
                // are pure feature sources, consumed where they live)
                let nonleaf_flat: u64 = mgs
                    .iter()
                    .flat_map(|g| g.depth.iter())
                    .filter(|&&dep| (dep as usize) < env.cfg.layers)
                    .count() as u64;
                let summed = mg_vertices(&mgs);
                let dedup = if summed == 0 {
                    1.0
                } else {
                    v_sub as f64 / summed as f64
                };
                let open_rows = (nonleaf_flat as f64 * dedup) as u64;

                // which servers hold this subgraph's features, and how many
                let mut counts = vec![0u64; n];
                for &v in &sub.vertices {
                    counts[env.partition.home(v) as usize] += 1;
                }
                // visit order: model's own server first, then descending
                let mut order: Vec<usize> =
                    (0..n).filter(|&s| counts[s] > 0).collect();
                order.sort_by_key(|&s| {
                    (if s == d { 0 } else { 1 }, u64::MAX - counts[s])
                });

                // the walk: consume local features at each stop. Carried
                // state = params + partial aggregations of rows whose
                // neighborhoods are not yet fully consumed (shrinks as
                // the walk progresses) + activations kept for backward.
                let mut cur = d;
                let mut consumed = 0u64;
                let e_total = mg_edges(&mgs);
                for &s in &order {
                    if s != cur {
                        // open-row partial sums shrink as features are
                        // consumed; activations accumulate for backward
                        let visited_frac =
                            consumed as f64 / v_sub.max(1) as f64;
                        let remaining = (open_rows as f64
                            * (1.0 - visited_frac)) as u64;
                        let state = param_bytes
                            + remaining * feat_bytes        // open agg rows
                            + open_rows * hid_bytes;        // saved acts
                        b.op(s, Op::Migrate {
                            from: cur,
                            kind: TransferKind::ModelParams,
                            bytes: param_bytes.min(state),
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                        b.op(s, Op::Migrate {
                            from: cur,
                            kind: TransferKind::Intermediate,
                            bytes: state.saturating_sub(param_bytes),
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                        cur = s;
                        steps_accum += 1.0;
                    }
                    // local feature read: host staging only
                    b.op(s, Op::Host {
                        secs: env.cfg.cost.stage_time(counts[s] * feat_bytes),
                        phase: Phase::Gather,
                    });
                    b.op(s, Op::Tally {
                        remote_requests: 0,
                        remote_vertices: 0,
                        local_hits: counts[s],
                    });
                    consumed += counts[s];
                    // partial compute proportional to consumed share
                    let frac = counts[s] as f64 / v_sub.max(1) as f64;
                    b.op(cur, Op::Compute {
                        v: (v_sub as f64 * frac) as u64,
                        e: (e_total as f64 * frac) as u64,
                    });
                }
                // return home for the update (bwd completes along the way)
                if cur != d {
                    let state = param_bytes + open_rows * hid_bytes;
                    b.op(d, Op::Migrate {
                        from: cur,
                        kind: TransferKind::ModelParams,
                        bytes: param_bytes,
                        phase: Phase::Migrate,
                        overlap: false,
                    });
                    b.op(d, Op::Migrate {
                        from: cur,
                        kind: TransferKind::Intermediate,
                        bytes: state - param_bytes,
                        phase: Phase::Migrate,
                        overlap: false,
                    });
                    steps_accum += 1.0;
                }
            }
            b.allreduce();
            driver.exec(&b.finish());
        }

        let mut m = driver.finish();
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = if m.iterations == 0 {
            0.0
        } else {
            steps_accum / m.iterations as f64
        };
        m.dropped_roots = env.dropped_roots;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::model_centric::ModelCentric;
    use crate::graph::datasets::tiny_test_dataset;

    fn cfg(feat_dim: Option<usize>) -> RunConfig {
        RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(4),
            feat_dim_override: feat_dim,
            ..Default::default()
        }
    }

    #[test]
    fn naive_moves_intermediate_state_not_features() {
        let d = tiny_test_dataset(50);
        let m = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(None)));
        assert_eq!(m.bytes(TransferKind::Feature), 0, "no remote features");
        assert!(m.bytes(TransferKind::Intermediate) > 0);
        assert!(m.bytes(TransferKind::ModelParams) > 0);
    }

    #[test]
    fn naive_can_move_more_bytes_than_dgl() {
        // Fig 7: with small features (low-dim) and scattered subgraphs the
        // intermediate state dwarfs what model-centric would have moved.
        let d = tiny_test_dataset(51);
        let dgl = ModelCentric::new()
            .run_epoch(&mut SimEnv::new(&d, cfg(Some(16))));
        let nv = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(Some(16))));
        assert!(
            nv.total_bytes() > dgl.total_bytes(),
            "naive {} !> dgl {}",
            nv.total_bytes(),
            dgl.total_bytes()
        );
    }

    #[test]
    fn multiple_migrations_per_iteration() {
        let d = tiny_test_dataset(52);
        let m = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(None)));
        assert!(
            m.time_steps_per_iter > 2.0,
            "walk length {}",
            m.time_steps_per_iter
        );
    }

    #[test]
    fn serial_walk_ignores_overlap_mode() {
        // NaiveFc emits no overlap-eligible ops: enabling the knob must
        // not change its epoch at all.
        let d = tiny_test_dataset(53);
        let base = NaiveFc::new().run_epoch(&mut SimEnv::new(&d, cfg(None)));
        let over = NaiveFc::new().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                overlap: true,
                ..cfg(None)
            },
        ));
        assert_eq!(base.total_bytes(), over.total_bytes());
        assert_eq!(base.epoch_time.to_bits(), over.epoch_time.to_bits());
        assert_eq!(over.time_overlap_hidden, 0.0);
    }
}
