//! P³ reimplementation (Gandhi & Iyer, OSDI '21) — the paper's strongest
//! baseline, reimplemented from its description exactly as the HopGNN
//! authors did (§7.1: "As P³ is not open-source, we reimplemented it").
//!
//! Design: random hash partitioning of vertices; **intra-layer model
//! parallelism for layer 1** — every server stores a 1/N slice of *every*
//! vertex's feature vector, so layer-1 aggregation+transform runs
//! model-parallel with no raw-feature movement; the resulting hidden
//! activations (width H) are then reduce-scattered to the data-parallel
//! owners, and layers ≥ 2 run data-parallel as usual. Backward mirrors the
//! hidden exchange.
//!
//! The crucial consequence (Fig 11/12): P³'s network traffic scales with
//! `hidden × layer-1 width`, not with the raw feature dimension — great
//! at H=16, poor at H=128, and its layer-1 width grows with layer count
//! (every sampled vertex below the top layer is a layer-1 destination).
//!
//! The op stream has two phases per iteration separated by a barrier:
//! MP (layer-1 compute + hidden push-pull) and DP (upper layers +
//! allreduce). The hidden exchange is overlap-eligible — P³'s design is
//! exactly a pipelining argument, and with the driver's overlap mode on
//! the push-pull hides behind compute.
//!
//! P³ is deliberately outside the feature-cache tier
//! (`featstore::cache`): it never moves raw features (every server
//! holds a 1/N slice of all of them), and its hidden-activation
//! exchange is fresh per step — there is nothing reusable to cache, so
//! the builder emits no gather ops and `--cache` is a no-op here.

use super::ops::{Op, Phase, ProgramBuilder};
use super::{mg_edges, mg_vertices, EpochDriver, SimEnv, Strategy};
use crate::cluster::TransferKind;
use crate::metrics::EpochMetrics;
use crate::sampler::Subgraph;

pub struct P3 {
    epoch_idx: u64,
}

impl P3 {
    pub fn new() -> Self {
        Self { epoch_idx: 0 }
    }
}

impl Default for P3 {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for P3 {
    fn name(&self) -> &'static str {
        "P3"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let mut rng = env.rng.fork(0xb3 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        let hid_bytes = (env.shape.hidden * 4) as u64;
        let feat_dim = env.shape.feat_dim;
        let mut driver = EpochDriver::new(env);

        for minibatches in &iterations {
            let mut b = ProgramBuilder::new(n);
            // every server samples its own mini-batch subgraph
            let mut layer1_dsts: Vec<u64> = Vec::with_capacity(n);
            let mut sub_edges: Vec<u64> = Vec::with_capacity(n);
            let mut sub_verts: Vec<u64> = Vec::with_capacity(n);
            for (server, roots) in minibatches.iter().enumerate() {
                let mgs = env.sample_micrographs(roots, &mut rng);
                b.op(server, Op::Sample {
                    vertices: mg_vertices(&mgs),
                });
                let sub = Subgraph::union_of(&mgs);
                // layer-1 destinations: all vertices that receive an
                // aggregation at the input layer = depth <= layers-1,
                // deduplicated across the mini-batch (P3 computes the
                // merged subgraph once, like DGL)
                let l1_flat: u64 = mgs
                    .iter()
                    .flat_map(|g| g.depth.iter())
                    .filter(|&&d| (d as usize) < env.cfg.layers)
                    .count() as u64;
                let summed = mg_vertices(&mgs);
                let dedup = if summed == 0 {
                    1.0
                } else {
                    sub.vertices.len() as f64 / summed as f64
                };
                layer1_dsts.push((l1_flat as f64 * dedup) as u64);
                sub_edges.push(mg_edges(&mgs));
                sub_verts.push(sub.vertices.len() as u64);
                // P3 keeps feature slices resident: no raw-feature fetch,
                // but the layer-1 input rows still count as local reads
                b.op(server, Op::Tally {
                    remote_requests: 0,
                    remote_vertices: 0,
                    local_hits: sub.vertices.len() as u64,
                });
            }

            // ---- phase 1: model-parallel layer 1 ----
            // each server computes the layer-1 partial for ALL mini-
            // batches over its F/N slice
            let total_l1: u64 = layer1_dsts.iter().sum();
            let total_edges: u64 = sub_edges.iter().sum();
            for server in 0..n {
                // aggregation over slice + transform to H, fwd+bwd (x3)
                let flops = 3.0
                    * (2.0 * total_edges as f64 * (feat_dim / n) as f64
                        + 2.0 * total_l1 as f64 * (feat_dim / n) as f64
                            * env.shape.hidden as f64);
                let secs = flops / env.cfg.cost.flops_per_sec
                    + env.cfg.cost.t_launch * 4.0;
                b.op(server, Op::ComputeSecs { secs });
            }
            // reduce-scatter partial activations to owners: each server
            // receives (N-1) partials for its own layer-1 rows (fwd),
            // and sends the corresponding error terms back (bwd)
            for server in 0..n {
                let rows = layer1_dsts[server];
                let bytes = rows * hid_bytes * (n as u64 - 1);
                // count as one batched request per peer, fwd + bwd
                for peer in 0..n {
                    if peer == server {
                        continue;
                    }
                    let per = bytes / (n as u64 - 1);
                    b.op(server, Op::Migrate {
                        from: peer,
                        kind: TransferKind::Hidden,
                        bytes: per,
                        phase: Phase::Gather,
                        overlap: true,
                    });
                    b.op(peer, Op::Migrate {
                        from: server,
                        kind: TransferKind::Hidden,
                        bytes: per,
                        phase: Phase::Gather,
                        overlap: true,
                    });
                    b.op(server, Op::Tally {
                        remote_requests: 2,
                        remote_vertices: 0,
                        local_hits: 0,
                    });
                }
                // hidden rows moved fwd+bwd
                b.op(server, Op::Tally {
                    remote_requests: 0,
                    remote_vertices: rows * 2,
                    local_hits: 0,
                });
                // CPU-side split/merge of the N-way partial tensors: each
                // of this server's rows is assembled from N partials (fwd)
                // and its gradient re-sliced N ways (bwd)
                b.op(server, Op::Host {
                    secs: env.cfg.cost.mp_row_overhead * (2 * rows) as f64,
                    phase: Phase::Gather,
                });
            }
            // the MP phase pipeline: push-pull rounds synchronize all
            // servers before the data-parallel phase can start
            b.barrier();
            b.sync_all();

            // ---- phase 2: data-parallel layers >= 2 ----
            for server in 0..n {
                let v = sub_verts[server];
                let e = sub_edges[server];
                // all layers minus the (already computed) first
                let upper = env.shape.train_flops(v, e)
                    * ((env.cfg.layers - 1) as f64 / env.cfg.layers as f64);
                let secs = upper / env.cfg.cost.flops_per_sec
                    + env.cfg.cost.launch_overhead(&env.shape);
                b.op(server, Op::ComputeSecs { secs });
            }

            // gradient sync for the data-parallel layers (layer-1 weights
            // are sharded and need no allreduce)
            b.allreduce();
            driver.exec(&b.finish());
        }

        let mut m = driver.finish();
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = 2.0; // MP phase + DP phase
        m.dropped_roots = env.dropped_roots;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::model_centric::ModelCentric;
    use crate::partition::PartitionAlgo;

    fn cfg(hidden: usize, feat: Option<usize>) -> RunConfig {
        RunConfig {
            batch_size: 256,
            num_servers: 4,
            hidden,
            max_iterations: Some(3),
            partition_algo: PartitionAlgo::Hash,
            feat_dim_override: feat,
            ..Default::default()
        }
    }

    #[test]
    fn p3_moves_hidden_not_features() {
        let d = crate::graph::datasets::small_test_dataset(60);
        let m = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, None)));
        assert_eq!(m.bytes(TransferKind::Feature), 0);
        assert!(m.bytes(TransferKind::Hidden) > 0);
    }

    #[test]
    fn p3_beats_dgl_at_small_hidden_large_features() {
        // P3's sweet spot: high-dim features, tiny hidden layer.
        let d = crate::graph::datasets::small_test_dataset(61);
        let p3 = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, Some(600))));
        let dgl = ModelCentric::new()
            .run_epoch(&mut SimEnv::new(&d, cfg(16, Some(600))));
        assert!(
            p3.epoch_time < dgl.epoch_time,
            "p3 {} !< dgl {}",
            p3.epoch_time,
            dgl.epoch_time
        );
    }

    #[test]
    fn p3_traffic_scales_with_hidden_dim() {
        // The sensitivity HopGNN exploits (Fig 11): quadrupling H
        // quadruples P3's hidden-exchange bytes.
        let d = crate::graph::datasets::small_test_dataset(62);
        let lo = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, None)));
        let hi = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(128, None)));
        let ratio = hi.bytes(TransferKind::Hidden) as f64
            / lo.bytes(TransferKind::Hidden) as f64;
        assert!(
            (6.0..10.0).contains(&ratio),
            "hidden bytes should scale ~8x, got {ratio}"
        );
    }

    #[test]
    fn overlap_pipelines_the_push_pull() {
        let d = crate::graph::datasets::small_test_dataset(63);
        let serial = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(64, None)));
        let over = P3::new().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                overlap: true,
                ..cfg(64, None)
            },
        ));
        assert_eq!(serial.total_bytes(), over.total_bytes());
        assert!(over.epoch_time <= serial.epoch_time);
        assert!(over.time_overlap_hidden > 0.0);
    }
}
