//! P³ reimplementation (Gandhi & Iyer, OSDI '21) — the paper's strongest
//! baseline, reimplemented from its description exactly as the HopGNN
//! authors did (§7.1: "As P³ is not open-source, we reimplemented it").
//!
//! Design: random hash partitioning of vertices; **intra-layer model
//! parallelism for layer 1** — every server stores a 1/N slice of *every*
//! vertex's feature vector, so layer-1 aggregation+transform runs
//! model-parallel with no raw-feature movement; the resulting hidden
//! activations (width H) are then reduce-scattered to the data-parallel
//! owners, and layers ≥ 2 run data-parallel as usual. Backward mirrors the
//! hidden exchange.
//!
//! The crucial consequence (Fig 11/12): P³'s network traffic scales with
//! `hidden × layer-1 width`, not with the raw feature dimension — great
//! at H=16, poor at H=128, and its layer-1 width grows with layer count
//! (every sampled vertex below the top layer is a layer-1 destination).

use super::{SimEnv, Strategy};
use crate::cluster::{Clocks, NetStats, TransferKind};
use crate::metrics::EpochMetrics;
use crate::sampler::Subgraph;

pub struct P3 {
    epoch_idx: u64,
}

impl P3 {
    pub fn new() -> Self {
        Self { epoch_idx: 0 }
    }
}

impl Default for P3 {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for P3 {
    fn name(&self) -> &'static str {
        "P3"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let mut clocks = Clocks::new(n);
        let mut stats = NetStats::new(n);
        let mut m = EpochMetrics::default();
        let mut rng = env.rng.fork(0xb3 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = 2.0; // MP phase + DP phase
        let hid_bytes = (env.shape.hidden * 4) as u64;
        let feat_dim = env.shape.feat_dim;

        for minibatches in &iterations {
            // every server samples its own mini-batch subgraph
            let mut layer1_dsts: Vec<u64> = Vec::with_capacity(n);
            let mut sub_edges: Vec<u64> = Vec::with_capacity(n);
            let mut sub_verts: Vec<u64> = Vec::with_capacity(n);
            for (server, roots) in minibatches.iter().enumerate() {
                let mgs = env.sample_batch(roots, &mut rng, server,
                                           &mut clocks, &mut m);
                let sub = Subgraph::union_of(&mgs);
                // layer-1 destinations: all vertices that receive an
                // aggregation at the input layer = depth <= layers-1,
                // deduplicated across the mini-batch (P3 computes the
                // merged subgraph once, like DGL)
                let l1_flat: u64 = mgs
                    .iter()
                    .flat_map(|g| g.depth.iter())
                    .filter(|&&d| (d as usize) < env.cfg.layers)
                    .count() as u64;
                let summed: u64 =
                    mgs.iter().map(|g| g.num_vertices() as u64).sum();
                let dedup = if summed == 0 {
                    1.0
                } else {
                    sub.vertices.len() as f64 / summed as f64
                };
                let l1 = (l1_flat as f64 * dedup) as u64;
                layer1_dsts.push(l1);
                sub_edges.push(
                    mgs.iter().map(|g| g.edges.len() as u64).sum::<u64>(),
                );
                sub_verts.push(sub.vertices.len() as u64);
                // P3 keeps feature slices resident: no raw-feature fetch,
                // but the layer-1 input rows still count as local reads
                m.local_hits += sub.vertices.len() as u64;
            }

            // ---- phase 1: model-parallel layer 1 ----
            // each server computes the layer-1 partial for ALL mini-
            // batches over its F/N slice
            for server in 0..n {
                let total_l1: u64 = layer1_dsts.iter().sum();
                let total_edges: u64 = sub_edges.iter().sum();
                // aggregation over slice + transform to H, fwd+bwd (x3)
                let flops = 3.0
                    * (2.0 * total_edges as f64 * (feat_dim / n) as f64
                        + 2.0 * total_l1 as f64 * (feat_dim / n) as f64
                            * env.shape.hidden as f64);
                let dt = flops / env.cfg.cost.flops_per_sec
                    + env.cfg.cost.t_launch * 4.0;
                clocks.advance_busy(server, dt);
                m.time_compute += dt;
            }
            // reduce-scatter partial activations to owners: each server
            // receives (N-1) partials for its own layer-1 rows (fwd),
            // and sends the corresponding error terms back (bwd)
            for server in 0..n {
                let rows = layer1_dsts[server];
                let bytes = rows * hid_bytes * (n as u64 - 1);
                // count as one batched request per peer, fwd + bwd
                for peer in 0..n {
                    if peer == server {
                        continue;
                    }
                    let per = bytes / (n as u64 - 1);
                    let dt_f = stats.record(&env.cfg.net, peer, server, per,
                                            TransferKind::Hidden);
                    let dt_b = stats.record(&env.cfg.net, server, peer, per,
                                            TransferKind::Hidden);
                    clocks.advance(server, dt_f);
                    clocks.advance(peer, dt_b);
                    m.time_gather += dt_f + dt_b;
                    m.remote_requests += 2;
                }
                m.remote_vertices += rows * 2; // hidden rows moved fwd+bwd
                // CPU-side split/merge of the N-way partial tensors: each
                // of this server's rows is assembled from N partials (fwd)
                // and its gradient re-sliced N ways (bwd)
                let dt = env.cfg.cost.mp_row_overhead * (2 * rows) as f64;
                clocks.advance(server, dt);
                m.time_gather += dt;
            }
            // the MP phase pipeline: push-pull rounds synchronize all
            // servers before the data-parallel phase can start
            clocks.barrier();
            for s in 0..n {
                clocks.advance(s, env.cfg.cost.t_sync);
            }
            m.time_sync += env.cfg.cost.t_sync;

            // ---- phase 2: data-parallel layers >= 2 ----
            for server in 0..n {
                let v = sub_verts[server];
                let e = sub_edges[server];
                // all layers minus the (already computed) first
                let upper = env.shape.train_flops(v, e)
                    * ((env.cfg.layers - 1) as f64 / env.cfg.layers as f64);
                let dt = upper / env.cfg.cost.flops_per_sec
                    + env.cfg.cost.launch_overhead(&env.shape);
                clocks.advance_busy(server, dt);
                m.time_compute += dt;
            }

            // gradient sync for the data-parallel layers (layer-1 weights
            // are sharded and need no allreduce)
            env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        }

        stats.validate().expect("byte accounting");
        m.absorb_net(&stats);
        m.epoch_time = clocks.max();
        m.gpu_busy_fraction = clocks.busy_fraction();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::model_centric::ModelCentric;
    use crate::graph::datasets::tiny_test_dataset;
    use crate::partition::PartitionAlgo;

    fn cfg(hidden: usize, feat: Option<usize>) -> RunConfig {
        RunConfig {
            batch_size: 256,
            num_servers: 4,
            hidden,
            max_iterations: Some(3),
            partition_algo: PartitionAlgo::Hash,
            feat_dim_override: feat,
            ..Default::default()
        }
    }

    #[test]
    fn p3_moves_hidden_not_features() {
        let d = crate::graph::datasets::small_test_dataset(60);
        let m = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, None)));
        assert_eq!(m.bytes(TransferKind::Feature), 0);
        assert!(m.bytes(TransferKind::Hidden) > 0);
    }

    #[test]
    fn p3_beats_dgl_at_small_hidden_large_features() {
        // P3's sweet spot: high-dim features, tiny hidden layer.
        let d = crate::graph::datasets::small_test_dataset(61);
        let p3 = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, Some(600))));
        let dgl = ModelCentric::new()
            .run_epoch(&mut SimEnv::new(&d, cfg(16, Some(600))));
        assert!(
            p3.epoch_time < dgl.epoch_time,
            "p3 {} !< dgl {}",
            p3.epoch_time,
            dgl.epoch_time
        );
    }

    #[test]
    fn p3_traffic_scales_with_hidden_dim() {
        // The sensitivity HopGNN exploits (Fig 11): quadrupling H
        // quadruples P3's hidden-exchange bytes.
        let d = crate::graph::datasets::small_test_dataset(62);
        let lo = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(16, None)));
        let hi = P3::new().run_epoch(&mut SimEnv::new(&d, cfg(128, None)));
        let ratio = hi.bytes(TransferKind::Hidden) as f64
            / lo.bytes(TransferKind::Hidden) as f64;
        assert!(
            (6.0..10.0).contains(&ratio),
            "hidden bytes should scale ~8x, got {ratio}"
        );
    }
}
