//! Composable strategy specifications: the ablation space as a product
//! of orthogonal axes instead of a frozen enum.
//!
//! The paper's ablations (§7.3–§7.7) are *combinations* of mechanisms —
//! micrograph training ± pre-gathering ± a merge policy — but the
//! original selector was a closed 11-variant enum in which every cross
//! (`+MG`, `+PG`, RD, FA, …) was a hand-written variant. A
//! [`StrategySpec`] instead names the axes directly:
//!
//! | axis         | values                                   | paper mechanism |
//! |--------------|------------------------------------------|-----------------|
//! | `base`       | `dgl`, `p3`, `naive`, `hopgnn`, `lo`, `ns`, `dgl-fb` | which schedule builder |
//! | `micrograph` | on/off                                   | §5.1 micrograph training |
//! | `pregather`  | on/off                                   | §5.2 pre-gathering |
//! | `merge`      | `Off`, `MinLoad`, `Random`, `FabricAware`| §5.3 step merging |
//!
//! New combinations are *composed*, not enumerated: fabric-aware
//! merging without pre-gathering is
//! `StrategySpec::hopgnn().merge(Merge::FabricAware).pregather(false)`
//! — no new variant, no new match arms.
//!
//! ## String grammar
//!
//! [`std::fmt::Display`] and [`std::str::FromStr`] round-trip a
//! canonical grammar: a base name followed by `+tok` / `-tok`
//! modifiers, each a delta against the base's defaults:
//!
//! ```text
//! hopgnn            the full system (mg + pg + min-load merging)
//! hopgnn+fa         fabric-aware merging
//! hopgnn+fa-pg      fabric-aware merging, pre-gathering off
//! hopgnn-merge      mg + pg, no merging          (the paper's "+PG")
//! hopgnn-merge-pg   mg only                      (the paper's "+MG")
//! dgl, p3, naive, lo, ns, dgl-fb                 fixed-schedule bases
//! ```
//!
//! Modifier tokens: `mg` / `pg` (set the booleans), `+ml` / `+rd` /
//! `+fa` (pick a merge policy), `-merge` (disable merging). Illegal
//! combinations are rejected with the rule that was violated — merging
//! and pre-gathering require micrograph training, and the
//! micrograph axes require the `hopgnn` base (the other bases have
//! fixed schedules).
//!
//! Every legacy alias (`dgl`, `rd`, `fa`, `+mg`, `hopgnn-mg-pg`, …)
//! still parses to the equivalent spec; `tests/spec_parity.rs` locks
//! each one bit-identical to the pre-redesign dispatch.

use super::hopgnn::HopGnn;
use super::locality_opt::LocalityOpt;
use super::merge::Selection;
use super::model_centric::ModelCentric;
use super::naive_fc::NaiveFc;
use super::neutronstar::NeutronStar;
use super::p3::P3;
use super::Strategy;
use crate::partition::PartitionAlgo;
use std::fmt;
use std::str::FromStr;

/// The schedule-builder axis: which coordinator module compiles the
/// epoch. Only [`Base::HopGnn`] composes with the other axes; the rest
/// are fixed schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Base {
    /// Model-centric data-parallel baseline ([`super::model_centric`]).
    Dgl,
    /// P³'s push-pull model/data parallelism ([`super::p3`]).
    P3,
    /// The §3.2 strawman feature-centric walk ([`super::naive_fc`]).
    Naive,
    /// Feature-centric model migration ([`super::hopgnn`]).
    HopGnn,
    /// Redistribution without migration ([`super::locality_opt`]).
    LocalityOpt,
    /// Full-batch hybrid boundary exchange ([`super::neutronstar`]).
    NeutronStar,
    /// Full-batch gather-everything baseline ([`super::neutronstar`]).
    DglFullBatch,
}

/// Every base, in presentation order.
pub const ALL_BASES: [Base; 7] = [
    Base::Dgl,
    Base::P3,
    Base::Naive,
    Base::HopGnn,
    Base::LocalityOpt,
    Base::NeutronStar,
    Base::DglFullBatch,
];

impl Base {
    /// The canonical grammar token (also parsed by [`StrategySpec`]'s
    /// [`FromStr`]).
    pub fn token(&self) -> &'static str {
        match self {
            Self::Dgl => "dgl",
            Self::P3 => "p3",
            Self::Naive => "naive",
            Self::HopGnn => "hopgnn",
            Self::LocalityOpt => "lo",
            Self::NeutronStar => "ns",
            Self::DglFullBatch => "dgl-fb",
        }
    }
}

/// The §5.3 merge-policy axis (requires micrograph training).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Merge {
    /// No merging: the round-robin schedule stays at T = N steps.
    Off,
    /// The paper's scheme: merge the step with the fewest root vertices.
    MinLoad,
    /// Fig 18's RD ablation baseline: random step selection.
    Random,
    /// Selection and re-placement weighted by observed lane times
    /// ([`Selection::FabricAware`]).
    FabricAware,
}

/// Every merge policy, in presentation order.
pub const ALL_MERGES: [Merge; 4] =
    [Merge::Off, Merge::MinLoad, Merge::Random, Merge::FabricAware];

impl Merge {
    /// The canonical grammar token (`+ml` / `+rd` / `+fa`; `Off` is
    /// spelled `-merge`).
    pub fn token(&self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::MinLoad => "ml",
            Self::Random => "rd",
            Self::FabricAware => "fa",
        }
    }
}

/// A composed strategy: one value per axis. Construct with the builder
/// API ([`StrategySpec::hopgnn`] + [`StrategySpec::merge()`] /
/// [`StrategySpec::pregather()`] / [`StrategySpec::micrograph()`]) or
/// parse the string grammar; validate before building.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StrategySpec {
    pub base: Base,
    /// §5.1 micrograph training (required by, and only legal with,
    /// [`Base::HopGnn`]).
    pub micrograph: bool,
    /// §5.2 pre-gathering: one merged fetch per server per iteration.
    pub pregather: bool,
    /// §5.3 step merging policy.
    pub merge: Merge,
}

/// The 11 specs of the pre-redesign `StrategyKind` enum, in its
/// presentation order (harness sweeps iterate this).
pub const ALL_LEGACY_SPECS: [StrategySpec; 11] = [
    StrategySpec::dgl(),
    StrategySpec::p3(),
    StrategySpec::naive(),
    StrategySpec::hopgnn(),
    StrategySpec::hopgnn_mg(),
    StrategySpec::hopgnn_mg_pg(),
    StrategySpec::hopgnn_rd(),
    StrategySpec::hopgnn_fa(),
    StrategySpec::locality_opt(),
    StrategySpec::neutronstar(),
    StrategySpec::dgl_full_batch(),
];

/// Legacy display names for the specs the old enum could express (the
/// figure labels every report table uses).
const LEGACY_NAMES: [(StrategySpec, &str); 11] = [
    (StrategySpec::dgl(), "DGL"),
    (StrategySpec::p3(), "P3"),
    (StrategySpec::naive(), "Naive"),
    (StrategySpec::hopgnn(), "HopGNN"),
    (StrategySpec::hopgnn_mg(), "+MG"),
    (StrategySpec::hopgnn_mg_pg(), "+PG"),
    (StrategySpec::hopgnn_rd(), "RD"),
    (StrategySpec::hopgnn_fa(), "HopGNN-FA"),
    (StrategySpec::locality_opt(), "LO"),
    (StrategySpec::neutronstar(), "NeutronStar"),
    (StrategySpec::dgl_full_batch(), "DGL-FB"),
];

impl StrategySpec {
    /// Every axis at the given base's defaults: the full system for
    /// [`Base::HopGnn`], everything off for the fixed-schedule bases.
    pub const fn base_default(base: Base) -> Self {
        match base {
            Base::HopGnn => Self {
                base,
                micrograph: true,
                pregather: true,
                merge: Merge::MinLoad,
            },
            _ => Self {
                base,
                micrograph: false,
                pregather: false,
                merge: Merge::Off,
            },
        }
    }

    /// The DGL model-centric baseline.
    pub const fn dgl() -> Self {
        Self::base_default(Base::Dgl)
    }

    /// P³ push-pull parallelism.
    pub const fn p3() -> Self {
        Self::base_default(Base::P3)
    }

    /// The §3.2 naive feature-centric strawman.
    pub const fn naive() -> Self {
        Self::base_default(Base::Naive)
    }

    /// The full HopGNN system: micrographs + pre-gathering + min-load
    /// merging.
    pub const fn hopgnn() -> Self {
        Self::base_default(Base::HopGnn)
    }

    /// The locality-optimized accuracy foil.
    pub const fn locality_opt() -> Self {
        Self::base_default(Base::LocalityOpt)
    }

    /// NeutronStar's full-batch hybrid.
    pub const fn neutronstar() -> Self {
        Self::base_default(Base::NeutronStar)
    }

    /// The full-batch DGL baseline.
    pub const fn dgl_full_batch() -> Self {
        Self::base_default(Base::DglFullBatch)
    }

    /// Fig 13's `+MG`: micrograph training only.
    pub const fn hopgnn_mg() -> Self {
        Self::hopgnn().pregather(false).merge(Merge::Off)
    }

    /// Fig 13's `+PG`: micrographs + pre-gathering, no merging.
    pub const fn hopgnn_mg_pg() -> Self {
        Self::hopgnn().merge(Merge::Off)
    }

    /// Fig 18's RD ablation: random merge-step selection.
    pub const fn hopgnn_rd() -> Self {
        Self::hopgnn().merge(Merge::Random)
    }

    /// Fabric-aware merging (load balancing under heterogeneity).
    pub const fn hopgnn_fa() -> Self {
        Self::hopgnn().merge(Merge::FabricAware)
    }

    /// Set the micrograph axis (builder style, by value).
    pub const fn micrograph(mut self, on: bool) -> Self {
        self.micrograph = on;
        self
    }

    /// Set the pre-gathering axis (builder style, by value).
    pub const fn pregather(mut self, on: bool) -> Self {
        self.pregather = on;
        self
    }

    /// Set the merge-policy axis (builder style, by value).
    pub const fn merge(mut self, merge: Merge) -> Self {
        self.merge = merge;
        self
    }

    /// Check the combination rules. Parsing validates automatically;
    /// builder-composed specs are validated by [`Self::build`] and the
    /// sweep engine.
    pub fn validate(&self) -> Result<(), String> {
        if self.base == Base::HopGnn && !self.micrograph {
            return Err(
                "base 'hopgnn' trains on micrographs by definition, so \
                 '-mg' is not a valid combination (the model-centric \
                 baseline is 'dgl'; the non-micrograph feature-centric \
                 one is 'naive')"
                    .to_string(),
            );
        }
        if self.base != Base::HopGnn && self.micrograph {
            return Err(format!(
                "base '{}' has a fixed schedule; the micrograph axis \
                 ('+mg') requires base 'hopgnn'",
                self.base.token()
            ));
        }
        if self.pregather && !self.micrograph {
            return Err(
                "pre-gathering ('+pg') requires micrograph training"
                    .to_string(),
            );
        }
        if self.merge != Merge::Off && !self.micrograph {
            return Err(format!(
                "merging ('+{}') requires micrograph training",
                self.merge.token()
            ));
        }
        Ok(())
    }

    /// Display name for report tables: the historical figure label when
    /// the spec matches a legacy variant, the canonical grammar string
    /// for new combinations.
    pub fn name(&self) -> String {
        for (spec, name) in &LEGACY_NAMES {
            if self == spec {
                return (*name).to_string();
            }
        }
        self.to_string()
    }

    /// Instantiate the strategy this spec describes.
    ///
    /// # Panics
    ///
    /// Panics if the spec violates the combination rules — parse
    /// user-supplied strings through [`FromStr`] (which validates) and
    /// call [`Self::validate`] on builder-composed specs first.
    pub fn build(&self) -> Box<dyn Strategy> {
        if let Err(e) = self.validate() {
            panic!("invalid strategy spec '{}': {e}", self);
        }
        match self.base {
            Base::Dgl => Box::new(ModelCentric::new()),
            Base::P3 => Box::new(P3::new()),
            Base::Naive => Box::new(NaiveFc::new()),
            Base::HopGnn => Box::new(HopGnn::with_flags(
                self.pregather,
                self.merge != Merge::Off,
                self.selection(),
            )),
            Base::LocalityOpt => Box::new(LocalityOpt::new()),
            Base::NeutronStar => Box::new(NeutronStar::new(false)),
            Base::DglFullBatch => Box::new(NeutronStar::new(true)),
        }
    }

    /// The merge controller's selection scheme for this spec.
    fn selection(&self) -> Selection {
        match self.merge {
            Merge::Random => Selection::Random,
            Merge::FabricAware => Selection::FabricAware,
            Merge::Off | Merge::MinLoad => Selection::MinLoad,
        }
    }

    /// P³'s design requires hash partitioning; everything else defaults
    /// to the config's partitioner.
    pub fn preferred_partition(&self) -> Option<PartitionAlgo> {
        match self.base {
            Base::P3 => Some(PartitionAlgo::Hash),
            _ => None,
        }
    }

    /// Whether the merge controller adapts the schedule across epochs
    /// (report the final frozen epoch as steady state).
    pub fn adapts_across_epochs(&self) -> bool {
        self.merge != Merge::Off
    }

    /// One-line grammar summary for CLI error messages.
    pub fn grammar_help() -> &'static str {
        "strategy grammar: <base>[+tok|-tok...] with base one of dgl, \
         p3, naive, hopgnn, lo, ns, dgl-fb and tokens mg, pg (axes), \
         +ml/+rd/+fa (merge policy), -merge (merging off) — e.g. \
         'hopgnn+fa-pg'; legacy aliases (+mg, +pg, rd, fa, ...) also \
         accepted"
    }
}

/// Exact-string legacy aliases, resolved before the grammar: every
/// spelling the pre-redesign enum accepted maps to its equivalent spec.
fn alias(s: &str) -> Option<StrategySpec> {
    Some(match s {
        "dgl" | "model-centric" => StrategySpec::dgl(),
        "p3" => StrategySpec::p3(),
        "naive" | "naive-fc" => StrategySpec::naive(),
        "hopgnn" | "all" => StrategySpec::hopgnn(),
        "hopgnn-mg" | "+mg" => StrategySpec::hopgnn_mg(),
        "hopgnn-mg-pg" | "+pg" => StrategySpec::hopgnn_mg_pg(),
        "hopgnn-rd" | "rd" => StrategySpec::hopgnn_rd(),
        "hopgnn-fa" | "fa" => StrategySpec::hopgnn_fa(),
        "lo" | "locality-opt" => StrategySpec::locality_opt(),
        "neutronstar" | "ns" => StrategySpec::neutronstar(),
        "dgl-fb" => StrategySpec::dgl_full_batch(),
        _ => return None,
    })
}

impl fmt::Display for StrategySpec {
    /// The canonical grammar string: base token plus the modifiers that
    /// differ from the base's defaults, in merge → mg → pg order (so
    /// the full HopGNN prints as plain `hopgnn`).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let d = Self::base_default(self.base);
        write!(f, "{}", self.base.token())?;
        if self.merge != d.merge {
            match self.merge {
                Merge::Off => write!(f, "-merge")?,
                m => write!(f, "+{}", m.token())?,
            }
        }
        if self.micrograph != d.micrograph {
            write!(f, "{}mg", if self.micrograph { '+' } else { '-' })?;
        }
        if self.pregather != d.pregather {
            write!(f, "{}pg", if self.pregather { '+' } else { '-' })?;
        }
        Ok(())
    }
}

impl FromStr for StrategySpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let input = s.trim();
        if let Some(spec) = alias(input) {
            return Ok(spec);
        }
        // longest base-name prefix ("dgl-fb" must win over "dgl")
        let mut best: Option<(Base, &str)> = None;
        for b in ALL_BASES {
            if let Some(rest) = input.strip_prefix(b.token()) {
                let longer = match best {
                    Some((prev, _)) => b.token().len() > prev.token().len(),
                    None => true,
                };
                if longer {
                    best = Some((b, rest));
                }
            }
        }
        let (base, mut rest) = best.ok_or_else(|| {
            format!(
                "unknown strategy '{input}'; {}",
                StrategySpec::grammar_help()
            )
        })?;
        let mut spec = StrategySpec::base_default(base);
        let (mut seen_mg, mut seen_pg, mut seen_merge) =
            (false, false, false);
        let dup = |seen: &mut bool, axis: &str| -> Result<(), String> {
            if *seen {
                return Err(format!(
                    "strategy '{input}': axis '{axis}' set twice"
                ));
            }
            *seen = true;
            Ok(())
        };
        while !rest.is_empty() {
            let on = match rest.as_bytes()[0] {
                b'+' => true,
                b'-' => false,
                c => {
                    return Err(format!(
                        "strategy '{input}': expected '+' or '-' before \
                         a modifier, found '{}'; {}",
                        c as char,
                        StrategySpec::grammar_help()
                    ))
                }
            };
            rest = &rest[1..];
            let end = rest
                .find(|c: char| c == '+' || c == '-')
                .unwrap_or(rest.len());
            let tok = &rest[..end];
            rest = &rest[end..];
            match (tok, on) {
                ("mg", _) => {
                    dup(&mut seen_mg, "micrograph")?;
                    spec.micrograph = on;
                }
                ("pg", _) => {
                    dup(&mut seen_pg, "pregather")?;
                    spec.pregather = on;
                }
                ("ml" | "merge", true) => {
                    dup(&mut seen_merge, "merge")?;
                    spec.merge = Merge::MinLoad;
                }
                ("rd", true) => {
                    dup(&mut seen_merge, "merge")?;
                    spec.merge = Merge::Random;
                }
                ("fa", true) => {
                    dup(&mut seen_merge, "merge")?;
                    spec.merge = Merge::FabricAware;
                }
                ("merge", false) => {
                    dup(&mut seen_merge, "merge")?;
                    spec.merge = Merge::Off;
                }
                ("ml" | "rd" | "fa", false) => {
                    return Err(format!(
                        "strategy '{input}': use '-merge' to disable \
                         merging (merge policies are picked with \
                         '+ml'/'+rd'/'+fa')"
                    ));
                }
                _ => {
                    return Err(format!(
                        "strategy '{input}': unknown modifier '{tok}'; \
                         valid modifiers: mg, pg, ml, rd, fa, merge"
                    ));
                }
            }
        }
        spec.validate()
            .map_err(|e| format!("invalid strategy '{input}': {e}"))?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_composes_new_combinations() {
        let s = StrategySpec::hopgnn()
            .merge(Merge::FabricAware)
            .pregather(false);
        assert_eq!(s.base, Base::HopGnn);
        assert!(s.micrograph);
        assert!(!s.pregather);
        assert_eq!(s.merge, Merge::FabricAware);
        s.validate().unwrap();
        assert_eq!(s.to_string(), "hopgnn+fa-pg");
        assert_eq!("hopgnn+fa-pg".parse::<StrategySpec>().unwrap(), s);
    }

    #[test]
    fn legacy_aliases_resolve() {
        for (input, expect) in [
            ("dgl", StrategySpec::dgl()),
            ("model-centric", StrategySpec::dgl()),
            ("p3", StrategySpec::p3()),
            ("naive", StrategySpec::naive()),
            ("naive-fc", StrategySpec::naive()),
            ("hopgnn", StrategySpec::hopgnn()),
            ("all", StrategySpec::hopgnn()),
            ("hopgnn-mg", StrategySpec::hopgnn_mg()),
            ("+mg", StrategySpec::hopgnn_mg()),
            ("hopgnn-mg-pg", StrategySpec::hopgnn_mg_pg()),
            ("+pg", StrategySpec::hopgnn_mg_pg()),
            ("hopgnn-rd", StrategySpec::hopgnn_rd()),
            ("rd", StrategySpec::hopgnn_rd()),
            ("hopgnn-fa", StrategySpec::hopgnn_fa()),
            ("fa", StrategySpec::hopgnn_fa()),
            ("lo", StrategySpec::locality_opt()),
            ("locality-opt", StrategySpec::locality_opt()),
            ("neutronstar", StrategySpec::neutronstar()),
            ("ns", StrategySpec::neutronstar()),
            ("dgl-fb", StrategySpec::dgl_full_batch()),
        ] {
            assert_eq!(
                input.parse::<StrategySpec>().unwrap(),
                expect,
                "alias '{input}'"
            );
        }
        assert!("bogus".parse::<StrategySpec>().is_err());
    }

    #[test]
    fn legacy_specs_keep_their_figure_labels() {
        let names: Vec<String> =
            ALL_LEGACY_SPECS.iter().map(StrategySpec::name).collect();
        assert_eq!(
            names,
            [
                "DGL",
                "P3",
                "Naive",
                "HopGNN",
                "+MG",
                "+PG",
                "RD",
                "HopGNN-FA",
                "LO",
                "NeutronStar",
                "DGL-FB"
            ]
        );
        // new combinations fall back to the canonical grammar string
        assert_eq!(
            StrategySpec::hopgnn().pregather(false).name(),
            "hopgnn-pg"
        );
    }

    #[test]
    fn illegal_combinations_are_rejected_with_the_rule() {
        let e = "dgl+ml".parse::<StrategySpec>().unwrap_err();
        assert!(e.contains("micrograph"), "{e}");
        let e = "dgl+pg".parse::<StrategySpec>().unwrap_err();
        assert!(e.contains("micrograph"), "{e}");
        let e = "p3+mg".parse::<StrategySpec>().unwrap_err();
        assert!(e.contains("hopgnn"), "{e}");
        let e = "hopgnn-mg-pg-merge".parse::<StrategySpec>();
        // alias "hopgnn-mg-pg" is exact-match only; this goes through
        // the grammar and strips micrograph from the hopgnn base
        assert!(e.unwrap_err().contains("micrographs by definition"));
    }

    #[test]
    fn grammar_is_strict_about_tokens() {
        assert!("hopgnn+zz".parse::<StrategySpec>().is_err());
        assert!("hopgnn+".parse::<StrategySpec>().is_err());
        assert!("hopgnnx".parse::<StrategySpec>().is_err());
        let e = "hopgnn-fa-pg".parse::<StrategySpec>().unwrap_err();
        assert!(e.contains("-merge"), "{e}");
        let e = "hopgnn+rd+ml".parse::<StrategySpec>().unwrap_err();
        assert!(e.contains("set twice"), "{e}");
        // '+merge' is accepted as min-load (the default policy)
        assert_eq!(
            "hopgnn+merge".parse::<StrategySpec>().unwrap(),
            StrategySpec::hopgnn()
        );
        // re-stating a boolean axis is harmless; only duplicates of the
        // same axis are rejected
        assert_eq!(
            "hopgnn-merge+pg".parse::<StrategySpec>().unwrap(),
            StrategySpec::hopgnn_mg_pg()
        );
    }

    #[test]
    fn every_legacy_spec_is_listed_buildable_and_round_trips() {
        for spec in ALL_LEGACY_SPECS {
            spec.validate().unwrap();
            let s = spec.build();
            assert!(!s.name().is_empty());
            assert_eq!(
                spec.to_string().parse::<StrategySpec>().unwrap(),
                spec,
                "canonical round-trip for {spec}"
            );
        }
    }

    #[test]
    fn adaptation_and_partition_preferences_follow_the_axes() {
        assert!(StrategySpec::hopgnn().adapts_across_epochs());
        assert!(StrategySpec::hopgnn_rd().adapts_across_epochs());
        assert!(StrategySpec::hopgnn_fa().adapts_across_epochs());
        assert!(!StrategySpec::hopgnn_mg_pg().adapts_across_epochs());
        assert!(!StrategySpec::dgl().adapts_across_epochs());
        assert_eq!(
            StrategySpec::p3().preferred_partition(),
            Some(PartitionAlgo::Hash)
        );
        assert_eq!(StrategySpec::hopgnn().preferred_partition(), None);
    }
}
