//! The shared epoch execution engine.
//!
//! [`EpochDriver::run`] executes a strategy-built [`Program`] against
//! the cluster substrate — per-server [`Clocks`], exact [`NetStats`]
//! byte accounting, and [`EpochMetrics`] — in one place. Strategies are
//! pure schedule builders; everything that used to be six hand-rolled
//! epoch loops (clock lifecycle, gather execution, migration timing,
//! allreduce, validation) lives here.
//!
//! ## Parallel per-server simulation
//!
//! Each [`Item::Lanes`] executes one op lane per server. Lanes are
//! independent by construction (an op only touches its own server's
//! clock; byte records are pure sums), so the driver runs them on
//! `std::thread::scope` workers when there is enough work to amortize
//! the spawns, then reduces lane-local `NetStats`/metrics deltas in
//! server order. The lane executor is the same function in both modes
//! and the reduction order is fixed, so parallel execution is
//! **bit-identical** to sequential execution — `deterministic` tests
//! hold with lanes enabled.
//!
//! ## Gather/compute overlap
//!
//! With [`RunConfig::overlap`] enabled, transfer ops flagged
//! `overlap: true` become *asynchronous*: their seconds accumulate in a
//! per-lane pending buffer instead of the clock, and subsequent compute
//! on the same lane drains (hides) the pending time — the steady-state
//! pipelining idealization (P³'s push-pull behind compute, HopGNN's
//! pre-gather as prefetch, RapidGNN-style deterministic fetch overlap).
//! Whatever compute cannot hide is exposed to the clock at the next
//! allreduce (gradient sync is a hard fence) or at epoch end. Byte
//! accounting is unaffected: overlap changes *when* time is charged,
//! never how many bytes move. With the knob off, every op is charged
//! inline and the driver reproduces the historical eager loops'
//! accounting exactly.
//!
//! ## The tiered feature store
//!
//! The driver owns one [`TierStack`] per server lane (built from
//! [`crate::config::RunConfig::tiers`] — or the legacy
//! `cache_policy`/`cache_mb` two-tier alias — or handed in warm via
//! [`EpochDriver::with_tiers`] when
//! [`crate::config::RunConfig::cache_persist`] keeps them alive across
//! epochs). [`Op::CacheFetch`] ops resolve their request through the
//! lane's tier stack before touching the network: each hit is priced
//! by the tier that holds the row (hbm free, dram staged, ssd staged +
//! flash read — see [`crate::featstore::tier`]) and moves zero network
//! bytes — in both serial and overlap modes, so with overlap on a hit
//! also never enters the async pending stream — while full misses cost
//! exactly what the equivalent `GatherMerged` would and are admitted
//! per the stack's placement policies. Stacks are lane-private,
//! keeping parallel lane execution bit-identical to sequential; the
//! single-dram stack reproduces the legacy cache bit-for-bit and a
//! capacity-0 stack the uncached driver (`tests/cache_parity.rs`,
//! `tests/tier_parity.rs`). [`EpochDriver::finish_session`] returns
//! the stacks so a strategy can carry them into its next epoch.
//!
//! ## The cluster fabric
//!
//! All lane costs are priced by the env's [`crate::cluster::Fabric`]:
//! transfer ops charge the per-(src, dst)-link time, and compute ops'
//! seconds are divided by the executing server's compute-speed
//! multiplier. On the `uniform` fabric both are bit-identical to the
//! historical scalar model (`tests/fabric_parity.rs`).

use super::ops::{Item, Op, Phase, Program};
use super::SimEnv;
use crate::cluster::{Clocks, NetStats};
use crate::featstore::pregather::{PlanScratch, PregatherPlan};
use crate::featstore::tier::{TierKind, TierStack, NUM_TIER_KINDS};
use crate::featstore::{FeatureStore, GatherPlan};
use crate::metrics::EpochMetrics;
use crate::util::stamp::StampedSet;

/// Minimum summed op weight in a lane set before the driver spawns
/// worker threads (below this, sequential execution is faster).
const PARALLEL_WORK_THRESHOLD: usize = 4096;

/// One epoch's execution session. Strategies stream [`Program`]
/// fragments (typically one per iteration) through [`Self::exec`] so
/// the materialized op working set stays O(one iteration) — the same
/// footprint the historical eager loops had — then close the session
/// with [`Self::finish`]. [`Self::run`] is the one-shot convenience
/// for a fully materialized program.
pub struct EpochDriver<'e, 'a> {
    env: &'e SimEnv<'a>,
    store: FeatureStore<'e>,
    clocks: Clocks,
    stats: NetStats,
    m: EpochMetrics,
    /// Per-server asynchronous transfer time not yet hidden or exposed.
    pending: Vec<f64>,
    /// One feature tier stack per server lane (an empty remote-only
    /// stack with the tiers off). A stack is only ever touched by its
    /// own lane, so parallel lane execution stays bit-identical to
    /// sequential.
    tiers: Vec<TierStack>,
    /// One reusable execution scratch per server lane (accounting
    /// deltas + gather-planning buffers), reset per lane run instead of
    /// reallocated — the driver-side half of the zero-allocation
    /// iteration hot path.
    scratch: Vec<LaneScratch>,
    parallel_override: Option<bool>,
}

impl<'e, 'a> EpochDriver<'e, 'a> {
    pub fn new(env: &'e SimEnv<'a>) -> Self {
        Self::with_parts(env, None, None)
    }

    /// `new` with warm feature tier stacks carried over from a
    /// previous epoch session (the `--cache-persist` path; see
    /// [`Self::finish_session`]).
    pub fn with_tiers(env: &'e SimEnv<'a>, tiers: Vec<TierStack>) -> Self {
        // hard assert: exec_lanes zips lanes with tier stacks, so a
        // wrong length would silently drop server lanes in release
        assert_eq!(
            tiers.len(),
            env.num_servers(),
            "persisted tier stacks do not match the env's server count"
        );
        Self::with_parts(env, Some(tiers), None)
    }

    /// Full constructor: optional warm tier stacks, optional forced
    /// lane-parallelism decision (tests assert bit-parity between the
    /// two modes through this entry point).
    fn with_parts(
        env: &'e SimEnv<'a>,
        tiers: Option<Vec<TierStack>>,
        parallel_override: Option<bool>,
    ) -> Self {
        let n = env.num_servers();
        Self {
            env,
            store: env.store(),
            clocks: Clocks::new(n),
            stats: NetStats::new(n),
            m: EpochMetrics::default(),
            pending: vec![0.0f64; n],
            tiers: tiers.unwrap_or_else(|| env.build_tiers()),
            scratch: (0..n).map(|_| LaneScratch::new(n)).collect(),
            parallel_override,
        }
    }

    /// Execute one schedule fragment against the session state.
    pub fn exec(&mut self, program: &Program) {
        let n = self.env.num_servers();
        debug_assert_eq!(n, program.num_servers, "program/env server count");
        for item in &program.items {
            match item {
                Item::Lanes(lanes) => {
                    let work: usize = lanes
                        .iter()
                        .flat_map(|l| l.iter().map(Op::weight))
                        .sum();
                    let active =
                        lanes.iter().filter(|l| !l.is_empty()).count();
                    let parallel = self.parallel_override.unwrap_or(
                        self.env.cfg.parallel_lanes
                            && work >= PARALLEL_WORK_THRESHOLD,
                    ) && active > 1;
                    exec_lanes(
                        self.env,
                        &self.store,
                        lanes,
                        parallel,
                        &mut self.clocks,
                        &mut self.stats,
                        &mut self.m,
                        &mut self.pending,
                        &mut self.tiers,
                        &mut self.scratch,
                    );
                }
                Item::Barrier => {
                    // async transfers keep flowing while a server waits
                    // at the barrier: the idle gap up to the slowest
                    // server absorbs pending transfer time. (With
                    // overlap off, pending is always zero.)
                    let max = self.clocks.max();
                    for s in 0..n {
                        let gap = max - self.clocks.now(s);
                        let hide = self.pending[s].min(gap);
                        if hide > 0.0 {
                            self.pending[s] -= hide;
                            self.m.time_overlap_hidden += hide;
                        }
                    }
                    self.clocks.barrier();
                }
                Item::SyncAll => {
                    for s in 0..n {
                        self.clocks.advance(s, self.env.cfg.cost.t_sync);
                    }
                    self.m.time_sync += self.env.cfg.cost.t_sync;
                }
                Item::Allreduce => {
                    // gradient sync is a hard fence: expose whatever
                    // async transfer time compute and idle could not hide
                    expose_pending(&mut self.clocks, &mut self.pending);
                    self.env.allreduce_grads(
                        &mut self.clocks,
                        &mut self.stats,
                        &mut self.m,
                    );
                }
            }
        }
    }

    /// Close the session: expose leftover async time, validate byte and
    /// message conservation ([`NetStats::validate`] runs on *every*
    /// session close, bench runs included), and return the epoch's
    /// metrics (times, exact bytes, counters, busy fraction).
    ///
    /// The caller (strategy) still owns schedule-level metrics:
    /// `iterations`, `time_steps_per_iter`, and `dropped_roots` are not
    /// known here.
    pub fn finish(self) -> EpochMetrics {
        self.finish_session().0
    }

    /// [`Self::finish`] that also hands the per-lane tier stacks
    /// back, so a strategy running with
    /// [`crate::config::RunConfig::cache_persist`] can seed its next
    /// epoch's session via [`Self::with_tiers`].
    pub fn finish_session(mut self) -> (EpochMetrics, Vec<TierStack>) {
        expose_pending(&mut self.clocks, &mut self.pending);
        self.stats.validate().expect("byte accounting");
        self.m.absorb_net(&self.stats);
        self.m.epoch_time = self.clocks.max();
        self.m.gpu_busy_fraction = self.clocks.busy_fraction();
        self.m.per_server_busy = (0..self.env.num_servers())
            .map(|s| self.clocks.busy_time(s))
            .collect();
        (self.m, self.tiers)
    }

    /// One-shot: execute `program` in a fresh session and finish.
    pub fn run(env: &SimEnv, program: &Program) -> EpochMetrics {
        Self::run_inner(env, program, None)
    }

    fn run_inner(
        env: &SimEnv,
        program: &Program,
        parallel_override: Option<bool>,
    ) -> EpochMetrics {
        let mut driver = EpochDriver::with_parts(env, None, parallel_override);
        driver.exec(program);
        driver.finish()
    }
}

fn expose_pending(clocks: &mut Clocks, pending: &mut [f64]) {
    for (s, p) in pending.iter_mut().enumerate() {
        if *p > 0.0 {
            clocks.advance(s, *p);
            *p = 0.0;
        }
    }
}

/// Reusable per-lane execution state: the lane-local accounting deltas
/// (`stats`, `m`) plus every gather-planning buffer a lane's ops need
/// (`seen`/`plan` for plain and cache-routed gathers, `ps`/`pre` for
/// merged pre-gathers). One scratch belongs to one server lane for the
/// whole driver session — like the caches, it is only ever touched by
/// its own lane, so parallel execution stays bit-identical — and is
/// reset (keeping capacity) at the start of each lane run, so
/// steady-state lane execution allocates nothing.
struct LaneScratch {
    stats: NetStats,
    m: EpochMetrics,
    seen: StampedSet,
    plan: GatherPlan,
    pre: PregatherPlan,
    ps: PlanScratch,
}

impl LaneScratch {
    fn new(num_servers: usize) -> Self {
        Self {
            stats: NetStats::new(num_servers),
            m: EpochMetrics::default(),
            seen: StampedSet::default(),
            plan: GatherPlan::default(),
            pre: PregatherPlan::default(),
            ps: PlanScratch::default(),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn exec_lanes(
    env: &SimEnv,
    store: &FeatureStore,
    lanes: &[Vec<Op>],
    parallel: bool,
    clocks: &mut Clocks,
    stats: &mut NetStats,
    m: &mut EpochMetrics,
    pending: &mut [f64],
    tiers: &mut [TierStack],
    scratches: &mut [LaneScratch],
) {
    if parallel {
        let results: Vec<(f64, f64, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = lanes
                .iter()
                .zip(tiers.iter_mut().zip(scratches.iter_mut()))
                .enumerate()
                .map(|(s, (ops, (stack, scratch)))| {
                    let t0 = clocks.now(s);
                    let p0 = pending[s];
                    scope.spawn(move || {
                        run_lane(env, store, s, ops, t0, p0, stack, scratch)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("lane worker panicked"))
                .collect()
        });
        // deterministic reduction: server order, independent of which
        // lane finished first
        for (s, (t, busy_dt, pend)) in results.into_iter().enumerate() {
            clocks.set(s, t);
            clocks.add_busy(s, busy_dt);
            stats.merge(&scratches[s].stats);
            m.accumulate(&scratches[s].m);
            pending[s] = pend;
        }
    } else {
        // run + reduce inline per lane, in server order. Lanes never
        // read another lane's clock, pending slot, or the global
        // accumulators, so reducing lane s before running lane s+1 is
        // bit-identical to the collect-then-reduce parallel path — and
        // allocation-free, which the parallel path (thread state, the
        // results Vec) inherently is not.
        for (s, (ops, (stack, scratch))) in lanes
            .iter()
            .zip(tiers.iter_mut().zip(scratches.iter_mut()))
            .enumerate()
        {
            let (t, busy_dt, pend) = run_lane(
                env,
                store,
                s,
                ops,
                clocks.now(s),
                pending[s],
                stack,
                scratch,
            );
            clocks.set(s, t);
            clocks.add_busy(s, busy_dt);
            stats.merge(&scratch.stats);
            m.accumulate(&scratch.m);
            pending[s] = pend;
        }
    }
}

/// Execute one server's ops starting from clock `t0` and async-pending
/// `pending0`. Pure with respect to shared state: reads only shared
/// immutable state, writes only lane-local accumulators (the feature
/// tier `stack` and the `scratch` belong to this lane alone). Returns
/// `(t, busy_dt, pending)`; the accounting deltas are left in the
/// scratch for the caller to reduce.
#[allow(clippy::too_many_arguments)]
fn run_lane(
    env: &SimEnv,
    store: &FeatureStore,
    server: usize,
    ops: &[Op],
    t0: f64,
    pending0: f64,
    stack: &mut TierStack,
    scratch: &mut LaneScratch,
) -> (f64, f64, f64) {
    let cfg = &env.cfg;
    let overlap_on = cfg.overlap;
    // heterogeneous compute: this server's cost-model seconds divide by
    // its fabric speed multiplier (1.0 on a uniform fabric — and
    // `x / 1.0` is bitwise `x`, preserving uniform parity)
    let speed = env.fabric.compute_speed(server);
    let mut t = t0;
    let mut busy_dt = 0.0f64;
    let mut pending = pending0;
    let LaneScratch {
        stats,
        m,
        seen,
        plan,
        pre,
        ps,
    } = scratch;
    stats.reset();
    m.reset();

    let charge_compute = |dt: f64,
                          t: &mut f64,
                          busy_dt: &mut f64,
                          pending: &mut f64,
                          m: &mut EpochMetrics| {
        *t += dt;
        *busy_dt += dt;
        m.time_compute += dt;
        if overlap_on && *pending > 0.0 {
            // async transfers proceed while the GPU computes
            let hidden = pending.min(dt);
            *pending -= hidden;
            m.time_overlap_hidden += hidden;
        }
    };

    // one place decides whether transfer seconds go to the clock or
    // the async-pending stream (Gather, GatherMerged, and Migrate all
    // share these semantics)
    let charge_transfer = |dt: f64,
                           phase: Phase,
                           async_ok: bool,
                           t: &mut f64,
                           pending: &mut f64,
                           m: &mut EpochMetrics| {
        phase_add(m, phase, dt);
        if overlap_on && async_ok {
            *pending += dt;
        } else {
            *t += dt;
        }
    };

    for op in ops {
        match op {
            Op::Sample { vertices } => {
                let dt = cfg.cost.sample_time(*vertices);
                t += dt;
                m.time_sample += dt;
            }
            Op::Gather { vertices, overlap } => {
                store.plan_into(server, vertices.iter().copied(), seen, plan);
                let dt = store.sim_cost(
                    plan,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::GatherMerged { steps, overlap } => {
                PregatherPlan::build_into(store, server, steps, ps, pre);
                let dt = store.sim_cost(
                    &pre.merged,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::CacheFetch { steps, overlap } => {
                // walk this lane's tier stack: hits are served (and
                // priced) by the tier that holds the row — hbm free,
                // dram staged, ssd staged + flash — skipping the
                // transfer (and, in overlap mode, the pending stream);
                // the residual plan fetches exactly like a merged
                // gather and is admitted per the placement policies
                let deltas =
                    stack.resolve_into(store, server, steps, seen, plan);
                let fb = store.feat_bytes;
                let hits = deltas.cache_hits();
                let remote = plan.remote_count();
                let mut dt = store.sim_cost_cached(
                    plan,
                    deltas.staged_hit_rows,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                // gated so stacks without flash add no float ops to
                // the legacy cost path (x + 0.0 is not bitwise id)
                let ssd = deltas.ssd_seconds(fb);
                if ssd > 0.0 {
                    dt += ssd;
                }
                m.cache_hits += hits;
                m.cache_misses += remote;
                m.cache_hit_bytes += hits * fb;
                m.cache_miss_bytes += remote * fb;
                m.cache_evict_bytes += deltas.evicted_bytes;
                for k in 0..NUM_TIER_KINDS {
                    m.tier_hits[k] += deltas.hits_at[k];
                    m.tier_hit_bytes[k] += deltas.hits_at[k] * fb;
                    m.tier_miss_bytes[k] += deltas.misses_at[k] * fb;
                    m.tier_promote_bytes[k] += deltas.promote_bytes_at[k];
                    m.tier_demote_bytes[k] += deltas.demote_bytes_at[k];
                }
                // the backstop never misses: residual fetches are
                // remote-tier hits in the per-tier view
                let ri = TierKind::Remote.index();
                m.tier_hits[ri] += remote;
                m.tier_hit_bytes[ri] += remote * fb;
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Compute { v, e } => {
                let dt = cfg.cost.train_time(&env.shape, *v, *e) / speed;
                charge_compute(
                    dt,
                    &mut t,
                    &mut busy_dt,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::ComputeSecs { secs } => {
                charge_compute(
                    *secs / speed,
                    &mut t,
                    &mut busy_dt,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Migrate {
                from,
                kind,
                bytes,
                phase,
                overlap,
            } => {
                let dt =
                    stats.record(&env.fabric, *from, server, *bytes, *kind);
                charge_transfer(
                    dt,
                    *phase,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Host { secs, phase } => {
                t += secs;
                phase_add(m, *phase, *secs);
            }
            Op::Tally {
                remote_requests,
                remote_vertices,
                local_hits,
            } => {
                m.remote_requests += remote_requests;
                m.remote_vertices += remote_vertices;
                m.local_hits += local_hits;
            }
        }
    }

    (t, busy_dt, pending)
}

fn phase_add(m: &mut EpochMetrics, phase: Phase, dt: f64) {
    match phase {
        Phase::Gather => m.time_gather += dt,
        Phase::Migrate => m.time_migrate += dt,
        Phase::Untimed => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TransferKind;
    use crate::config::RunConfig;
    use crate::coordinator::ops::ProgramBuilder;
    use crate::featstore::cache::CachePolicy;
    use crate::graph::datasets::tiny_test_dataset;

    fn env_with(overlap: bool, parallel: bool) -> RunConfig {
        RunConfig {
            num_servers: 4,
            overlap,
            parallel_lanes: parallel,
            ..Default::default()
        }
    }

    fn demo_program(n: usize) -> Program {
        let mut b = ProgramBuilder::new(n);
        for s in 0..n {
            b.op(s, Op::Sample { vertices: 500 });
            b.op(s, Op::Gather {
                // tiny_test_dataset has 400 vertices; gather them all
                vertices: (0..400u32).collect(),
                overlap: true,
            });
            b.op(s, Op::Compute { v: 400, e: 2400 });
        }
        b.barrier();
        for s in 0..n {
            b.op(s, Op::Migrate {
                from: (s + 1) % n,
                kind: TransferKind::ModelParams,
                bytes: 1 << 16,
                phase: Phase::Migrate,
                overlap: false,
            });
        }
        b.allreduce();
        b.finish()
    }

    #[test]
    fn sequential_and_parallel_lanes_are_bit_identical() {
        let d = tiny_test_dataset(200);
        let prog = demo_program(4);
        let env = SimEnv::new(&d, env_with(false, true));
        let seq = EpochDriver::run_inner(&env, &prog, Some(false));
        let par = EpochDriver::run_inner(&env, &prog, Some(true));
        assert_eq!(seq.total_bytes(), par.total_bytes());
        for k in 0..crate::cluster::network::NUM_KINDS {
            assert_eq!(seq.bytes_by_kind[k], par.bytes_by_kind[k]);
        }
        assert_eq!(seq.epoch_time.to_bits(), par.epoch_time.to_bits());
        assert_eq!(
            seq.gpu_busy_fraction.to_bits(),
            par.gpu_busy_fraction.to_bits()
        );
        assert_eq!(seq.time_gather.to_bits(), par.time_gather.to_bits());
        assert_eq!(seq.remote_vertices, par.remote_vertices);
        assert_eq!(seq.local_hits, par.local_hits);
    }

    #[test]
    fn streaming_fragments_equal_one_program() {
        // feeding the epoch as per-iteration fragments through exec()
        // is bit-identical to one materialized program
        let d = tiny_test_dataset(204);
        let env = SimEnv::new(&d, env_with(false, false));
        let one = EpochDriver::run(&env, &demo_program(4));

        let mut frag_a = ProgramBuilder::new(4);
        for s in 0..4 {
            frag_a.op(s, Op::Sample { vertices: 500 });
            frag_a.op(s, Op::Gather {
                vertices: (0..400u32).collect(),
                overlap: true,
            });
            frag_a.op(s, Op::Compute { v: 400, e: 2400 });
        }
        frag_a.barrier();
        let mut frag_b = ProgramBuilder::new(4);
        for s in 0..4 {
            frag_b.op(s, Op::Migrate {
                from: (s + 1) % 4,
                kind: TransferKind::ModelParams,
                bytes: 1 << 16,
                phase: Phase::Migrate,
                overlap: false,
            });
        }
        frag_b.allreduce();
        let mut driver = EpochDriver::new(&env);
        driver.exec(&frag_a.finish());
        driver.exec(&frag_b.finish());
        let streamed = driver.finish();

        assert_eq!(one.total_bytes(), streamed.total_bytes());
        assert_eq!(one.epoch_time.to_bits(), streamed.epoch_time.to_bits());
        assert_eq!(one.remote_vertices, streamed.remote_vertices);
    }

    #[test]
    fn overlap_changes_time_not_bytes() {
        let d = tiny_test_dataset(201);
        let off_env = SimEnv::new(&d, env_with(false, false));
        let off = EpochDriver::run(&off_env, &demo_program(4));
        let on_env = SimEnv::new(&d, env_with(true, false));
        let on = EpochDriver::run(&on_env, &demo_program(4));
        assert_eq!(off.total_bytes(), on.total_bytes());
        assert_eq!(off.remote_vertices, on.remote_vertices);
        assert!(on.epoch_time <= off.epoch_time + 1e-15,
                "overlap must not slow the epoch: {} > {}",
                on.epoch_time, off.epoch_time);
        assert!(on.time_overlap_hidden > 0.0, "some gather must hide");
        // gather *work* is unchanged; only its exposure moved
        assert!((on.time_gather - off.time_gather).abs() < 1e-15);
    }

    #[test]
    fn unhidden_async_time_is_exposed_at_fences() {
        // a program with a huge async gather and almost no compute:
        // overlap cannot hide it, so epoch time must match serial
        let d = tiny_test_dataset(202);
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Gather {
            vertices: (0..400u32).collect(),
            overlap: true,
        });
        b.allreduce();
        let prog = b.finish();
        let off = EpochDriver::run(
            &SimEnv::new(&d, RunConfig {
                num_servers: 2,
                overlap: false,
                parallel_lanes: false,
                ..Default::default()
            }),
            &prog,
        );
        let on = EpochDriver::run(
            &SimEnv::new(&d, RunConfig {
                num_servers: 2,
                overlap: true,
                parallel_lanes: false,
                ..Default::default()
            }),
            &prog,
        );
        assert!((on.epoch_time - off.epoch_time).abs() < 1e-12,
                "nothing to hide behind: {} vs {}",
                on.epoch_time, off.epoch_time);
        assert_eq!(on.time_overlap_hidden, 0.0);
    }

    /// Two identical cache-routed gathers on server 0 + an allreduce.
    /// No compute: in overlap mode the pending stream is fully exposed
    /// at the allreduce fence, so any hit shows up in the epoch time.
    fn cache_program(overlap: bool) -> Program {
        let mut b = ProgramBuilder::new(2);
        for _ in 0..2 {
            b.op(0, Op::CacheFetch {
                steps: vec![(0..400u32).collect()],
                overlap,
            });
        }
        b.allreduce();
        b.finish()
    }

    fn cache_cfg(policy: CachePolicy, mb: usize, overlap: bool) -> RunConfig {
        RunConfig {
            num_servers: 2,
            overlap,
            parallel_lanes: false,
            cache_policy: policy,
            cache_mb: mb,
            ..Default::default()
        }
    }

    #[test]
    fn cache_hits_skip_transfers_in_serial_and_overlap_lanes() {
        let d = tiny_test_dataset(205);
        for overlap in [false, true] {
            let prog = cache_program(overlap);
            let cold = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 0, overlap)),
                &prog,
            );
            let warm = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 64, overlap)),
                &prog,
            );
            // capacity 0 never hits; 64 MiB holds the whole remote set,
            // so the second gather is all hits: half the feature bytes
            assert_eq!(cold.cache_hits, 0);
            assert!(warm.cache_hits > 0);
            assert_eq!(warm.cache_hits, warm.cache_misses);
            assert_eq!(
                2 * warm.bytes(TransferKind::Feature),
                cold.bytes(TransferKind::Feature),
                "overlap={overlap}: warm cache must halve feature bytes"
            );
            // byte conservation: requested = skipped + transferred
            assert_eq!(
                warm.cache_hit_bytes + warm.cache_miss_bytes,
                cold.cache_miss_bytes,
                "overlap={overlap}"
            );
            assert_eq!(warm.cache_miss_bytes,
                       warm.bytes(TransferKind::Feature));
            assert!(
                warm.epoch_time < cold.epoch_time,
                "overlap={overlap}: hits must shrink the epoch \
                 ({} !< {})",
                warm.epoch_time,
                cold.epoch_time
            );
        }
    }

    #[test]
    fn capacity_zero_cache_matches_uncached_gather_bitwise() {
        let d = tiny_test_dataset(206);
        for overlap in [false, true] {
            // the uncached twin of `cache_program`: plain gathers,
            // op-for-op identical otherwise
            let mut b = ProgramBuilder::new(2);
            for _ in 0..2 {
                b.op(0, Op::Gather {
                    vertices: (0..400u32).collect(),
                    overlap,
                });
            }
            b.allreduce();
            let plain = b.finish();
            let off = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::None, 64, overlap)),
                &plain,
            );
            let zero = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 0, overlap)),
                &cache_program(overlap),
            );
            assert_eq!(off.total_bytes(), zero.total_bytes());
            assert_eq!(off.epoch_time.to_bits(), zero.epoch_time.to_bits());
            assert_eq!(off.time_gather.to_bits(), zero.time_gather.to_bits());
            assert_eq!(off.remote_vertices, zero.remote_vertices);
            assert_eq!(off.local_hits, zero.local_hits);
            assert_eq!(zero.cache_hits, 0);
        }
    }

    #[test]
    fn parallel_lanes_bit_identical_with_cache_enabled() {
        let d = tiny_test_dataset(207);
        let prog = demo_cache_lanes();
        let cfg = |parallel| RunConfig {
            num_servers: 4,
            parallel_lanes: parallel,
            cache_policy: CachePolicy::Lru,
            cache_mb: 4,
            ..Default::default()
        };
        let env_seq = SimEnv::new(&d, cfg(false));
        let env_par = SimEnv::new(&d, cfg(true));
        let seq = EpochDriver::run_inner(&env_seq, &prog, Some(false));
        let par = EpochDriver::run_inner(&env_par, &prog, Some(true));
        assert_eq!(seq.total_bytes(), par.total_bytes());
        assert_eq!(seq.epoch_time.to_bits(), par.epoch_time.to_bits());
        assert_eq!(seq.cache_hits, par.cache_hits);
        assert_eq!(seq.cache_hit_bytes, par.cache_hit_bytes);
        assert_eq!(seq.cache_evict_bytes, par.cache_evict_bytes);
        assert!(seq.cache_hits > 0, "warm rows must hit on the re-fetch");
    }

    /// Four lanes, each fetching overlapping windows twice through the
    /// cache, so every lane produces both misses and hits.
    fn demo_cache_lanes() -> Program {
        let mut b = ProgramBuilder::new(4);
        for round in 0..2u32 {
            for s in 0..4 {
                let lo = (s as u32 * 50 + round * 25) % 300;
                b.op(s, Op::CacheFetch {
                    steps: vec![(lo..lo + 100).collect()],
                    overlap: false,
                });
                b.op(s, Op::Compute { v: 100, e: 600 });
            }
            b.barrier();
        }
        b.allreduce();
        b.finish()
    }

    #[test]
    fn straggler_fabric_scales_compute_per_server() {
        use crate::cluster::FabricSpec;
        let d = tiny_test_dataset(208);
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Compute { v: 400, e: 2400 });
        b.op(1, Op::Compute { v: 400, e: 2400 });
        let prog = b.finish();
        let mk = |fabric| {
            SimEnv::new(&d, RunConfig {
                num_servers: 2,
                parallel_lanes: false,
                fabric,
                ..Default::default()
            })
        };
        let uni = EpochDriver::run(&mk(FabricSpec::Uniform), &prog);
        let strag =
            EpochDriver::run(&mk(FabricSpec::Straggler { server: 0 }), &prog);
        // server 0 computes at half speed; same work, twice the time
        assert!(
            (strag.epoch_time - 2.0 * uni.epoch_time).abs()
                < 1e-12 * uni.epoch_time,
            "straggler epoch {} != 2x uniform {}",
            strag.epoch_time,
            uni.epoch_time
        );
        assert_eq!(strag.per_server_busy.len(), 2);
        assert!(
            (strag.per_server_busy[0] - 2.0 * strag.per_server_busy[1])
                .abs()
                < 1e-12 * strag.per_server_busy[1],
            "observed lane times must expose the straggler"
        );
        // uniform fabric: busy times match exactly (bit parity)
        assert_eq!(
            uni.per_server_busy[0].to_bits(),
            uni.per_server_busy[1].to_bits()
        );
    }

    #[test]
    fn warm_tiers_carry_across_driver_sessions() {
        let d = tiny_test_dataset(209);
        let env = SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 64, false));
        let prog = cache_program(false);
        // session 1 starts cold: first fetch misses, re-fetch hits
        let mut s1 = EpochDriver::new(&env);
        s1.exec(&prog);
        let (m1, tiers) = s1.finish_session();
        assert!(m1.cache_hits > 0);
        assert!(m1.cache_misses > 0);
        // session 2 seeded with session 1's stacks: every fetch hits
        let mut s2 = EpochDriver::with_tiers(&env, tiers);
        s2.exec(&prog);
        let (m2, _) = s2.finish_session();
        assert_eq!(m2.cache_misses, 0, "warm session must not re-fetch");
        assert!(m2.cache_hits > m1.cache_hits);
        assert!(m2.epoch_time < m1.epoch_time);
        // a fresh session still starts cold (persistence is opt-in)
        let m3 = EpochDriver::run(&env, &prog);
        assert_eq!(m3.cache_hits, m1.cache_hits);
    }

    #[test]
    fn tier_kind_prices_the_hit_hbm_free_ssd_flash() {
        use crate::featstore::tier::TierSpec;
        let d = tiny_test_dataset(210);
        let cfg = |tiers: &str| RunConfig {
            tiers: Some(TierSpec::parse(tiers).unwrap()),
            ..cache_cfg(CachePolicy::None, 0, false)
        };
        let prog = cache_program(false);
        let run = |spec| EpochDriver::run(&SimEnv::new(&d, cfg(spec)), &prog);
        let hbm = run("hbm:64m:lru+remote");
        let dram = run("dram:64m:lru+remote");
        let ssd = run("ssd:64m:lru+remote");
        // same residency trajectory, different per-hit price
        assert!(hbm.cache_hits > 0);
        assert_eq!(hbm.cache_hits, dram.cache_hits);
        assert_eq!(dram.cache_hits, ssd.cache_hits);
        assert!(
            hbm.epoch_time < dram.epoch_time,
            "hbm hits skip staging: {} !< {}",
            hbm.epoch_time,
            dram.epoch_time
        );
        assert!(
            dram.epoch_time < ssd.epoch_time,
            "ssd hits pay the flash read: {} !< {}",
            dram.epoch_time,
            ssd.epoch_time
        );
        // per-tier accounting lands in the right slots
        assert_eq!(hbm.tier_hits[TierKind::Hbm.index()], hbm.cache_hits);
        assert_eq!(dram.tier_hits[TierKind::Dram.index()], dram.cache_hits);
        assert_eq!(ssd.tier_hits[TierKind::Ssd.index()], ssd.cache_hits);
        assert_eq!(
            dram.tier_hits[TierKind::Remote.index()],
            dram.cache_misses
        );
        // bytes conserved across the tier view too
        assert_eq!(
            dram.tier_hit_bytes.iter().sum::<u64>(),
            dram.cache_hit_bytes + dram.cache_miss_bytes
        );
    }

    #[test]
    fn untimed_phase_charges_clock_but_no_metric() {
        let d = tiny_test_dataset(203);
        let mut b = ProgramBuilder::new(2);
        b.op(1, Op::Migrate {
            from: 0,
            kind: TransferKind::Control,
            bytes: 4096,
            phase: Phase::Untimed,
            overlap: false,
        });
        let prog = b.finish();
        let env = SimEnv::new(&d, RunConfig {
            num_servers: 2,
            ..Default::default()
        });
        let m = EpochDriver::run(&env, &prog);
        assert!(m.epoch_time > 0.0);
        assert_eq!(m.bytes(TransferKind::Control), 4096);
        let phases = m.time_sample + m.time_gather + m.time_compute
            + m.time_migrate + m.time_sync;
        assert_eq!(phases, 0.0);
    }
}
