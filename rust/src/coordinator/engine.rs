//! The shared epoch execution engine.
//!
//! [`EpochDriver::run`] executes a strategy-built [`Program`] against
//! the cluster substrate — per-server [`Clocks`], exact [`NetStats`]
//! byte accounting, and [`EpochMetrics`] — in one place. Strategies are
//! pure schedule builders; everything that used to be six hand-rolled
//! epoch loops (clock lifecycle, gather execution, migration timing,
//! allreduce, validation) lives here.
//!
//! ## Parallel per-server simulation
//!
//! Each [`Item::Lanes`] executes one op lane per server. Lanes are
//! independent by construction (an op only touches its own server's
//! clock; byte records are pure sums), so the driver dispatches them
//! to a session-persistent [`crate::util::pool::LanePool`]: parked
//! worker threads created once per session (or carried across epochs
//! by the strategy, see [`SessionState`]), woken per fragment to claim
//! lane indices off an atomic word, with the dispatching thread
//! claiming alongside them. Lane results land in each lane's
//! [`LaneScratch`] result slot and are reduced in server order after
//! the fragment drains, so parallel execution is **bit-identical** to
//! sequential execution — `deterministic` tests hold with lanes
//! enabled, and `tests/parity.rs` / `tests/fabric_parity.rs` lock it.
//!
//! The pool engages when [`crate::config::RunConfig::parallel_lanes`]
//! is on, the fragment's summed [`Op::weight`] reaches the dispatch
//! threshold (`HOPGNN_PARALLEL_THRESHOLD`, default
//! [`DEFAULT_PARALLEL_WORK_THRESHOLD`]), and the
//! [`crate::util::pool::lane_allowance`] grants this driver more than
//! one thread — inside `bench sweep --jobs N` that allowance is the
//! driver's deterministic share of the `--jobs` budget, so nested
//! cell × lane parallelism never oversubscribes. [`LaneDispatch`]
//! forces a mode explicitly: the parity tests pin `Serial`/`Pool`, and
//! the `engine.lanes_dispatch` hot-path bench keeps the legacy
//! `SpawnPerItem` path around to measure what the pool saves.
//!
//! ## Gather/compute overlap
//!
//! With [`RunConfig::overlap`] enabled, transfer ops flagged
//! `overlap: true` become *asynchronous*: their seconds accumulate in a
//! per-lane pending buffer instead of the clock, and subsequent compute
//! on the same lane drains (hides) the pending time — the steady-state
//! pipelining idealization (P³'s push-pull behind compute, HopGNN's
//! pre-gather as prefetch, RapidGNN-style deterministic fetch overlap).
//! Whatever compute cannot hide is exposed to the clock at the next
//! allreduce (gradient sync is a hard fence) or at epoch end. Byte
//! accounting is unaffected: overlap changes *when* time is charged,
//! never how many bytes move. With the knob off, every op is charged
//! inline and the driver reproduces the historical eager loops'
//! accounting exactly.
//!
//! ## The tiered feature store
//!
//! The driver owns one [`TierStack`] per server lane (built from
//! [`crate::config::RunConfig::tiers`] — or the legacy
//! `cache_policy`/`cache_mb` two-tier alias — or handed in warm via
//! [`EpochDriver::with_tiers`] when
//! [`crate::config::RunConfig::cache_persist`] keeps them alive across
//! epochs). [`Op::CacheFetch`] ops resolve their request through the
//! lane's tier stack before touching the network: each hit is priced
//! by the tier that holds the row (hbm free, dram staged, ssd staged +
//! flash read — see [`crate::featstore::tier`]) and moves zero network
//! bytes — in both serial and overlap modes, so with overlap on a hit
//! also never enters the async pending stream — while full misses cost
//! exactly what the equivalent `GatherMerged` would and are admitted
//! per the stack's placement policies. Stacks are lane-private,
//! keeping parallel lane execution bit-identical to sequential; the
//! single-dram stack reproduces the legacy cache bit-for-bit and a
//! capacity-0 stack the uncached driver (`tests/cache_parity.rs`,
//! `tests/tier_parity.rs`). [`EpochDriver::finish_session`] returns
//! the stacks so a strategy can carry them into its next epoch.
//!
//! ## The cluster fabric
//!
//! All lane costs are priced by the env's [`crate::cluster::Fabric`]:
//! transfer ops charge the per-(src, dst)-link time, and compute ops'
//! seconds are divided by the executing server's compute-speed
//! multiplier. On the `uniform` fabric both are bit-identical to the
//! historical scalar model (`tests/fabric_parity.rs`).

use super::ops::{Item, Op, Phase, Program};
use super::SimEnv;
use crate::cluster::{Clocks, NetStats};
use crate::featstore::pregather::{PlanScratch, PregatherPlan};
use crate::featstore::tier::{TierKind, TierStack, NUM_TIER_KINDS};
use crate::featstore::{FeatureStore, GatherPlan};
use crate::metrics::EpochMetrics;
use crate::util::pool::{self, IndexedCells, LanePool};
use crate::util::stamp::StampedSet;

/// Default minimum summed [`Op::weight`] in a lane set before the
/// driver dispatches it to the lane pool (below this, sequential
/// execution is faster). The pre-pool spawn-per-fragment driver needed
/// 4096 to amortize `std::thread::scope` spawn+join; pool dispatch
/// (unpark + atomic claim) is over an order of magnitude cheaper per
/// fragment — measured by the `engine.lanes_dispatch` hot-path bench —
/// so small-but-frequent lane sets now parallelize too.
pub const DEFAULT_PARALLEL_WORK_THRESHOLD: usize = 1024;

/// The dispatch threshold, overridable via the
/// `HOPGNN_PARALLEL_THRESHOLD` environment variable (read once per
/// process; `0` parallelizes every multi-lane fragment). Both sides of
/// the threshold are bit-identical by construction — the override is a
/// wall-clock tuning knob only.
fn parallel_work_threshold() -> usize {
    static CACHED: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CACHED.get_or_init(|| {
        std::env::var("HOPGNN_PARALLEL_THRESHOLD")
            .ok()
            .and_then(|v| v.trim().parse().ok())
            .unwrap_or(DEFAULT_PARALLEL_WORK_THRESHOLD)
    })
}

/// How an [`EpochDriver`] executes multi-lane fragments. `Auto` is the
/// production mode; the forced modes exist so parity tests and the
/// dispatch bench can pin a mechanism regardless of config, work size,
/// or the machine's lane allowance.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LaneDispatch {
    /// [`crate::config::RunConfig::parallel_lanes`], the work
    /// threshold, and the lane allowance decide per fragment.
    #[default]
    Auto,
    /// Always sequential, regardless of config.
    Serial,
    /// Always the persistent lane pool, sized one thread per server
    /// (ignoring the budget allowance).
    Pool,
    /// Legacy pre-pool path: `std::thread::scope` spawn per fragment.
    /// Kept for the `engine.lanes_dispatch` bench comparison.
    SpawnPerItem,
}

/// Cross-epoch driver state a strategy can thread between sessions via
/// [`EpochDriver::finish_state`] / [`DriverBuilder`]: the per-lane
/// feature tier stacks (warm rows, when
/// [`crate::config::RunConfig::cache_persist`] wants them) and the
/// persistent lane pool (so a whole training run pays the lane-worker
/// spawn cost once, not once per epoch).
pub struct SessionState {
    pub tiers: Vec<TierStack>,
    pub pool: Option<LanePool>,
}

/// One epoch's execution session. Strategies stream [`Program`]
/// fragments (typically one per iteration) through [`Self::exec`] so
/// the materialized op working set stays O(one iteration) — the same
/// footprint the historical eager loops had — then close the session
/// with [`Self::finish`]. [`Self::run`] is the one-shot convenience
/// for a fully materialized program.
pub struct EpochDriver<'e, 'a> {
    env: &'e SimEnv<'a>,
    store: FeatureStore<'e>,
    clocks: Clocks,
    stats: NetStats,
    m: EpochMetrics,
    /// Per-server asynchronous transfer time not yet hidden or exposed.
    pending: Vec<f64>,
    /// One feature tier stack per server lane (an empty remote-only
    /// stack with the tiers off). A stack is only ever touched by its
    /// own lane, so parallel lane execution stays bit-identical to
    /// sequential.
    tiers: Vec<TierStack>,
    /// One reusable execution scratch per server lane (accounting
    /// deltas + gather-planning buffers + the lane result slot), reset
    /// per lane run instead of reallocated — the driver-side half of
    /// the zero-allocation iteration hot path, in every dispatch mode.
    scratch: Vec<LaneScratch>,
    dispatch: LaneDispatch,
    /// The persistent lane workers, created lazily on the first
    /// fragment that wants them (or handed in warm via the builder).
    pool: Option<LanePool>,
    /// Set when pool creation was declined (lane allowance of 1), so
    /// the decision is made once per session, not per fragment.
    no_pool: bool,
}

/// Builder-style construction for [`EpochDriver`] sessions: optional
/// warm [`SessionState`] pieces (tier stacks, lane pool) and an
/// optional forced [`LaneDispatch`]. Replaces the old positional
/// `Option` threading that tests used to force lane modes.
pub struct DriverBuilder<'e, 'a> {
    env: &'e SimEnv<'a>,
    tiers: Option<Vec<TierStack>>,
    pool: Option<LanePool>,
    dispatch: LaneDispatch,
}

impl<'e, 'a> DriverBuilder<'e, 'a> {
    /// Seed the session with warm feature tier stacks carried over
    /// from a previous epoch (the `--cache-persist` path; see
    /// [`EpochDriver::finish_state`]).
    pub fn tiers(mut self, tiers: Vec<TierStack>) -> Self {
        // hard assert: lane execution zips lanes with tier stacks, so
        // a wrong length would silently drop server lanes in release
        assert_eq!(
            tiers.len(),
            self.env.num_servers(),
            "persisted tier stacks do not match the env's server count"
        );
        self.tiers = Some(tiers);
        self
    }

    /// Reuse a lane pool from a previous session instead of spawning
    /// fresh workers.
    pub fn pool(mut self, pool: LanePool) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Force a lane dispatch mode (parity tests, the dispatch bench).
    pub fn dispatch(mut self, dispatch: LaneDispatch) -> Self {
        self.dispatch = dispatch;
        self
    }

    pub fn build(self) -> EpochDriver<'e, 'a> {
        let env = self.env;
        let n = env.num_servers();
        EpochDriver {
            env,
            store: env.store(),
            clocks: Clocks::new(n),
            stats: NetStats::new(n),
            m: EpochMetrics::default(),
            pending: vec![0.0f64; n],
            tiers: self.tiers.unwrap_or_else(|| env.build_tiers()),
            scratch: (0..n).map(|_| LaneScratch::new(n)).collect(),
            dispatch: self.dispatch,
            pool: self.pool,
            no_pool: false,
        }
    }

    /// One-shot convenience: build, execute `program`, finish.
    pub fn run(self, program: &Program) -> EpochMetrics {
        let mut driver = self.build();
        driver.exec(program);
        driver.finish()
    }
}

impl<'e, 'a> EpochDriver<'e, 'a> {
    pub fn builder(env: &'e SimEnv<'a>) -> DriverBuilder<'e, 'a> {
        DriverBuilder {
            env,
            tiers: None,
            pool: None,
            dispatch: LaneDispatch::Auto,
        }
    }

    pub fn new(env: &'e SimEnv<'a>) -> Self {
        Self::builder(env).build()
    }

    /// `new` with warm feature tier stacks carried over from a
    /// previous epoch session (the `--cache-persist` path; see
    /// [`Self::finish_session`]).
    pub fn with_tiers(env: &'e SimEnv<'a>, tiers: Vec<TierStack>) -> Self {
        Self::builder(env).tiers(tiers).build()
    }

    /// Execute one schedule fragment against the session state.
    pub fn exec(&mut self, program: &Program) {
        let n = self.env.num_servers();
        debug_assert_eq!(n, program.num_servers, "program/env server count");
        for item in &program.items {
            match item {
                Item::Lanes(lanes) => {
                    let active =
                        lanes.iter().filter(|l| !l.is_empty()).count();
                    let wanted = active > 1
                        && match self.dispatch {
                            LaneDispatch::Serial => false,
                            LaneDispatch::Pool
                            | LaneDispatch::SpawnPerItem => true,
                            LaneDispatch::Auto => {
                                self.env.cfg.parallel_lanes && {
                                    let work: usize = lanes
                                        .iter()
                                        .flat_map(|l| {
                                            l.iter().map(Op::weight)
                                        })
                                        .sum();
                                    work >= parallel_work_threshold()
                                }
                            }
                        };
                    if wanted
                        && self.dispatch == LaneDispatch::SpawnPerItem
                    {
                        exec_lanes_spawn(
                            self.env,
                            &self.store,
                            lanes,
                            &mut self.clocks,
                            &mut self.stats,
                            &mut self.m,
                            &mut self.pending,
                            &mut self.tiers,
                            &mut self.scratch,
                        );
                        continue;
                    }
                    let pool = if wanted {
                        ensure_pool(
                            &mut self.pool,
                            &mut self.no_pool,
                            n,
                            self.dispatch == LaneDispatch::Pool,
                        )
                    } else {
                        None
                    };
                    match pool {
                        Some(pool) => exec_lanes_pool(
                            pool,
                            self.env,
                            &self.store,
                            lanes,
                            &mut self.clocks,
                            &mut self.stats,
                            &mut self.m,
                            &mut self.pending,
                            &mut self.tiers,
                            &mut self.scratch,
                        ),
                        None => exec_lanes_serial(
                            self.env,
                            &self.store,
                            lanes,
                            &mut self.clocks,
                            &mut self.stats,
                            &mut self.m,
                            &mut self.pending,
                            &mut self.tiers,
                            &mut self.scratch,
                        ),
                    }
                }
                Item::Barrier => {
                    // async transfers keep flowing while a server waits
                    // at the barrier: the idle gap up to the slowest
                    // server absorbs pending transfer time. (With
                    // overlap off, pending is always zero.)
                    let max = self.clocks.max();
                    for s in 0..n {
                        let gap = max - self.clocks.now(s);
                        let hide = self.pending[s].min(gap);
                        if hide > 0.0 {
                            self.pending[s] -= hide;
                            self.m.time_overlap_hidden += hide;
                        }
                    }
                    self.clocks.barrier();
                }
                Item::SyncAll => {
                    for s in 0..n {
                        self.clocks.advance(s, self.env.cfg.cost.t_sync);
                    }
                    self.m.time_sync += self.env.cfg.cost.t_sync;
                }
                Item::Allreduce => {
                    // gradient sync is a hard fence: expose whatever
                    // async transfer time compute and idle could not hide
                    expose_pending(&mut self.clocks, &mut self.pending);
                    self.env.allreduce_grads(
                        &mut self.clocks,
                        &mut self.stats,
                        &mut self.m,
                    );
                }
            }
        }
    }

    /// Close the session: expose leftover async time, validate byte and
    /// message conservation ([`NetStats::validate`] runs on *every*
    /// session close, bench runs included), and return the epoch's
    /// metrics (times, exact bytes, counters, busy fraction).
    ///
    /// The caller (strategy) still owns schedule-level metrics:
    /// `iterations`, `time_steps_per_iter`, and `dropped_roots` are not
    /// known here.
    pub fn finish(self) -> EpochMetrics {
        self.finish_state().0
    }

    /// [`Self::finish`] that also hands the per-lane tier stacks
    /// back, so a strategy running with
    /// [`crate::config::RunConfig::cache_persist`] can seed its next
    /// epoch's session via [`Self::with_tiers`]. (The lane pool is
    /// dropped; use [`Self::finish_state`] to keep it too.)
    pub fn finish_session(self) -> (EpochMetrics, Vec<TierStack>) {
        let (m, state) = self.finish_state();
        (m, state.tiers)
    }

    /// [`Self::finish`] that hands back everything worth carrying into
    /// the next epoch's session ([`SessionState`]): the tier stacks
    /// and the persistent lane pool, re-seeded through
    /// [`DriverBuilder::tiers`] / [`DriverBuilder::pool`].
    pub fn finish_state(mut self) -> (EpochMetrics, SessionState) {
        expose_pending(&mut self.clocks, &mut self.pending);
        self.stats.validate().expect("byte accounting");
        self.m.absorb_net(&self.stats);
        self.m.epoch_time = self.clocks.max();
        self.m.gpu_busy_fraction = self.clocks.busy_fraction();
        self.m.per_server_busy = (0..self.env.num_servers())
            .map(|s| self.clocks.busy_time(s))
            .collect();
        (
            self.m,
            SessionState {
                tiers: self.tiers,
                pool: self.pool,
            },
        )
    }

    /// One-shot: execute `program` in a fresh session and finish.
    pub fn run(env: &SimEnv, program: &Program) -> EpochMetrics {
        Self::builder(env).run(program)
    }
}

/// Create (once per session) the lane pool for a driver that decided
/// to parallelize. `forced` ([`LaneDispatch::Pool`]) sizes one thread
/// per server regardless of the budget allowance; `Auto` respects
/// [`pool::lane_allowance`] and declines (serial fallback, remembered
/// in `no_pool`) when the allowance grants a single thread.
fn ensure_pool<'p>(
    pool: &'p mut Option<LanePool>,
    no_pool: &mut bool,
    num_servers: usize,
    forced: bool,
) -> Option<&'p mut LanePool> {
    if pool.is_none() && !*no_pool {
        let threads = if forced {
            num_servers
        } else {
            num_servers.min(pool::lane_allowance())
        };
        if threads > 1 {
            // the dispatching thread claims lanes too, so spawn one
            // fewer worker than the thread allowance
            *pool = Some(LanePool::new(threads - 1));
        } else {
            *no_pool = true;
        }
    }
    pool.as_mut()
}

fn expose_pending(clocks: &mut Clocks, pending: &mut [f64]) {
    for (s, p) in pending.iter_mut().enumerate() {
        if *p > 0.0 {
            clocks.advance(s, *p);
            *p = 0.0;
        }
    }
}

/// Reusable per-lane execution state: the lane-local accounting deltas
/// (`stats`, `m`) plus every gather-planning buffer a lane's ops need
/// (`seen`/`plan` for plain and cache-routed gathers, `ps`/`pre` for
/// merged pre-gathers). One scratch belongs to one server lane for the
/// whole driver session — like the caches, it is only ever touched by
/// its own lane, so parallel execution stays bit-identical — and is
/// reset (keeping capacity) at the start of each lane run, so
/// steady-state lane execution allocates nothing.
struct LaneScratch {
    stats: NetStats,
    m: EpochMetrics,
    seen: StampedSet,
    plan: GatherPlan,
    pre: PregatherPlan,
    ps: PlanScratch,
    /// The lane run's `(clock, busy_dt, pending)` result, written by
    /// whichever thread ran the lane and reduced in server order by
    /// the dispatcher — a reused slot, so parallel dispatch allocates
    /// nothing either.
    out: (f64, f64, f64),
}

impl LaneScratch {
    fn new(num_servers: usize) -> Self {
        Self {
            stats: NetStats::new(num_servers),
            m: EpochMetrics::default(),
            seen: StampedSet::default(),
            plan: GatherPlan::default(),
            pre: PregatherPlan::default(),
            ps: PlanScratch::default(),
            out: (0.0, 0.0, 0.0),
        }
    }
}

/// Deterministic lane reduction: server order, independent of which
/// thread finished which lane first — the property that makes every
/// parallel mode bit-identical to sequential execution.
fn reduce_lanes(
    clocks: &mut Clocks,
    stats: &mut NetStats,
    m: &mut EpochMetrics,
    pending: &mut [f64],
    scratches: &[LaneScratch],
) {
    for (s, scratch) in scratches.iter().enumerate() {
        let (t, busy_dt, pend) = scratch.out;
        clocks.set(s, t);
        clocks.add_busy(s, busy_dt);
        stats.merge(&scratch.stats);
        m.accumulate(&scratch.m);
        pending[s] = pend;
    }
}

/// Run + reduce inline per lane, in server order. Lanes never read
/// another lane's clock, pending slot, or the global accumulators, so
/// reducing lane s before running lane s+1 is bit-identical to the
/// run-all-then-reduce parallel paths.
#[allow(clippy::too_many_arguments)]
fn exec_lanes_serial(
    env: &SimEnv,
    store: &FeatureStore,
    lanes: &[Vec<Op>],
    clocks: &mut Clocks,
    stats: &mut NetStats,
    m: &mut EpochMetrics,
    pending: &mut [f64],
    tiers: &mut [TierStack],
    scratches: &mut [LaneScratch],
) {
    for (s, (ops, (stack, scratch))) in lanes
        .iter()
        .zip(tiers.iter_mut().zip(scratches.iter_mut()))
        .enumerate()
    {
        let (t, busy_dt, pend) = run_lane(
            env,
            store,
            s,
            ops,
            clocks.now(s),
            pending[s],
            stack,
            scratch,
        );
        clocks.set(s, t);
        clocks.add_busy(s, busy_dt);
        stats.merge(&scratch.stats);
        m.accumulate(&scratch.m);
        pending[s] = pend;
    }
}

/// Dispatch the fragment to the session's persistent lane pool: the
/// parked workers plus this thread claim lane indices, write results
/// into the per-lane scratch slots, and the fragment is reduced in
/// server order once it drains.
#[allow(clippy::too_many_arguments)]
fn exec_lanes_pool(
    pool: &mut LanePool,
    env: &SimEnv,
    store: &FeatureStore,
    lanes: &[Vec<Op>],
    clocks: &mut Clocks,
    stats: &mut NetStats,
    m: &mut EpochMetrics,
    pending: &mut [f64],
    tiers: &mut [TierStack],
    scratches: &mut [LaneScratch],
) {
    {
        let clocks_ro: &Clocks = clocks;
        let pending_ro: &[f64] = pending;
        let tier_cells = IndexedCells::new(tiers);
        let scratch_cells = IndexedCells::new(scratches);
        pool.run(lanes.len(), &|s: usize| {
            // safety: the pool's claim loop hands each lane index to
            // exactly one thread per dispatch
            let stack = unsafe { tier_cells.get(s) };
            let scratch = unsafe { scratch_cells.get(s) };
            let out = run_lane(
                env,
                store,
                s,
                &lanes[s],
                clocks_ro.now(s),
                pending_ro[s],
                stack,
                &mut *scratch,
            );
            scratch.out = out;
        });
    }
    reduce_lanes(clocks, stats, m, pending, scratches);
}

/// Legacy parallel path: one `std::thread::scope` spawn per lane, per
/// fragment. Only reachable via [`LaneDispatch::SpawnPerItem`] — kept
/// so the `engine.lanes_dispatch` bench can measure what the pool
/// saves, and as a parity reference for the spawn-era semantics.
#[allow(clippy::too_many_arguments)]
fn exec_lanes_spawn(
    env: &SimEnv,
    store: &FeatureStore,
    lanes: &[Vec<Op>],
    clocks: &mut Clocks,
    stats: &mut NetStats,
    m: &mut EpochMetrics,
    pending: &mut [f64],
    tiers: &mut [TierStack],
    scratches: &mut [LaneScratch],
) {
    std::thread::scope(|scope| {
        for (s, (ops, (stack, scratch))) in lanes
            .iter()
            .zip(tiers.iter_mut().zip(scratches.iter_mut()))
            .enumerate()
        {
            let t0 = clocks.now(s);
            let p0 = pending[s];
            scope.spawn(move || {
                scratch.out = run_lane(
                    env,
                    store,
                    s,
                    ops,
                    t0,
                    p0,
                    stack,
                    &mut *scratch,
                );
            });
        }
    });
    reduce_lanes(clocks, stats, m, pending, scratches);
}

/// Execute one server's ops starting from clock `t0` and async-pending
/// `pending0`. Pure with respect to shared state: reads only shared
/// immutable state, writes only lane-local accumulators (the feature
/// tier `stack` and the `scratch` belong to this lane alone). Returns
/// `(t, busy_dt, pending)`; the accounting deltas are left in the
/// scratch for the caller to reduce.
#[allow(clippy::too_many_arguments)]
fn run_lane(
    env: &SimEnv,
    store: &FeatureStore,
    server: usize,
    ops: &[Op],
    t0: f64,
    pending0: f64,
    stack: &mut TierStack,
    scratch: &mut LaneScratch,
) -> (f64, f64, f64) {
    let cfg = &env.cfg;
    let overlap_on = cfg.overlap;
    // heterogeneous compute: this server's cost-model seconds divide by
    // its fabric speed multiplier (1.0 on a uniform fabric — and
    // `x / 1.0` is bitwise `x`, preserving uniform parity)
    let speed = env.fabric.compute_speed(server);
    let mut t = t0;
    let mut busy_dt = 0.0f64;
    let mut pending = pending0;
    let LaneScratch {
        stats,
        m,
        seen,
        plan,
        pre,
        ps,
        // `out` is the caller's result slot, written after this returns
        ..
    } = scratch;
    stats.reset();
    m.reset();

    let charge_compute = |dt: f64,
                          t: &mut f64,
                          busy_dt: &mut f64,
                          pending: &mut f64,
                          m: &mut EpochMetrics| {
        *t += dt;
        *busy_dt += dt;
        m.time_compute += dt;
        if overlap_on && *pending > 0.0 {
            // async transfers proceed while the GPU computes
            let hidden = pending.min(dt);
            *pending -= hidden;
            m.time_overlap_hidden += hidden;
        }
    };

    // one place decides whether transfer seconds go to the clock or
    // the async-pending stream (Gather, GatherMerged, and Migrate all
    // share these semantics)
    let charge_transfer = |dt: f64,
                           phase: Phase,
                           async_ok: bool,
                           t: &mut f64,
                           pending: &mut f64,
                           m: &mut EpochMetrics| {
        phase_add(m, phase, dt);
        if overlap_on && async_ok {
            *pending += dt;
        } else {
            *t += dt;
        }
    };

    for op in ops {
        match op {
            Op::Sample { vertices } => {
                let dt = cfg.cost.sample_time(*vertices);
                t += dt;
                m.time_sample += dt;
            }
            Op::Gather { vertices, overlap } => {
                store.plan_into(server, vertices.iter().copied(), seen, plan);
                let dt = store.sim_cost(
                    plan,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::GatherMerged { steps, overlap } => {
                PregatherPlan::build_into(store, server, steps, ps, pre);
                let dt = store.sim_cost(
                    &pre.merged,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::CacheFetch { steps, overlap } => {
                // walk this lane's tier stack: hits are served (and
                // priced) by the tier that holds the row — hbm free,
                // dram staged, ssd staged + flash — skipping the
                // transfer (and, in overlap mode, the pending stream);
                // the residual plan fetches exactly like a merged
                // gather and is admitted per the placement policies
                let deltas =
                    stack.resolve_into(store, server, steps, seen, plan);
                let fb = store.feat_bytes;
                let hits = deltas.cache_hits();
                let remote = plan.remote_count();
                let mut dt = store.sim_cost_cached(
                    plan,
                    deltas.staged_hit_rows,
                    &env.fabric,
                    &cfg.cost,
                    stats,
                    m,
                );
                // gated so stacks without flash add no float ops to
                // the legacy cost path (x + 0.0 is not bitwise id)
                let ssd = deltas.ssd_seconds(fb);
                if ssd > 0.0 {
                    dt += ssd;
                }
                m.cache_hits += hits;
                m.cache_misses += remote;
                m.cache_hit_bytes += hits * fb;
                m.cache_miss_bytes += remote * fb;
                m.cache_evict_bytes += deltas.evicted_bytes;
                for k in 0..NUM_TIER_KINDS {
                    m.tier_hits[k] += deltas.hits_at[k];
                    m.tier_hit_bytes[k] += deltas.hits_at[k] * fb;
                    m.tier_miss_bytes[k] += deltas.misses_at[k] * fb;
                    m.tier_promote_bytes[k] += deltas.promote_bytes_at[k];
                    m.tier_demote_bytes[k] += deltas.demote_bytes_at[k];
                }
                // the backstop never misses: residual fetches are
                // remote-tier hits in the per-tier view
                let ri = TierKind::Remote.index();
                m.tier_hits[ri] += remote;
                m.tier_hit_bytes[ri] += remote * fb;
                charge_transfer(
                    dt,
                    Phase::Gather,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Compute { v, e } => {
                let dt = cfg.cost.train_time(&env.shape, *v, *e) / speed;
                charge_compute(
                    dt,
                    &mut t,
                    &mut busy_dt,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::ComputeSecs { secs } => {
                charge_compute(
                    *secs / speed,
                    &mut t,
                    &mut busy_dt,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Migrate {
                from,
                kind,
                bytes,
                phase,
                overlap,
            } => {
                let dt =
                    stats.record(&env.fabric, *from, server, *bytes, *kind);
                charge_transfer(
                    dt,
                    *phase,
                    *overlap,
                    &mut t,
                    &mut pending,
                    &mut *m,
                );
            }
            Op::Host { secs, phase } => {
                t += secs;
                phase_add(m, *phase, *secs);
            }
            Op::Tally {
                remote_requests,
                remote_vertices,
                local_hits,
            } => {
                m.remote_requests += remote_requests;
                m.remote_vertices += remote_vertices;
                m.local_hits += local_hits;
            }
        }
    }

    (t, busy_dt, pending)
}

fn phase_add(m: &mut EpochMetrics, phase: Phase, dt: f64) {
    match phase {
        Phase::Gather => m.time_gather += dt,
        Phase::Migrate => m.time_migrate += dt,
        Phase::Untimed => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TransferKind;
    use crate::config::RunConfig;
    use crate::coordinator::ops::ProgramBuilder;
    use crate::featstore::cache::CachePolicy;
    use crate::graph::datasets::tiny_test_dataset;

    fn env_with(overlap: bool, parallel: bool) -> RunConfig {
        RunConfig {
            num_servers: 4,
            overlap,
            parallel_lanes: parallel,
            ..Default::default()
        }
    }

    fn demo_program(n: usize) -> Program {
        let mut b = ProgramBuilder::new(n);
        for s in 0..n {
            b.op(s, Op::Sample { vertices: 500 });
            b.op(s, Op::Gather {
                // tiny_test_dataset has 400 vertices; gather them all
                vertices: (0..400u32).collect(),
                overlap: true,
            });
            b.op(s, Op::Compute { v: 400, e: 2400 });
        }
        b.barrier();
        for s in 0..n {
            b.op(s, Op::Migrate {
                from: (s + 1) % n,
                kind: TransferKind::ModelParams,
                bytes: 1 << 16,
                phase: Phase::Migrate,
                overlap: false,
            });
        }
        b.allreduce();
        b.finish()
    }

    #[test]
    fn sequential_pool_and_spawn_lanes_are_bit_identical() {
        let d = tiny_test_dataset(200);
        let prog = demo_program(4);
        let env = SimEnv::new(&d, env_with(false, true));
        let run = |dispatch| {
            EpochDriver::builder(&env).dispatch(dispatch).run(&prog)
        };
        let seq = run(LaneDispatch::Serial);
        for (what, par) in [
            ("pool", run(LaneDispatch::Pool)),
            ("spawn-per-item", run(LaneDispatch::SpawnPerItem)),
        ] {
            assert_eq!(seq.total_bytes(), par.total_bytes(), "{what}");
            for k in 0..crate::cluster::network::NUM_KINDS {
                assert_eq!(
                    seq.bytes_by_kind[k], par.bytes_by_kind[k],
                    "{what}"
                );
            }
            assert_eq!(
                seq.epoch_time.to_bits(),
                par.epoch_time.to_bits(),
                "{what}"
            );
            assert_eq!(
                seq.gpu_busy_fraction.to_bits(),
                par.gpu_busy_fraction.to_bits(),
                "{what}"
            );
            assert_eq!(
                seq.time_gather.to_bits(),
                par.time_gather.to_bits(),
                "{what}"
            );
            assert_eq!(seq.remote_vertices, par.remote_vertices, "{what}");
            assert_eq!(seq.local_hits, par.local_hits, "{what}");
        }
    }

    #[test]
    fn pool_persists_across_fragments_and_sessions() {
        // one pool serves every fragment of a session, and the
        // session state hands it to the next session untouched
        let d = tiny_test_dataset(212);
        let env = SimEnv::new(&d, env_with(false, true));
        let prog = demo_program(4);
        let mut s1 = EpochDriver::builder(&env)
            .dispatch(LaneDispatch::Pool)
            .build();
        s1.exec(&prog);
        s1.exec(&prog);
        let (_, state) = s1.finish_state();
        let pool = state.pool.expect("forced pool dispatch spawns a pool");
        assert_eq!(pool.workers(), 3, "one thread per server, one claims");
        let mut s2 = EpochDriver::builder(&env)
            .dispatch(LaneDispatch::Pool)
            .pool(pool)
            .build();
        s2.exec(&prog);
        let (m2, state2) = s2.finish_state();
        assert!(state2.pool.is_some(), "the warm pool survives finish");
        // and a serial one-shot of the same program matches bitwise
        let serial = EpochDriver::builder(&env)
            .dispatch(LaneDispatch::Serial)
            .run(&prog);
        assert_eq!(m2.epoch_time.to_bits(), serial.epoch_time.to_bits());
        assert_eq!(m2.total_bytes(), serial.total_bytes());
    }

    #[test]
    fn both_sides_of_the_work_threshold_are_bit_identical() {
        // Auto dispatch: `small` stays under the default threshold
        // (sequential), `big` crosses it (pool) — both must match the
        // forced-serial run bit for bit, so the threshold (and its
        // HOPGNN_PARALLEL_THRESHOLD override) can only move wall-clock
        let d = tiny_test_dataset(211);
        let env = SimEnv::new(&d, env_with(false, true));
        let prog_with = |verts: u32| {
            let mut b = ProgramBuilder::new(4);
            for _ in 0..4 {
                for s in 0..4 {
                    b.op(s, Op::Gather {
                        vertices: (0..verts).collect(),
                        overlap: false,
                    });
                    b.op(s, Op::Compute { v: verts as u64, e: 6 });
                }
                b.barrier();
            }
            b.allreduce();
            b.finish()
        };
        let small = prog_with(8); // 4 lanes x (8 + 1) x 4 frags << 1024
        let big = prog_with(400); // 4 lanes x 401 per fragment >= 1024
        for (what, prog) in [("small", &small), ("big", &big)] {
            let auto = EpochDriver::builder(&env).run(prog);
            let serial = EpochDriver::builder(&env)
                .dispatch(LaneDispatch::Serial)
                .run(prog);
            assert_eq!(
                auto.epoch_time.to_bits(),
                serial.epoch_time.to_bits(),
                "{what}: epoch_time"
            );
            assert_eq!(
                auto.total_bytes(),
                serial.total_bytes(),
                "{what}: bytes"
            );
            assert_eq!(
                auto.time_gather.to_bits(),
                serial.time_gather.to_bits(),
                "{what}: time_gather"
            );
        }
    }

    #[test]
    fn streaming_fragments_equal_one_program() {
        // feeding the epoch as per-iteration fragments through exec()
        // is bit-identical to one materialized program
        let d = tiny_test_dataset(204);
        let env = SimEnv::new(&d, env_with(false, false));
        let one = EpochDriver::run(&env, &demo_program(4));

        let mut frag_a = ProgramBuilder::new(4);
        for s in 0..4 {
            frag_a.op(s, Op::Sample { vertices: 500 });
            frag_a.op(s, Op::Gather {
                vertices: (0..400u32).collect(),
                overlap: true,
            });
            frag_a.op(s, Op::Compute { v: 400, e: 2400 });
        }
        frag_a.barrier();
        let mut frag_b = ProgramBuilder::new(4);
        for s in 0..4 {
            frag_b.op(s, Op::Migrate {
                from: (s + 1) % 4,
                kind: TransferKind::ModelParams,
                bytes: 1 << 16,
                phase: Phase::Migrate,
                overlap: false,
            });
        }
        frag_b.allreduce();
        let mut driver = EpochDriver::new(&env);
        driver.exec(&frag_a.finish());
        driver.exec(&frag_b.finish());
        let streamed = driver.finish();

        assert_eq!(one.total_bytes(), streamed.total_bytes());
        assert_eq!(one.epoch_time.to_bits(), streamed.epoch_time.to_bits());
        assert_eq!(one.remote_vertices, streamed.remote_vertices);
    }

    #[test]
    fn overlap_changes_time_not_bytes() {
        let d = tiny_test_dataset(201);
        let off_env = SimEnv::new(&d, env_with(false, false));
        let off = EpochDriver::run(&off_env, &demo_program(4));
        let on_env = SimEnv::new(&d, env_with(true, false));
        let on = EpochDriver::run(&on_env, &demo_program(4));
        assert_eq!(off.total_bytes(), on.total_bytes());
        assert_eq!(off.remote_vertices, on.remote_vertices);
        assert!(on.epoch_time <= off.epoch_time + 1e-15,
                "overlap must not slow the epoch: {} > {}",
                on.epoch_time, off.epoch_time);
        assert!(on.time_overlap_hidden > 0.0, "some gather must hide");
        // gather *work* is unchanged; only its exposure moved
        assert!((on.time_gather - off.time_gather).abs() < 1e-15);
    }

    #[test]
    fn unhidden_async_time_is_exposed_at_fences() {
        // a program with a huge async gather and almost no compute:
        // overlap cannot hide it, so epoch time must match serial
        let d = tiny_test_dataset(202);
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Gather {
            vertices: (0..400u32).collect(),
            overlap: true,
        });
        b.allreduce();
        let prog = b.finish();
        let off = EpochDriver::run(
            &SimEnv::new(&d, RunConfig {
                num_servers: 2,
                overlap: false,
                parallel_lanes: false,
                ..Default::default()
            }),
            &prog,
        );
        let on = EpochDriver::run(
            &SimEnv::new(&d, RunConfig {
                num_servers: 2,
                overlap: true,
                parallel_lanes: false,
                ..Default::default()
            }),
            &prog,
        );
        assert!((on.epoch_time - off.epoch_time).abs() < 1e-12,
                "nothing to hide behind: {} vs {}",
                on.epoch_time, off.epoch_time);
        assert_eq!(on.time_overlap_hidden, 0.0);
    }

    /// Two identical cache-routed gathers on server 0 + an allreduce.
    /// No compute: in overlap mode the pending stream is fully exposed
    /// at the allreduce fence, so any hit shows up in the epoch time.
    fn cache_program(overlap: bool) -> Program {
        let mut b = ProgramBuilder::new(2);
        for _ in 0..2 {
            b.op(0, Op::CacheFetch {
                steps: vec![(0..400u32).collect()],
                overlap,
            });
        }
        b.allreduce();
        b.finish()
    }

    fn cache_cfg(policy: CachePolicy, mb: usize, overlap: bool) -> RunConfig {
        RunConfig {
            num_servers: 2,
            overlap,
            parallel_lanes: false,
            cache_policy: policy,
            cache_mb: mb,
            ..Default::default()
        }
    }

    #[test]
    fn cache_hits_skip_transfers_in_serial_and_overlap_lanes() {
        let d = tiny_test_dataset(205);
        for overlap in [false, true] {
            let prog = cache_program(overlap);
            let cold = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 0, overlap)),
                &prog,
            );
            let warm = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 64, overlap)),
                &prog,
            );
            // capacity 0 never hits; 64 MiB holds the whole remote set,
            // so the second gather is all hits: half the feature bytes
            assert_eq!(cold.cache_hits, 0);
            assert!(warm.cache_hits > 0);
            assert_eq!(warm.cache_hits, warm.cache_misses);
            assert_eq!(
                2 * warm.bytes(TransferKind::Feature),
                cold.bytes(TransferKind::Feature),
                "overlap={overlap}: warm cache must halve feature bytes"
            );
            // byte conservation: requested = skipped + transferred
            assert_eq!(
                warm.cache_hit_bytes + warm.cache_miss_bytes,
                cold.cache_miss_bytes,
                "overlap={overlap}"
            );
            assert_eq!(warm.cache_miss_bytes,
                       warm.bytes(TransferKind::Feature));
            assert!(
                warm.epoch_time < cold.epoch_time,
                "overlap={overlap}: hits must shrink the epoch \
                 ({} !< {})",
                warm.epoch_time,
                cold.epoch_time
            );
        }
    }

    #[test]
    fn capacity_zero_cache_matches_uncached_gather_bitwise() {
        let d = tiny_test_dataset(206);
        for overlap in [false, true] {
            // the uncached twin of `cache_program`: plain gathers,
            // op-for-op identical otherwise
            let mut b = ProgramBuilder::new(2);
            for _ in 0..2 {
                b.op(0, Op::Gather {
                    vertices: (0..400u32).collect(),
                    overlap,
                });
            }
            b.allreduce();
            let plain = b.finish();
            let off = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::None, 64, overlap)),
                &plain,
            );
            let zero = EpochDriver::run(
                &SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 0, overlap)),
                &cache_program(overlap),
            );
            assert_eq!(off.total_bytes(), zero.total_bytes());
            assert_eq!(off.epoch_time.to_bits(), zero.epoch_time.to_bits());
            assert_eq!(off.time_gather.to_bits(), zero.time_gather.to_bits());
            assert_eq!(off.remote_vertices, zero.remote_vertices);
            assert_eq!(off.local_hits, zero.local_hits);
            assert_eq!(zero.cache_hits, 0);
        }
    }

    #[test]
    fn parallel_lanes_bit_identical_with_cache_enabled() {
        let d = tiny_test_dataset(207);
        let prog = demo_cache_lanes();
        let cfg = |parallel| RunConfig {
            num_servers: 4,
            parallel_lanes: parallel,
            cache_policy: CachePolicy::Lru,
            cache_mb: 4,
            ..Default::default()
        };
        let env_seq = SimEnv::new(&d, cfg(false));
        let env_par = SimEnv::new(&d, cfg(true));
        let seq = EpochDriver::builder(&env_seq)
            .dispatch(LaneDispatch::Serial)
            .run(&prog);
        let par = EpochDriver::builder(&env_par)
            .dispatch(LaneDispatch::Pool)
            .run(&prog);
        assert_eq!(seq.total_bytes(), par.total_bytes());
        assert_eq!(seq.epoch_time.to_bits(), par.epoch_time.to_bits());
        assert_eq!(seq.cache_hits, par.cache_hits);
        assert_eq!(seq.cache_hit_bytes, par.cache_hit_bytes);
        assert_eq!(seq.cache_evict_bytes, par.cache_evict_bytes);
        assert!(seq.cache_hits > 0, "warm rows must hit on the re-fetch");
    }

    /// Four lanes, each fetching overlapping windows twice through the
    /// cache, so every lane produces both misses and hits.
    fn demo_cache_lanes() -> Program {
        let mut b = ProgramBuilder::new(4);
        for round in 0..2u32 {
            for s in 0..4 {
                let lo = (s as u32 * 50 + round * 25) % 300;
                b.op(s, Op::CacheFetch {
                    steps: vec![(lo..lo + 100).collect()],
                    overlap: false,
                });
                b.op(s, Op::Compute { v: 100, e: 600 });
            }
            b.barrier();
        }
        b.allreduce();
        b.finish()
    }

    #[test]
    fn straggler_fabric_scales_compute_per_server() {
        use crate::cluster::FabricSpec;
        let d = tiny_test_dataset(208);
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Compute { v: 400, e: 2400 });
        b.op(1, Op::Compute { v: 400, e: 2400 });
        let prog = b.finish();
        let mk = |fabric| {
            SimEnv::new(&d, RunConfig {
                num_servers: 2,
                parallel_lanes: false,
                fabric,
                ..Default::default()
            })
        };
        let uni = EpochDriver::run(&mk(FabricSpec::Uniform), &prog);
        let strag =
            EpochDriver::run(&mk(FabricSpec::Straggler { server: 0 }), &prog);
        // server 0 computes at half speed; same work, twice the time
        assert!(
            (strag.epoch_time - 2.0 * uni.epoch_time).abs()
                < 1e-12 * uni.epoch_time,
            "straggler epoch {} != 2x uniform {}",
            strag.epoch_time,
            uni.epoch_time
        );
        assert_eq!(strag.per_server_busy.len(), 2);
        assert!(
            (strag.per_server_busy[0] - 2.0 * strag.per_server_busy[1])
                .abs()
                < 1e-12 * strag.per_server_busy[1],
            "observed lane times must expose the straggler"
        );
        // uniform fabric: busy times match exactly (bit parity)
        assert_eq!(
            uni.per_server_busy[0].to_bits(),
            uni.per_server_busy[1].to_bits()
        );
    }

    #[test]
    fn warm_tiers_carry_across_driver_sessions() {
        let d = tiny_test_dataset(209);
        let env = SimEnv::new(&d, cache_cfg(CachePolicy::Lru, 64, false));
        let prog = cache_program(false);
        // session 1 starts cold: first fetch misses, re-fetch hits
        let mut s1 = EpochDriver::new(&env);
        s1.exec(&prog);
        let (m1, tiers) = s1.finish_session();
        assert!(m1.cache_hits > 0);
        assert!(m1.cache_misses > 0);
        // session 2 seeded with session 1's stacks: every fetch hits
        let mut s2 = EpochDriver::with_tiers(&env, tiers);
        s2.exec(&prog);
        let (m2, _) = s2.finish_session();
        assert_eq!(m2.cache_misses, 0, "warm session must not re-fetch");
        assert!(m2.cache_hits > m1.cache_hits);
        assert!(m2.epoch_time < m1.epoch_time);
        // a fresh session still starts cold (persistence is opt-in)
        let m3 = EpochDriver::run(&env, &prog);
        assert_eq!(m3.cache_hits, m1.cache_hits);
    }

    #[test]
    fn tier_kind_prices_the_hit_hbm_free_ssd_flash() {
        use crate::featstore::tier::TierSpec;
        let d = tiny_test_dataset(210);
        let cfg = |tiers: &str| RunConfig {
            tiers: Some(TierSpec::parse(tiers).unwrap()),
            ..cache_cfg(CachePolicy::None, 0, false)
        };
        let prog = cache_program(false);
        let run = |spec| EpochDriver::run(&SimEnv::new(&d, cfg(spec)), &prog);
        let hbm = run("hbm:64m:lru+remote");
        let dram = run("dram:64m:lru+remote");
        let ssd = run("ssd:64m:lru+remote");
        // same residency trajectory, different per-hit price
        assert!(hbm.cache_hits > 0);
        assert_eq!(hbm.cache_hits, dram.cache_hits);
        assert_eq!(dram.cache_hits, ssd.cache_hits);
        assert!(
            hbm.epoch_time < dram.epoch_time,
            "hbm hits skip staging: {} !< {}",
            hbm.epoch_time,
            dram.epoch_time
        );
        assert!(
            dram.epoch_time < ssd.epoch_time,
            "ssd hits pay the flash read: {} !< {}",
            dram.epoch_time,
            ssd.epoch_time
        );
        // per-tier accounting lands in the right slots
        assert_eq!(hbm.tier_hits[TierKind::Hbm.index()], hbm.cache_hits);
        assert_eq!(dram.tier_hits[TierKind::Dram.index()], dram.cache_hits);
        assert_eq!(ssd.tier_hits[TierKind::Ssd.index()], ssd.cache_hits);
        assert_eq!(
            dram.tier_hits[TierKind::Remote.index()],
            dram.cache_misses
        );
        // bytes conserved across the tier view too
        assert_eq!(
            dram.tier_hit_bytes.iter().sum::<u64>(),
            dram.cache_hit_bytes + dram.cache_miss_bytes
        );
    }

    #[test]
    fn untimed_phase_charges_clock_but_no_metric() {
        let d = tiny_test_dataset(203);
        let mut b = ProgramBuilder::new(2);
        b.op(1, Op::Migrate {
            from: 0,
            kind: TransferKind::Control,
            bytes: 4096,
            phase: Phase::Untimed,
            overlap: false,
        });
        let prog = b.finish();
        let env = SimEnv::new(&d, RunConfig {
            num_servers: 2,
            ..Default::default()
        });
        let m = EpochDriver::run(&env, &prog);
        assert!(m.epoch_time > 0.0);
        assert_eq!(m.bytes(TransferKind::Control), 4096);
        let phases = m.time_sample + m.time_gather + m.time_compute
            + m.time_migrate + m.time_sync;
        assert_eq!(phases, 0.0);
    }
}
