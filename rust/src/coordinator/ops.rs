//! The typed per-server op stream every strategy compiles to.
//!
//! A strategy no longer executes its epoch eagerly against the clocks;
//! it emits [`Program`] fragments (typically one per iteration): a
//! sequence of [`Item`]s, where each item is either a set of
//! per-server op *lanes* (executed concurrently by the
//! [`super::engine::EpochDriver`]) or a global synchronization point
//! (barrier, per-step sync cost, gradient allreduce). Ops carry only
//! data — vertex id lists, byte counts, FLOP-derived seconds — so the
//! driver can execute lanes on worker threads with no shared mutable
//! state and reduce the results deterministically.
//!
//! Design invariants:
//!
//! * Every op belongs to exactly one lane: the server whose clock its
//!   time is charged to. Byte transfers name their remote peer via
//!   `from`, so network accounting stays exact per (src, dst) link.
//! * Within one `Item::Lanes`, lane order is execution order per
//!   server; lanes never read another server's clock, so concurrent
//!   execution is bit-identical to sequential execution.
//! * Transfer ops flagged `overlap: true` *may* be hidden behind
//!   compute on the same lane when [`crate::config::RunConfig::overlap`]
//!   is enabled (see the driver for the exact semantics); with the knob
//!   off they are charged inline, byte-for-byte and second-for-second
//!   identical to the historical eager loops.

use crate::cluster::TransferKind;

/// Which epoch-metrics phase a transfer/host op's seconds are
/// attributed to. Sampling, compute, and sync time always flow through
/// their dedicated ops ([`Op::Sample`], [`Op::Compute`]/
/// [`Op::ComputeSecs`], [`Item::SyncAll`]), so only the phases a
/// `Migrate`/`Host` op can legitimately claim exist here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Gather,
    Migrate,
    /// Clock time with no phase attribution (e.g. LO's control-plane
    /// root shipping, which the eager loop never charged to a phase).
    Untimed,
}

/// One unit of simulated work on a single server lane.
#[derive(Clone, Debug)]
pub enum Op {
    /// Charge sampling time for `vertices` sampled micrograph vertices.
    Sample { vertices: u64 },
    /// Gather features for `vertices` (duplicates allowed; the gather
    /// plan deduplicates). Remote fetches are recorded per source link.
    Gather { vertices: Vec<u32>, overlap: bool },
    /// Iteration-level merged gather (§5.2 pre-gathering): one
    /// deduplicated fetch for all `steps` of the iteration.
    GatherMerged { steps: Vec<Vec<u32>>, overlap: bool },
    /// Cache-mediated gather: the dedup union of `steps` is resolved
    /// through this lane's [`crate::featstore::cache::FeatureCache`] —
    /// hits skip the transfer entirely (in overlap mode they also never
    /// enter the async pending stream), misses are fetched like a
    /// `GatherMerged` and admitted. With a capacity-0 cache this is
    /// bit-identical to `Gather`/`GatherMerged` (`tests/cache_parity`).
    /// Emitted by the strategy builders in place of the plain gathers
    /// when [`crate::config::RunConfig::cache_enabled`] holds.
    CacheFetch { steps: Vec<Vec<u32>>, overlap: bool },
    /// GNN training compute over `v` vertices / `e` edges (busy time,
    /// cost-model derived).
    Compute { v: u64, e: u64 },
    /// Pre-computed compute seconds (busy) — for strategies with custom
    /// FLOP accounting (P³'s model-parallel phase).
    ComputeSecs { secs: f64 },
    /// Receive `bytes` of `kind` from server `from`; the transfer time
    /// is charged to this lane and attributed to `phase`.
    Migrate {
        from: usize,
        kind: TransferKind,
        bytes: u64,
        phase: Phase,
        overlap: bool,
    },
    /// Host-side seconds (staging, CPU split/merge overheads).
    Host { secs: f64, phase: Phase },
    /// Metrics-only counters (no time, no bytes).
    Tally {
        remote_requests: u64,
        remote_vertices: u64,
        local_hits: u64,
    },
}

impl Op {
    /// Rough work weight used to decide whether parallel lane execution
    /// is worth spawning threads for.
    pub fn weight(&self) -> usize {
        match self {
            Op::Gather { vertices, .. } => vertices.len(),
            Op::GatherMerged { steps, .. } | Op::CacheFetch { steps, .. } => {
                steps.iter().map(|s| s.len()).sum()
            }
            _ => 1,
        }
    }

    /// Single-step feature gather, routed through the per-server cache
    /// when `cached` — the one gather-emission point every strategy
    /// builder shares, so the cache knob cannot drift per strategy.
    pub fn gather(cached: bool, vertices: Vec<u32>, overlap: bool) -> Op {
        if cached {
            Op::CacheFetch {
                steps: vec![vertices],
                overlap,
            }
        } else {
            Op::Gather { vertices, overlap }
        }
    }

    /// Iteration-level merged gather (§5.2), cache-routed when `cached`.
    pub fn gather_merged(
        cached: bool,
        steps: Vec<Vec<u32>>,
        overlap: bool,
    ) -> Op {
        if cached {
            Op::CacheFetch { steps, overlap }
        } else {
            Op::GatherMerged { steps, overlap }
        }
    }
}

/// One schedule element: concurrent per-server lanes or a global op.
#[derive(Clone, Debug)]
pub enum Item {
    /// `lanes[s]` = ops executed (in order) on server `s`, concurrently
    /// across servers.
    Lanes(Vec<Vec<Op>>),
    /// Align all clocks to the slowest server.
    Barrier,
    /// Charge the fixed synchronization cost `t_sync` to every server.
    SyncAll,
    /// Ring allreduce of gradients (the iteration-end sync every
    /// strategy pays).
    Allreduce,
}

/// A schedule fragment for `num_servers` servers. Strategies typically
/// build one `Program` per iteration and stream the fragments through
/// an [`super::engine::EpochDriver`] session, keeping the materialized
/// op working set O(one iteration) rather than O(epoch).
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub num_servers: usize,
    pub items: Vec<Item>,
}

impl Program {
    /// Total ops across all lane items (introspection / tests).
    pub fn num_ops(&self) -> usize {
        self.items
            .iter()
            .map(|i| match i {
                Item::Lanes(lanes) => {
                    lanes.iter().map(|l| l.len()).sum::<usize>()
                }
                _ => 0,
            })
            .sum()
    }

    /// Number of global synchronization items (barriers + syncs +
    /// allreduces).
    pub fn num_sync_points(&self) -> usize {
        self.items
            .iter()
            .filter(|i| !matches!(i, Item::Lanes(_)))
            .count()
    }
}

/// Incremental [`Program`] construction: ops accumulate into the
/// current lane set; any global item seals it.
pub struct ProgramBuilder {
    num_servers: usize,
    items: Vec<Item>,
    cur: Vec<Vec<Op>>,
}

impl ProgramBuilder {
    pub fn new(num_servers: usize) -> Self {
        Self {
            num_servers,
            items: Vec::new(),
            cur: vec![Vec::new(); num_servers],
        }
    }

    pub fn num_servers(&self) -> usize {
        self.num_servers
    }

    /// Append `op` to server `server`'s current lane.
    pub fn op(&mut self, server: usize, op: Op) {
        debug_assert!(server < self.num_servers);
        self.cur[server].push(op);
    }

    fn flush(&mut self) {
        if self.cur.iter().any(|l| !l.is_empty()) {
            let lanes = std::mem::replace(
                &mut self.cur,
                vec![Vec::new(); self.num_servers],
            );
            self.items.push(Item::Lanes(lanes));
        }
    }

    pub fn barrier(&mut self) {
        self.flush();
        self.items.push(Item::Barrier);
    }

    pub fn sync_all(&mut self) {
        self.flush();
        self.items.push(Item::SyncAll);
    }

    pub fn allreduce(&mut self) {
        self.flush();
        self.items.push(Item::Allreduce);
    }

    pub fn finish(mut self) -> Program {
        self.flush();
        Program {
            num_servers: self.num_servers,
            items: self.items,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_seals_lanes_at_global_items() {
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Sample { vertices: 10 });
        b.op(1, Op::Compute { v: 5, e: 20 });
        b.barrier();
        b.op(0, Op::Host {
            secs: 1e-3,
            phase: Phase::Gather,
        });
        b.allreduce();
        let p = b.finish();
        assert_eq!(p.items.len(), 4); // lanes, barrier, lanes, allreduce
        assert_eq!(p.num_ops(), 3);
        assert_eq!(p.num_sync_points(), 2);
        match &p.items[0] {
            Item::Lanes(lanes) => {
                assert_eq!(lanes[0].len(), 1);
                assert_eq!(lanes[1].len(), 1);
            }
            other => panic!("expected lanes, got {other:?}"),
        }
    }

    #[test]
    fn empty_lane_sets_are_not_emitted() {
        let mut b = ProgramBuilder::new(3);
        b.barrier();
        b.barrier();
        let p = b.finish();
        assert_eq!(p.items.len(), 2);
        assert!(p.items.iter().all(|i| matches!(i, Item::Barrier)));
    }

    #[test]
    fn op_weights() {
        assert_eq!(Op::Sample { vertices: 99 }.weight(), 1);
        assert_eq!(
            Op::Gather {
                vertices: vec![1, 2, 3],
                overlap: false
            }
            .weight(),
            3
        );
        assert_eq!(
            Op::GatherMerged {
                steps: vec![vec![1, 2], vec![3]],
                overlap: true
            }
            .weight(),
            3
        );
        assert_eq!(
            Op::CacheFetch {
                steps: vec![vec![1, 2], vec![3, 4]],
                overlap: true
            }
            .weight(),
            4
        );
    }

    #[test]
    fn gather_helpers_route_through_the_cache_knob() {
        match Op::gather(false, vec![1, 2], true) {
            Op::Gather { vertices, overlap } => {
                assert_eq!(vertices, vec![1, 2]);
                assert!(overlap);
            }
            other => panic!("expected Gather, got {other:?}"),
        }
        match Op::gather(true, vec![1, 2], false) {
            Op::CacheFetch { steps, overlap } => {
                assert_eq!(steps, vec![vec![1, 2]]);
                assert!(!overlap);
            }
            other => panic!("expected CacheFetch, got {other:?}"),
        }
        match Op::gather_merged(false, vec![vec![5]], true) {
            Op::GatherMerged { .. } => {}
            other => panic!("expected GatherMerged, got {other:?}"),
        }
        match Op::gather_merged(true, vec![vec![5]], true) {
            Op::CacheFetch { .. } => {}
            other => panic!("expected CacheFetch, got {other:?}"),
        }
    }
}
