//! The typed per-server op stream every strategy compiles to.
//!
//! A strategy no longer executes its epoch eagerly against the clocks;
//! it emits [`Program`] fragments (typically one per iteration): a
//! sequence of [`Item`]s, where each item is either a set of
//! per-server op *lanes* (executed concurrently by the
//! [`super::engine::EpochDriver`]) or a global synchronization point
//! (barrier, per-step sync cost, gradient allreduce). Ops carry only
//! data — vertex id lists, byte counts, FLOP-derived seconds — so the
//! driver can execute lanes on worker threads with no shared mutable
//! state and reduce the results deterministically.
//!
//! Design invariants:
//!
//! * Every op belongs to exactly one lane: the server whose clock its
//!   time is charged to. Byte transfers name their remote peer via
//!   `from`, so network accounting stays exact per (src, dst) link.
//! * Within one `Item::Lanes`, lane order is execution order per
//!   server; lanes never read another server's clock, so concurrent
//!   execution is bit-identical to sequential execution.
//! * Transfer ops flagged `overlap: true` *may* be hidden behind
//!   compute on the same lane when [`crate::config::RunConfig::overlap`]
//!   is enabled (see the driver for the exact semantics); with the knob
//!   off they are charged inline, byte-for-byte and second-for-second
//!   identical to the historical eager loops.

use crate::cluster::TransferKind;

/// Which epoch-metrics phase a transfer/host op's seconds are
/// attributed to. Sampling, compute, and sync time always flow through
/// their dedicated ops ([`Op::Sample`], [`Op::Compute`]/
/// [`Op::ComputeSecs`], [`Item::SyncAll`]), so only the phases a
/// `Migrate`/`Host` op can legitimately claim exist here.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Gather,
    Migrate,
    /// Clock time with no phase attribution (e.g. LO's control-plane
    /// root shipping, which the eager loop never charged to a phase).
    Untimed,
}

/// One unit of simulated work on a single server lane.
#[derive(Clone, Debug)]
pub enum Op {
    /// Charge sampling time for `vertices` sampled micrograph vertices.
    Sample { vertices: u64 },
    /// Gather features for `vertices` (duplicates allowed; the gather
    /// plan deduplicates). Remote fetches are recorded per source link.
    Gather { vertices: Vec<u32>, overlap: bool },
    /// Iteration-level merged gather (§5.2 pre-gathering): one
    /// deduplicated fetch for all `steps` of the iteration.
    GatherMerged { steps: Vec<Vec<u32>>, overlap: bool },
    /// Tier-mediated gather: the dedup union of `steps` is resolved
    /// through this lane's [`crate::featstore::tier::TierStack`] — a
    /// hit is priced by the tier that holds the row and skips the
    /// transfer entirely (in overlap mode it also never enters the
    /// async pending stream), full misses are fetched like a
    /// `GatherMerged` and admitted per the placement policies. With a
    /// capacity-0 stack this is bit-identical to
    /// `Gather`/`GatherMerged` (`tests/cache_parity`). Emitted by the
    /// strategy builders in place of the plain gathers when
    /// [`crate::config::RunConfig::cache_enabled`] holds.
    CacheFetch { steps: Vec<Vec<u32>>, overlap: bool },
    /// GNN training compute over `v` vertices / `e` edges (busy time,
    /// cost-model derived).
    Compute { v: u64, e: u64 },
    /// Pre-computed compute seconds (busy) — for strategies with custom
    /// FLOP accounting (P³'s model-parallel phase).
    ComputeSecs { secs: f64 },
    /// Receive `bytes` of `kind` from server `from`; the transfer time
    /// is charged to this lane and attributed to `phase`.
    Migrate {
        from: usize,
        kind: TransferKind,
        bytes: u64,
        phase: Phase,
        overlap: bool,
    },
    /// Host-side seconds (staging, CPU split/merge overheads).
    Host { secs: f64, phase: Phase },
    /// Metrics-only counters (no time, no bytes).
    Tally {
        remote_requests: u64,
        remote_vertices: u64,
        local_hits: u64,
    },
}

impl Op {
    /// Rough work weight used to decide whether parallel lane execution
    /// is worth spawning threads for.
    pub fn weight(&self) -> usize {
        match self {
            Op::Gather { vertices, .. } => vertices.len(),
            Op::GatherMerged { steps, .. } | Op::CacheFetch { steps, .. } => {
                steps.iter().map(|s| s.len()).sum()
            }
            _ => 1,
        }
    }

    /// Single-step feature gather, routed through the per-server cache
    /// when `cached` — the one gather-emission point every strategy
    /// builder shares, so the cache knob cannot drift per strategy.
    pub fn gather(cached: bool, vertices: Vec<u32>, overlap: bool) -> Op {
        if cached {
            Op::CacheFetch {
                steps: vec![vertices],
                overlap,
            }
        } else {
            Op::Gather { vertices, overlap }
        }
    }

    /// Iteration-level merged gather (§5.2), cache-routed when `cached`.
    pub fn gather_merged(
        cached: bool,
        steps: Vec<Vec<u32>>,
        overlap: bool,
    ) -> Op {
        if cached {
            Op::CacheFetch { steps, overlap }
        } else {
            Op::GatherMerged { steps, overlap }
        }
    }
}

/// One schedule element: concurrent per-server lanes or a global op.
#[derive(Clone, Debug)]
pub enum Item {
    /// `lanes[s]` = ops executed (in order) on server `s`, concurrently
    /// across servers.
    Lanes(Vec<Vec<Op>>),
    /// Align all clocks to the slowest server.
    Barrier,
    /// Charge the fixed synchronization cost `t_sync` to every server.
    SyncAll,
    /// Ring allreduce of gradients (the iteration-end sync every
    /// strategy pays).
    Allreduce,
}

/// A schedule fragment for `num_servers` servers. Strategies typically
/// build one `Program` per iteration and stream the fragments through
/// an [`super::engine::EpochDriver`] session, keeping the materialized
/// op working set O(one iteration) rather than O(epoch).
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub num_servers: usize,
    pub items: Vec<Item>,
}

impl Program {
    /// Total ops across all lane items (introspection / tests).
    pub fn num_ops(&self) -> usize {
        self.items
            .iter()
            .map(|i| match i {
                Item::Lanes(lanes) => {
                    lanes.iter().map(|l| l.len()).sum::<usize>()
                }
                _ => 0,
            })
            .sum()
    }

    /// Number of global synchronization items (barriers + syncs +
    /// allreduces).
    pub fn num_sync_points(&self) -> usize {
        self.items
            .iter()
            .filter(|i| !matches!(i, Item::Lanes(_)))
            .count()
    }
}

/// Incremental [`Program`] construction: ops accumulate into the
/// current lane set; any global item seals it.
///
/// A builder can be **persistent**: [`Self::take`] moves the built
/// program out without consuming the builder, and [`Self::recycle`]
/// harvests an executed program's storage — lane sets, item vectors,
/// and the `Vec<u32>` / `Vec<Vec<u32>>` payloads inside gather ops —
/// into free pools that [`Self::vbuf`] / [`Self::sbuf`] hand back out.
/// A strategy that builds one program per iteration and recycles it
/// after `EpochDriver::exec` therefore reaches a steady state where
/// schedule construction allocates nothing (all buffers cycle at their
/// high-water capacity); `tests/alloc_budget.rs` asserts this.
pub struct ProgramBuilder {
    num_servers: usize,
    items: Vec<Item>,
    cur: Vec<Vec<Op>>,
    /// Free `Vec<u32>` payload buffers (gather vertex lists).
    vpool: Vec<Vec<u32>>,
    /// Free `Vec<Vec<u32>>` step-list buffers (merged/cached gathers);
    /// always empty of inner vectors (those live in `vpool`).
    spool: Vec<Vec<Vec<u32>>>,
    /// Free lane sets (length `num_servers`, all lanes empty).
    lane_pool: Vec<Vec<Vec<Op>>>,
    /// Free item vectors.
    item_pool: Vec<Vec<Item>>,
}

impl ProgramBuilder {
    pub fn new(num_servers: usize) -> Self {
        Self {
            num_servers,
            items: Vec::new(),
            cur: vec![Vec::new(); num_servers],
            vpool: Vec::new(),
            spool: Vec::new(),
            lane_pool: Vec::new(),
            item_pool: Vec::new(),
        }
    }

    pub fn num_servers(&self) -> usize {
        self.num_servers
    }

    /// Append `op` to server `server`'s current lane.
    pub fn op(&mut self, server: usize, op: Op) {
        debug_assert!(server < self.num_servers);
        self.cur[server].push(op);
    }

    fn flush(&mut self) {
        if self.cur.iter().any(|l| !l.is_empty()) {
            let fresh = self
                .lane_pool
                .pop()
                .unwrap_or_else(|| vec![Vec::new(); self.num_servers]);
            let lanes = std::mem::replace(&mut self.cur, fresh);
            self.items.push(Item::Lanes(lanes));
        }
    }

    pub fn barrier(&mut self) {
        self.flush();
        self.items.push(Item::Barrier);
    }

    pub fn sync_all(&mut self) {
        self.flush();
        self.items.push(Item::SyncAll);
    }

    pub fn allreduce(&mut self) {
        self.flush();
        self.items.push(Item::Allreduce);
    }

    pub fn finish(mut self) -> Program {
        self.flush();
        Program {
            num_servers: self.num_servers,
            items: self.items,
        }
    }

    /// Move the built program out, leaving the builder empty and ready
    /// for the next fragment (the persistent-builder twin of
    /// [`Self::finish`]).
    pub fn take(&mut self) -> Program {
        self.flush();
        let items = std::mem::replace(
            &mut self.items,
            self.item_pool.pop().unwrap_or_default(),
        );
        Program {
            num_servers: self.num_servers,
            items,
        }
    }

    /// Harvest an executed program's storage back into the builder's
    /// pools. Pair every [`Self::take`] with a `recycle` after
    /// `EpochDriver::exec` and steady-state schedule construction stops
    /// allocating.
    pub fn recycle(&mut self, mut program: Program) {
        debug_assert_eq!(program.num_servers, self.num_servers);
        for item in program.items.drain(..) {
            if let Item::Lanes(mut lanes) = item {
                if lanes.len() != self.num_servers {
                    continue; // foreign program; drop its lane set
                }
                for lane in &mut lanes {
                    for op in lane.drain(..) {
                        self.harvest(op);
                    }
                }
                self.lane_pool.push(lanes);
            }
        }
        self.item_pool.push(program.items);
    }

    /// Return an op's heap payloads to the pools.
    fn harvest(&mut self, op: Op) {
        match op {
            Op::Gather { vertices, .. } => self.give(vertices),
            Op::GatherMerged { steps, .. } | Op::CacheFetch { steps, .. } => {
                self.give_steps(steps);
            }
            _ => {}
        }
    }

    /// A cleared `Vec<u32>` from the payload pool (or a fresh one).
    pub fn vbuf(&mut self) -> Vec<u32> {
        self.vpool.pop().unwrap_or_default()
    }

    /// A cleared `Vec<Vec<u32>>` from the step-list pool (or a fresh
    /// one).
    pub fn sbuf(&mut self) -> Vec<Vec<u32>> {
        self.spool.pop().unwrap_or_default()
    }

    /// Return an unused (or harvested) payload buffer to the pool.
    pub fn give(&mut self, mut v: Vec<u32>) {
        v.clear();
        self.vpool.push(v);
    }

    /// Return a step-list buffer to the pool, recycling its inner
    /// vectors as payload buffers.
    pub fn give_steps(&mut self, mut steps: Vec<Vec<u32>>) {
        for step in steps.drain(..) {
            self.give(step);
        }
        self.spool.push(steps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_seals_lanes_at_global_items() {
        let mut b = ProgramBuilder::new(2);
        b.op(0, Op::Sample { vertices: 10 });
        b.op(1, Op::Compute { v: 5, e: 20 });
        b.barrier();
        b.op(0, Op::Host {
            secs: 1e-3,
            phase: Phase::Gather,
        });
        b.allreduce();
        let p = b.finish();
        assert_eq!(p.items.len(), 4); // lanes, barrier, lanes, allreduce
        assert_eq!(p.num_ops(), 3);
        assert_eq!(p.num_sync_points(), 2);
        match &p.items[0] {
            Item::Lanes(lanes) => {
                assert_eq!(lanes[0].len(), 1);
                assert_eq!(lanes[1].len(), 1);
            }
            other => panic!("expected lanes, got {other:?}"),
        }
    }

    #[test]
    fn empty_lane_sets_are_not_emitted() {
        let mut b = ProgramBuilder::new(3);
        b.barrier();
        b.barrier();
        let p = b.finish();
        assert_eq!(p.items.len(), 2);
        assert!(p.items.iter().all(|i| matches!(i, Item::Barrier)));
    }

    #[test]
    fn take_recycle_round_trip_matches_finish() {
        // A persistent builder cycled through take/recycle must emit
        // programs identical in shape to one-shot finish() builds.
        let build = |b: &mut ProgramBuilder| {
            let mut verts = b.vbuf();
            verts.extend([1u32, 2, 3]);
            b.op(0, Op::Gather {
                vertices: verts,
                overlap: false,
            });
            let mut steps = b.sbuf();
            let mut s0 = b.vbuf();
            s0.extend([4u32, 5]);
            steps.push(s0);
            b.op(1, Op::GatherMerged {
                steps,
                overlap: true,
            });
            b.barrier();
            b.allreduce();
        };
        let mut oneshot = ProgramBuilder::new(2);
        build(&mut oneshot);
        let want = oneshot.finish();

        let mut b = ProgramBuilder::new(2);
        for round in 0..3 {
            build(&mut b);
            let p = b.take();
            assert_eq!(p.items.len(), want.items.len(), "round {round}");
            assert_eq!(p.num_ops(), want.num_ops(), "round {round}");
            assert_eq!(p.num_sync_points(), want.num_sync_points());
            match (&p.items[0], &want.items[0]) {
                (Item::Lanes(got), Item::Lanes(w)) => {
                    assert_eq!(got.len(), w.len());
                    match (&got[0][0], &w[0][0]) {
                        (
                            Op::Gather { vertices: g, .. },
                            Op::Gather { vertices: e, .. },
                        ) => assert_eq!(g, e, "round {round}"),
                        other => panic!("unexpected ops {other:?}"),
                    }
                }
                other => panic!("unexpected items {other:?}"),
            }
            b.recycle(p);
        }
    }

    #[test]
    fn recycled_buffers_come_back_cleared() {
        let mut b = ProgramBuilder::new(1);
        let mut v = b.vbuf();
        v.extend([9u32, 8, 7]);
        let cap = v.capacity();
        b.op(0, Op::Gather {
            vertices: v,
            overlap: false,
        });
        let p = b.take();
        b.recycle(p);
        let v2 = b.vbuf();
        assert!(v2.is_empty(), "harvested buffer must be cleared");
        assert_eq!(v2.capacity(), cap, "harvested buffer keeps capacity");
    }

    #[test]
    fn op_weights() {
        assert_eq!(Op::Sample { vertices: 99 }.weight(), 1);
        assert_eq!(
            Op::Gather {
                vertices: vec![1, 2, 3],
                overlap: false
            }
            .weight(),
            3
        );
        assert_eq!(
            Op::GatherMerged {
                steps: vec![vec![1, 2], vec![3]],
                overlap: true
            }
            .weight(),
            3
        );
        assert_eq!(
            Op::CacheFetch {
                steps: vec![vec![1, 2], vec![3, 4]],
                overlap: true
            }
            .weight(),
            4
        );
    }

    #[test]
    fn gather_helpers_route_through_the_cache_knob() {
        match Op::gather(false, vec![1, 2], true) {
            Op::Gather { vertices, overlap } => {
                assert_eq!(vertices, vec![1, 2]);
                assert!(overlap);
            }
            other => panic!("expected Gather, got {other:?}"),
        }
        match Op::gather(true, vec![1, 2], false) {
            Op::CacheFetch { steps, overlap } => {
                assert_eq!(steps, vec![vec![1, 2]]);
                assert!(!overlap);
            }
            other => panic!("expected CacheFetch, got {other:?}"),
        }
        match Op::gather_merged(false, vec![vec![5]], true) {
            Op::GatherMerged { .. } => {}
            other => panic!("expected GatherMerged, got {other:?}"),
        }
        match Op::gather_merged(true, vec![vec![5]], true) {
            Op::CacheFetch { .. } => {}
            other => panic!("expected CacheFetch, got {other:?}"),
        }
    }
}
