//! Micrograph merging (§5.3): the adaptive controller that folds the
//! lightest time step into the remaining ones, trading extra remote
//! feature fetches against fewer kernel switches and synchronizations.
//!
//! Schedule representation (Fig 10's matrix): `visits[d][t]` is the
//! server hosting model `d` at time step `t` (each column is a
//! permutation — models always train on distinct servers). `extras[d][t]`
//! lists home servers whose root groups were merged into slot `(d, t)`:
//! those micrographs are trained wherever model `d` is, with their
//! features fetched from the (removed) home server.
//!
//! ## Fabric awareness
//!
//! The paper's min-load selection treats all workers as equal — true on
//! its uniform testbed, false on a [`crate::cluster::Fabric`] with
//! stragglers or mixed GPU generations. [`Selection::FabricAware`]
//! weights per-worker micrograph counts by *observed* lane compute
//! times (seconds of busy time per unit of scheduled work, fed back via
//! [`MergeController::end_epoch_observed`]): step selection minimizes
//! the weighted load it has to re-place, and
//! [`Schedule::merge_step_weighted`] re-places each displaced root
//! group on the surviving step whose training server is fastest and
//! least crowded — real load balancing instead of round-robin. With
//! uniform weights the selection coincides with min-load.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Schedule {
    pub visits: Vec<Vec<usize>>,
    pub extras: Vec<Vec<Vec<usize>>>,
}

impl Schedule {
    /// Initial round-robin schedule: T = N, model d at server (d+t) % N.
    pub fn round_robin(num_servers: usize) -> Self {
        let visits = (0..num_servers)
            .map(|d| (0..num_servers).map(|t| (d + t) % num_servers).collect())
            .collect();
        let extras = vec![vec![Vec::new(); num_servers]; num_servers];
        Self { visits, extras }
    }

    pub fn num_steps(&self) -> usize {
        self.visits.first().map(|v| v.len()).unwrap_or(0)
    }

    pub fn num_models(&self) -> usize {
        self.visits.len()
    }

    /// All home servers whose root group trains in slot `(d, t)`:
    /// the primary (visited) server plus merged extras.
    pub fn sources(&self, d: usize, t: usize) -> Vec<usize> {
        let mut out = vec![self.visits[d][t]];
        out.extend(self.extras[d][t].iter().copied());
        out
    }

    /// Remove time step `ts` and redistribute its root groups across the
    /// surviving steps of the same model, round-robin ("as evenly as
    /// possible", §5.3).
    pub fn merge_step(&mut self, ts: usize) {
        assert!(self.num_steps() > 1, "cannot merge the last step");
        assert!(ts < self.num_steps());
        for d in 0..self.num_models() {
            let removed_primary = self.visits[d].remove(ts);
            let removed_extras = self.extras[d].remove(ts);
            let steps = self.visits[d].len();
            let mut sources = vec![removed_primary];
            sources.extend(removed_extras);
            for (i, src) in sources.into_iter().enumerate() {
                // spread across surviving steps, offset by model id so
                // different models load different steps first
                let slot = (d + i) % steps;
                self.extras[d][slot].push(src);
            }
        }
    }

    /// Fabric-aware variant of [`Self::merge_step`]: each displaced
    /// root group lands on the surviving step whose *training server*
    /// has the lowest (speed-weight × occupancy) cost, instead of
    /// round-robin — so a straggler's slots stop absorbing extra work.
    /// `weights[s]` ≈ observed seconds per unit of work on server `s`
    /// (1.0 = baseline; missing entries default to 1.0). Preserves the
    /// Fig 10 invariant exactly like `merge_step`.
    pub fn merge_step_weighted(&mut self, ts: usize, weights: &[f64]) {
        assert!(self.num_steps() > 1, "cannot merge the last step");
        assert!(ts < self.num_steps());
        for d in 0..self.num_models() {
            let removed_primary = self.visits[d].remove(ts);
            let removed_extras = self.extras[d].remove(ts);
            let steps = self.visits[d].len();
            let mut sources = vec![removed_primary];
            sources.extend(removed_extras);
            for src in sources {
                let mut best = 0usize;
                let mut best_cost = f64::INFINITY;
                for slot in 0..steps {
                    let srv = self.visits[d][slot];
                    let w = weights.get(srv).copied().unwrap_or(1.0);
                    // occupancy = groups already training in the slot
                    // (primary + extras) plus the one being placed
                    let cost =
                        w * (2.0 + self.extras[d][slot].len() as f64);
                    if cost < best_cost {
                        best_cost = cost;
                        best = slot;
                    }
                }
                self.extras[d][best].push(src);
            }
        }
    }

    /// Order-sensitive structural hash (FNV-1a over `visits` and
    /// `extras`, with length separators): any two schedules that would
    /// shape a different sampling order hash differently. Keys the
    /// cross-cell epoch-sample memo (`bench::memo`), so sweep cells
    /// only share a recorded sampling tape while their merge
    /// trajectories still agree.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut mix = |h: &mut u64, x: u64| {
            *h ^= x;
            *h = h.wrapping_mul(PRIME);
        };
        mix(&mut h, self.visits.len() as u64);
        for row in &self.visits {
            mix(&mut h, row.len() as u64);
            for &s in row {
                mix(&mut h, s as u64);
            }
        }
        for row in &self.extras {
            mix(&mut h, row.len() as u64);
            for slot in row {
                mix(&mut h, slot.len() as u64);
                for &s in slot {
                    mix(&mut h, s as u64);
                }
            }
        }
        h
    }

    /// Invariant (Fig 10): each model still trains every home server's
    /// root group exactly once, and each step's primaries are distinct.
    pub fn validate(&self, num_servers: usize) -> Result<(), String> {
        for d in 0..self.num_models() {
            let mut seen = vec![false; num_servers];
            for t in 0..self.num_steps() {
                for s in self.sources(d, t) {
                    if seen[s] {
                        return Err(format!(
                            "model {d}: server {s} trained twice"
                        ));
                    }
                    seen[s] = true;
                }
            }
            if !seen.iter().all(|&x| x) {
                return Err(format!("model {d}: some server never trained"));
            }
        }
        for t in 0..self.num_steps() {
            let mut seen = vec![false; num_servers];
            for d in 0..self.num_models() {
                let s = self.visits[d][t];
                if seen[s] {
                    return Err(format!(
                        "step {t}: two models on server {s}"
                    ));
                }
                seen[s] = true;
            }
        }
        Ok(())
    }
}

/// Which step to merge (Fig 18 compares the paper's min-load selection
/// against random; `FabricAware` extends min-load to heterogeneous
/// clusters).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// The paper's scheme: merge the step with the fewest root vertices.
    MinLoad,
    /// Ablation baseline (RD in Fig 18).
    Random,
    /// Merge the step with the least *time-weighted* load (per-worker
    /// root counts × observed lane seconds-per-work), and re-place its
    /// groups on fast, uncrowded servers
    /// ([`Schedule::merge_step_weighted`]). Requires feedback through
    /// [`MergeController::end_epoch_observed`]; degrades to min-load +
    /// occupancy-balanced placement when no observation exists yet.
    FabricAware,
}

/// Cross-epoch adaptive controller: starting from the second epoch, merge
/// one step per epoch while the measured epoch time keeps improving;
/// revert the last merge and freeze once it stops (§5.3).
pub struct MergeController {
    pub schedule: Schedule,
    pub enabled: bool,
    selection: Selection,
    prev_schedule: Option<Schedule>,
    prev_epoch_time: Option<f64>,
    frozen: bool,
    rng: Rng,
    /// Latest observed per-server weights (seconds of busy time per
    /// unit of scheduled work; empty until the first
    /// [`Self::end_epoch_observed`] call).
    server_weights: Vec<f64>,
    /// Latest `slot_loads[t][server]` = root vertices trained on
    /// `server` at step `t` (empty for the plain `end_epoch` path).
    slot_loads: Vec<Vec<u64>>,
    /// (epoch, steps) history for Fig 17.
    pub history: Vec<(f64, usize)>,
}

impl MergeController {
    pub fn new(
        num_servers: usize,
        enabled: bool,
        selection: Selection,
        seed: u64,
    ) -> Self {
        Self {
            schedule: Schedule::round_robin(num_servers),
            enabled,
            selection,
            prev_schedule: None,
            prev_epoch_time: None,
            frozen: !enabled,
            rng: Rng::new(seed),
            server_weights: Vec::new(),
            slot_loads: Vec::new(),
            history: Vec::new(),
        }
    }

    /// Feed back one epoch's measurement. `step_loads[t]` = total root
    /// vertices trained at step t over the epoch (the paper's Num_vertex
    /// approximation). [`Selection::FabricAware`] controllers should
    /// prefer [`Self::end_epoch_observed`], which also carries the
    /// per-server breakdown and observed lane weights.
    pub fn end_epoch(&mut self, epoch_time: f64, step_loads: &[u64]) {
        self.history.push((epoch_time, self.schedule.num_steps()));
        if self.frozen {
            return;
        }
        match self.prev_epoch_time {
            None => {
                // first epoch done: begin probing
                self.prev_epoch_time = Some(epoch_time);
                self.try_merge(step_loads);
            }
            Some(prev) => {
                if epoch_time < prev * 0.995 {
                    self.prev_epoch_time = Some(epoch_time);
                    self.try_merge(step_loads);
                } else {
                    // merging made it worse: revert and freeze
                    if let Some(s) = self.prev_schedule.take() {
                        self.schedule = s;
                    }
                    self.frozen = true;
                }
            }
        }
    }

    /// [`Self::end_epoch`] with the observed per-server breakdown:
    /// `slot_loads[t][s]` = root vertices trained on server `s` at step
    /// `t`, `server_weights[s]` = observed seconds of lane busy time
    /// per unit of scheduled work (1.0 = baseline; a straggler shows
    /// ~2.0). Non-fabric-aware selections ignore the extra detail, so
    /// this is a strict superset of the plain feedback path.
    pub fn end_epoch_observed(
        &mut self,
        epoch_time: f64,
        slot_loads: &[Vec<u64>],
        server_weights: &[f64],
    ) {
        self.slot_loads = slot_loads.to_vec();
        self.server_weights = server_weights.to_vec();
        let step_loads: Vec<u64> = slot_loads
            .iter()
            .map(|per_server| per_server.iter().sum())
            .collect();
        self.end_epoch(epoch_time, &step_loads);
    }

    fn try_merge(&mut self, step_loads: &[u64]) {
        if self.schedule.num_steps() <= 1 {
            self.frozen = true;
            return;
        }
        let steps = self.schedule.num_steps();
        let min_load = || {
            step_loads
                .iter()
                .enumerate()
                .take(steps)
                .min_by_key(|(_, &l)| l)
                .map(|(t, _)| t)
                .unwrap_or(0)
        };
        let ts = match self.selection {
            Selection::MinLoad => min_load(),
            Selection::Random => self.rng.below(steps),
            Selection::FabricAware => {
                if self.slot_loads.len() >= steps
                    && !self.server_weights.is_empty()
                {
                    self.weighted_min_step(steps)
                } else {
                    min_load()
                }
            }
        };
        self.prev_schedule = Some(self.schedule.clone());
        if self.selection == Selection::FabricAware {
            let weights = self.server_weights.clone();
            self.schedule.merge_step_weighted(ts, &weights);
        } else {
            self.schedule.merge_step(ts);
        }
    }

    /// The step whose time-weighted load is cheapest to re-place:
    /// `argmin_t Σ_s slot_loads[t][s] * weights[s]`. With uniform
    /// weights this is exactly min-load.
    fn weighted_min_step(&self, steps: usize) -> usize {
        let mut best = 0usize;
        let mut best_cost = f64::INFINITY;
        for (t, per_server) in self.slot_loads.iter().enumerate().take(steps)
        {
            let mut cost = 0.0;
            for (s, &load) in per_server.iter().enumerate() {
                let w = self.server_weights.get(s).copied().unwrap_or(1.0);
                cost += load as f64 * w;
            }
            if cost < best_cost {
                best_cost = cost;
                best = t;
            }
        }
        best
    }

    pub fn frozen(&self) -> bool {
        self.frozen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_robin_columns_are_permutations() {
        let s = Schedule::round_robin(4);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 4);
        assert_eq!(s.visits[1][2], 3);
    }

    #[test]
    fn fingerprint_distinguishes_schedules() {
        let a = Schedule::round_robin(4);
        let b = Schedule::round_robin(4);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let mut c = Schedule::round_robin(4);
        c.merge_step(1);
        assert_ne!(a.fingerprint(), c.fingerprint());
        // extras placement matters, not just step count
        let mut d = Schedule::round_robin(4);
        d.merge_step(2);
        assert_ne!(c.fingerprint(), d.fingerprint());
        assert_ne!(
            a.fingerprint(),
            Schedule::round_robin(3).fingerprint()
        );
    }

    #[test]
    fn merge_preserves_model_root_groups() {
        let mut s = Schedule::round_robin(4);
        s.merge_step(1);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 3);
        // extras were distributed
        let extras: usize = s.extras.iter().flatten().map(|e| e.len()).sum();
        assert_eq!(extras, 4); // one removed slot per model
        s.merge_step(0);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 2);
    }

    #[test]
    fn prop_merging_down_to_one_step_keeps_invariant() {
        prop::check(
            "merge-invariant",
            30,
            |r| (r.range(2, 9), r.next_u64()),
            |&(n, seed)| {
                let mut s = Schedule::round_robin(n);
                let mut rng = Rng::new(seed);
                while s.num_steps() > 1 {
                    let ts = rng.below(s.num_steps());
                    s.merge_step(ts);
                    s.validate(n).map_err(|e| e)?;
                }
                // with one step, every model trains all n groups there
                for d in 0..n {
                    if s.sources(d, 0).len() != n {
                        return Err(format!("model {d} lost groups"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_weighted_merge_keeps_invariant() {
        prop::check(
            "weighted-merge-invariant",
            30,
            |r| (r.range(2, 9), r.next_u64()),
            |&(n, seed)| {
                let mut s = Schedule::round_robin(n);
                let mut rng = Rng::new(seed);
                // arbitrary positive weights
                let weights: Vec<f64> = (0..n)
                    .map(|_| 0.5 + rng.below(8) as f64 * 0.5)
                    .collect();
                while s.num_steps() > 1 {
                    let ts = rng.below(s.num_steps());
                    s.merge_step_weighted(ts, &weights);
                    s.validate(n).map_err(|e| e)?;
                }
                for d in 0..n {
                    if s.sources(d, 0).len() != n {
                        return Err(format!("model {d} lost groups"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn weighted_merge_avoids_the_slow_server() {
        // 4 servers, server 0 twice as slow: no displaced group may be
        // re-placed on a slot whose training server is 0 while a fast
        // empty slot exists
        let mut s = Schedule::round_robin(4);
        let weights = [2.0, 1.0, 1.0, 1.0];
        s.merge_step_weighted(0, &weights);
        s.validate(4).unwrap();
        for d in 0..4 {
            for t in 0..s.num_steps() {
                if s.visits[d][t] == 0 {
                    assert!(
                        s.extras[d][t].is_empty(),
                        "model {d}: straggler slot {t} absorbed extras"
                    );
                }
            }
        }
    }

    #[test]
    fn fabric_aware_controller_uses_observed_weights() {
        let mut c = MergeController::new(3, true, Selection::FabricAware, 4);
        // step 0 is lightest by raw count, but its load sits on fast
        // servers; step 1's load sits on the straggler (server 0), so
        // its *weighted* cost is what the controller must not pick...
        // selection removes the *cheapest-to-re-place* step: step 0
        // slot_loads[t][server]
        let slot_loads = vec![
            vec![0, 20, 20],  // step 0: 40 on fast servers
            vec![30, 0, 15],  // step 1: 30 on the straggler
            vec![25, 25, 0],  // step 2
        ];
        let weights = vec![4.0, 1.0, 1.0];
        // weighted costs: step0 = 40, step1 = 135, step2 = 125
        c.end_epoch_observed(10.0, &slot_loads, &weights);
        assert_eq!(c.schedule.num_steps(), 2);
        c.schedule.validate(3).unwrap();
        // with uniform weights the same feedback picks min raw load
        // (step 1: 45 < step 0: 40? no — step 0 is 40, still min), so
        // check a case where weighting flips the argmin:
        let mut c2 = MergeController::new(3, true, Selection::FabricAware, 4);
        let flip = vec![
            vec![30, 0, 0],   // step 0: raw 30 (min), all on straggler
            vec![0, 20, 15],  // step 1: raw 35, weighted 35
            vec![0, 25, 20],  // step 2: raw 45
        ];
        // weighted: step0 = 120, step1 = 35, step2 = 45 -> merge step 1
        c2.end_epoch_observed(10.0, &flip, &weights);
        let mut c3 = MergeController::new(3, true, Selection::MinLoad, 4);
        c3.end_epoch(10.0, &[30, 35, 45]); // min-load merges step 0
        assert_ne!(
            c2.schedule.visits[0], c3.schedule.visits[0],
            "weighting must flip the selection"
        );
    }

    #[test]
    fn fabric_aware_without_observation_falls_back_to_min_load() {
        let mut c = MergeController::new(4, true, Selection::FabricAware, 5);
        c.end_epoch(10.0, &[100, 50, 100, 100]);
        assert_eq!(c.schedule.num_steps(), 3);
        c.schedule.validate(4).unwrap();
    }

    #[test]
    fn controller_probes_then_freezes_on_regression() {
        let mut c = MergeController::new(4, true, Selection::MinLoad, 1);
        assert_eq!(c.schedule.num_steps(), 4);
        // epoch 0 (baseline) -> first merge
        c.end_epoch(10.0, &[100, 50, 100, 100]);
        assert_eq!(c.schedule.num_steps(), 3);
        // improved -> merge again
        c.end_epoch(8.0, &[120, 110, 120]);
        assert_eq!(c.schedule.num_steps(), 2);
        // regressed -> revert to 3 steps and freeze (Fig 17's trajectory)
        c.end_epoch(9.5, &[200, 150]);
        assert_eq!(c.schedule.num_steps(), 3);
        assert!(c.frozen());
        // further feedback is a no-op
        c.end_epoch(1.0, &[1, 1, 1]);
        assert_eq!(c.schedule.num_steps(), 3);
    }

    #[test]
    fn min_load_picks_lightest() {
        let mut c = MergeController::new(3, true, Selection::MinLoad, 2);
        c.end_epoch(5.0, &[50, 10, 50]);
        // step 1 was merged: model 0's step list is servers [0, 2]
        assert_eq!(c.schedule.visits[0], vec![0, 2]);
    }

    #[test]
    fn disabled_controller_never_merges() {
        let mut c = MergeController::new(4, false, Selection::MinLoad, 3);
        c.end_epoch(10.0, &[1, 1, 1, 1]);
        c.end_epoch(5.0, &[1, 1, 1, 1]);
        assert_eq!(c.schedule.num_steps(), 4);
    }
}
