//! Micrograph merging (§5.3): the adaptive controller that folds the
//! lightest time step into the remaining ones, trading extra remote
//! feature fetches against fewer kernel switches and synchronizations.
//!
//! Schedule representation (Fig 10's matrix): `visits[d][t]` is the
//! server hosting model `d` at time step `t` (each column is a
//! permutation — models always train on distinct servers). `extras[d][t]`
//! lists home servers whose root groups were merged into slot `(d, t)`:
//! those micrographs are trained wherever model `d` is, with their
//! features fetched from the (removed) home server.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Schedule {
    pub visits: Vec<Vec<usize>>,
    pub extras: Vec<Vec<Vec<usize>>>,
}

impl Schedule {
    /// Initial round-robin schedule: T = N, model d at server (d+t) % N.
    pub fn round_robin(num_servers: usize) -> Self {
        let visits = (0..num_servers)
            .map(|d| (0..num_servers).map(|t| (d + t) % num_servers).collect())
            .collect();
        let extras = vec![vec![Vec::new(); num_servers]; num_servers];
        Self { visits, extras }
    }

    pub fn num_steps(&self) -> usize {
        self.visits.first().map(|v| v.len()).unwrap_or(0)
    }

    pub fn num_models(&self) -> usize {
        self.visits.len()
    }

    /// All home servers whose root group trains in slot `(d, t)`:
    /// the primary (visited) server plus merged extras.
    pub fn sources(&self, d: usize, t: usize) -> Vec<usize> {
        let mut out = vec![self.visits[d][t]];
        out.extend(self.extras[d][t].iter().copied());
        out
    }

    /// Remove time step `ts` and redistribute its root groups across the
    /// surviving steps of the same model, round-robin ("as evenly as
    /// possible", §5.3).
    pub fn merge_step(&mut self, ts: usize) {
        assert!(self.num_steps() > 1, "cannot merge the last step");
        assert!(ts < self.num_steps());
        for d in 0..self.num_models() {
            let removed_primary = self.visits[d].remove(ts);
            let removed_extras = self.extras[d].remove(ts);
            let steps = self.visits[d].len();
            let mut sources = vec![removed_primary];
            sources.extend(removed_extras);
            for (i, src) in sources.into_iter().enumerate() {
                // spread across surviving steps, offset by model id so
                // different models load different steps first
                let slot = (d + i) % steps;
                self.extras[d][slot].push(src);
            }
        }
    }

    /// Invariant (Fig 10): each model still trains every home server's
    /// root group exactly once, and each step's primaries are distinct.
    pub fn validate(&self, num_servers: usize) -> Result<(), String> {
        for d in 0..self.num_models() {
            let mut seen = vec![false; num_servers];
            for t in 0..self.num_steps() {
                for s in self.sources(d, t) {
                    if seen[s] {
                        return Err(format!(
                            "model {d}: server {s} trained twice"
                        ));
                    }
                    seen[s] = true;
                }
            }
            if !seen.iter().all(|&x| x) {
                return Err(format!("model {d}: some server never trained"));
            }
        }
        for t in 0..self.num_steps() {
            let mut seen = vec![false; num_servers];
            for d in 0..self.num_models() {
                let s = self.visits[d][t];
                if seen[s] {
                    return Err(format!(
                        "step {t}: two models on server {s}"
                    ));
                }
                seen[s] = true;
            }
        }
        Ok(())
    }
}

/// Which step to merge (Fig 18 compares the paper's min-load selection
/// against random).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// The paper's scheme: merge the step with the fewest root vertices.
    MinLoad,
    /// Ablation baseline (RD in Fig 18).
    Random,
}

/// Cross-epoch adaptive controller: starting from the second epoch, merge
/// one step per epoch while the measured epoch time keeps improving;
/// revert the last merge and freeze once it stops (§5.3).
pub struct MergeController {
    pub schedule: Schedule,
    pub enabled: bool,
    selection: Selection,
    prev_schedule: Option<Schedule>,
    prev_epoch_time: Option<f64>,
    frozen: bool,
    rng: Rng,
    /// (epoch, steps) history for Fig 17.
    pub history: Vec<(f64, usize)>,
}

impl MergeController {
    pub fn new(
        num_servers: usize,
        enabled: bool,
        selection: Selection,
        seed: u64,
    ) -> Self {
        Self {
            schedule: Schedule::round_robin(num_servers),
            enabled,
            selection,
            prev_schedule: None,
            prev_epoch_time: None,
            frozen: !enabled,
            rng: Rng::new(seed),
            history: Vec::new(),
        }
    }

    /// Feed back one epoch's measurement. `step_loads[t]` = total root
    /// vertices trained at step t over the epoch (the paper's Num_vertex
    /// approximation).
    pub fn end_epoch(&mut self, epoch_time: f64, step_loads: &[u64]) {
        self.history.push((epoch_time, self.schedule.num_steps()));
        if self.frozen {
            return;
        }
        match self.prev_epoch_time {
            None => {
                // first epoch done: begin probing
                self.prev_epoch_time = Some(epoch_time);
                self.try_merge(step_loads);
            }
            Some(prev) => {
                if epoch_time < prev * 0.995 {
                    self.prev_epoch_time = Some(epoch_time);
                    self.try_merge(step_loads);
                } else {
                    // merging made it worse: revert and freeze
                    if let Some(s) = self.prev_schedule.take() {
                        self.schedule = s;
                    }
                    self.frozen = true;
                }
            }
        }
    }

    fn try_merge(&mut self, step_loads: &[u64]) {
        if self.schedule.num_steps() <= 1 {
            self.frozen = true;
            return;
        }
        let ts = match self.selection {
            Selection::MinLoad => step_loads
                .iter()
                .enumerate()
                .take(self.schedule.num_steps())
                .min_by_key(|(_, &l)| l)
                .map(|(t, _)| t)
                .unwrap_or(0),
            Selection::Random => self.rng.below(self.schedule.num_steps()),
        };
        self.prev_schedule = Some(self.schedule.clone());
        self.schedule.merge_step(ts);
    }

    pub fn frozen(&self) -> bool {
        self.frozen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn round_robin_columns_are_permutations() {
        let s = Schedule::round_robin(4);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 4);
        assert_eq!(s.visits[1][2], 3);
    }

    #[test]
    fn merge_preserves_model_root_groups() {
        let mut s = Schedule::round_robin(4);
        s.merge_step(1);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 3);
        // extras were distributed
        let extras: usize = s.extras.iter().flatten().map(|e| e.len()).sum();
        assert_eq!(extras, 4); // one removed slot per model
        s.merge_step(0);
        s.validate(4).unwrap();
        assert_eq!(s.num_steps(), 2);
    }

    #[test]
    fn prop_merging_down_to_one_step_keeps_invariant() {
        prop::check(
            "merge-invariant",
            30,
            |r| (r.range(2, 9), r.next_u64()),
            |&(n, seed)| {
                let mut s = Schedule::round_robin(n);
                let mut rng = Rng::new(seed);
                while s.num_steps() > 1 {
                    let ts = rng.below(s.num_steps());
                    s.merge_step(ts);
                    s.validate(n).map_err(|e| e)?;
                }
                // with one step, every model trains all n groups there
                for d in 0..n {
                    if s.sources(d, 0).len() != n {
                        return Err(format!("model {d} lost groups"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn controller_probes_then_freezes_on_regression() {
        let mut c = MergeController::new(4, true, Selection::MinLoad, 1);
        assert_eq!(c.schedule.num_steps(), 4);
        // epoch 0 (baseline) -> first merge
        c.end_epoch(10.0, &[100, 50, 100, 100]);
        assert_eq!(c.schedule.num_steps(), 3);
        // improved -> merge again
        c.end_epoch(8.0, &[120, 110, 120]);
        assert_eq!(c.schedule.num_steps(), 2);
        // regressed -> revert to 3 steps and freeze (Fig 17's trajectory)
        c.end_epoch(9.5, &[200, 150]);
        assert_eq!(c.schedule.num_steps(), 3);
        assert!(c.frozen());
        // further feedback is a no-op
        c.end_epoch(1.0, &[1, 1, 1]);
        assert_eq!(c.schedule.num_steps(), 3);
    }

    #[test]
    fn min_load_picks_lightest() {
        let mut c = MergeController::new(3, true, Selection::MinLoad, 2);
        c.end_epoch(5.0, &[50, 10, 50]);
        // step 1 was merged: model 0's step list is servers [0, 2]
        assert_eq!(c.schedule.visits[0], vec![0, 2]);
    }

    #[test]
    fn disabled_controller_never_merges() {
        let mut c = MergeController::new(4, false, Selection::MinLoad, 3);
        c.end_epoch(10.0, &[1, 1, 1, 1]);
        c.end_epoch(5.0, &[1, 1, 1, 1]);
        assert_eq!(c.schedule.num_steps(), 4);
    }
}
