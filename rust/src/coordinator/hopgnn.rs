//! HopGNN (§5): feature-centric training via model migration.
//!
//! One iteration (Fig 9):
//!   1. **Redistribution** — every model's mini-batch roots are grouped by
//!      the server that homes their features (control-plane transfer of
//!      root ids only).
//!   2. **Micrograph generation** — each group is k-hop-sampled *at the
//!      server that will train it* (topology is replicated, §2).
//!   3. **T time steps** — at step t, model d sits on server
//!      `schedule.visits[d][t]`, trains the micrographs of its root
//!      groups assigned there (plus any groups merged in by §5.3),
//!      accumulates gradients, then migrates (params + accumulated grads)
//!      to its next server behind a step barrier.
//!   4. **Allreduce** — accumulated gradients are averaged and applied.
//!
//! Feature flags reproduce the Fig 13 ablation: `+MG` (micrograph
//! training only), `+PG` (adds pre-gathering §5.2), `All` (adds merging
//! §5.3).
//!
//! The builder emits one lane segment for redistribution + sampling +
//! pre-gathering, then a (gather →) compute segment per time step with
//! migration segments between steps. Feature gathers are overlap-
//! eligible: with the driver's overlap mode on, the pre-gather becomes a
//! true prefetch that streams in behind the step computes instead of
//! blocking the iteration head — the principled version of §5.2's
//! "gather once, early" idea. With a feature cache configured
//! ([`crate::config::RunConfig::cache_policy`]) the (pre-)gathers are
//! emitted as `CacheFetch` ops, so rows still resident from earlier
//! iterations skip the fetch entirely — §5.2 dedups within the
//! iteration, the cache dedups across them.

use super::merge::{MergeController, Selection};
use super::ops::{Op, Phase, ProgramBuilder};
use super::{sample_group, EpochDriver, SampleTape, SimEnv, Strategy};
use crate::cluster::TransferKind;
use crate::featstore::tier::TierStack;
use crate::metrics::EpochMetrics;
use crate::sampler::SampleScratch;
use crate::util::pool::LanePool;

pub struct HopGnn {
    pub pregather: bool,
    pub merging: bool,
    pub selection: Selection,
    controller: Option<MergeController>,
    /// Warm feature tier stacks carried across epochs when
    /// `RunConfig::cache_persist` is set (otherwise every epoch's
    /// driver session builds its own cold stacks).
    tiers: Option<Vec<TierStack>>,
    /// The persistent lane-executor pool, carried across epochs like
    /// the scratch/builder state: the whole run pays the lane-worker
    /// spawn cost once.
    pool: Option<LanePool>,
    epoch_idx: u64,
    /// Reusable sampler scratch: one interner + buffer set for every
    /// root of every iteration of every epoch.
    scratch: SampleScratch,
    /// Persistent program builder: op lanes, item vectors, and gather
    /// payload buffers cycle through its pools (`take`/`recycle`), so
    /// steady-state iterations emit their op stream with zero heap
    /// allocation.
    builder: Option<ProgramBuilder>,
    /// `groups[d][s]` = model `d`'s mini-batch roots homed at server
    /// `s` (the redistribution step), cleared and refilled per
    /// iteration.
    groups: Vec<Vec<Vec<u32>>>,
    /// `slot_verts[t * n + srv]` = flattened sampled vertices trained
    /// on `srv` at step `t` this iteration; the buffers are swapped
    /// into gather ops and come back through the builder pools.
    slot_verts: Vec<Vec<u32>>,
    /// Summed vertex / edge counts per slot (the `Op::Compute`
    /// operands).
    slot_v: Vec<u64>,
    slot_e: Vec<u64>,
}

impl HopGnn {
    pub fn full() -> Self {
        Self::with_flags(true, true, Selection::MinLoad)
    }

    pub fn mg_only() -> Self {
        Self::with_flags(false, false, Selection::MinLoad)
    }

    pub fn mg_pg() -> Self {
        Self::with_flags(true, false, Selection::MinLoad)
    }

    /// Fig 18's RD baseline: merging with random step selection.
    /// Reachable end-to-end as the `hopgnn+rd` spec (`--strategy rd`).
    pub fn random_merge() -> Self {
        Self::with_flags(true, true, Selection::Random)
    }

    /// Fabric-aware merging: the controller weights per-worker
    /// micrograph counts by observed lane compute times, so merging
    /// load-balances away from stragglers. Reachable end-to-end as the
    /// `hopgnn+fa` spec (`--strategy fa`).
    pub fn fabric_aware() -> Self {
        Self::with_flags(true, true, Selection::FabricAware)
    }

    pub fn with_flags(
        pregather: bool,
        merging: bool,
        selection: Selection,
    ) -> Self {
        Self {
            pregather,
            merging,
            selection,
            controller: None,
            tiers: None,
            pool: None,
            epoch_idx: 0,
            scratch: SampleScratch::new(),
            builder: None,
            groups: Vec::new(),
            slot_verts: Vec::new(),
            slot_v: Vec::new(),
            slot_e: Vec::new(),
        }
    }

    /// Merge-controller history (epoch_time, steps) — Fig 17's series.
    pub fn merge_history(&self) -> &[(f64, usize)] {
        self.controller
            .as_ref()
            .map(|c| c.history.as_slice())
            .unwrap_or(&[])
    }
}

impl Strategy for HopGnn {
    fn name(&self) -> &'static str {
        if self.merging {
            if self.selection == Selection::FabricAware {
                "HopGNN-FA"
            } else {
                "HopGNN"
            }
        } else if self.pregather {
            "+PG"
        } else {
            "+MG"
        }
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let cached = env.cfg.cache_enabled();
        let controller = self.controller.get_or_insert_with(|| {
            MergeController::new(
                n,
                self.merging,
                self.selection,
                env.cfg.seed ^ 0x3E46,
            )
        });
        let schedule = controller.schedule.clone();
        let t_steps = schedule.num_steps();

        // Sampled-epoch memoization: under `memo::run`, identical
        // sampling inputs (dataset, sampler config, seed, epoch, and
        // the merge trajectory captured by the schedule fingerprint)
        // replay a recorded vertex tape instead of re-walking the
        // graph. The fork below still runs either way so the parent
        // RNG stream stays cell-independent.
        let mut tape = SampleTape::for_epoch(
            env,
            0x40B,
            self.epoch_idx,
            schedule.fingerprint(),
        );
        let mut rng = env.rng.fork(0x40B ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        let param_bytes = env.shape.param_bytes();
        // slot_loads[t][server] = root vertices trained on `server` at
        // step t over the epoch (summed over servers this is the
        // paper's Num_vertex step load)
        let mut slot_loads = vec![vec![0u64; n]; t_steps];
        // unscaled compute seconds emitted per server — dividing the
        // observed lane busy time by this measures each server's
        // effective slowdown for the fabric-aware controller
        let mut ideal_secs = vec![0.0f64; n];
        let mut db = EpochDriver::builder(env);
        if let Some(t) = self.tiers.take() {
            db = db.tiers(t);
        }
        if let Some(p) = self.pool.take() {
            db = db.pool(p);
        }
        let mut driver = db.build();

        let pregather = self.pregather;
        let mut b = match self.builder.take() {
            Some(b) if b.num_servers() == n => b,
            _ => ProgramBuilder::new(n),
        };
        let HopGnn {
            scratch,
            groups,
            slot_verts,
            slot_v,
            slot_e,
            ..
        } = self;
        if groups.len() != n || groups.first().map(Vec::len) != Some(n) {
            *groups = vec![vec![Vec::new(); n]; n];
        }
        for v in slot_verts.iter_mut() {
            v.clear();
        }
        slot_verts.resize_with(t_steps * n, Vec::new);

        for minibatches in &iterations {
            // (1) redistribution: group roots by home server; ship ids
            for (d, mb) in minibatches.iter().enumerate() {
                let per_server = &mut groups[d];
                for g in per_server.iter_mut() {
                    g.clear();
                }
                for &r in mb {
                    per_server[env.partition.home(r) as usize].push(r);
                }
            }
            for (d, per_server) in groups.iter().enumerate() {
                for (s, roots) in per_server.iter().enumerate() {
                    if s != d && !roots.is_empty() {
                        b.op(s, Op::Migrate {
                            from: d,
                            kind: TransferKind::Control,
                            bytes: 4 * roots.len() as u64,
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                    }
                }
            }

            // (2) micrograph generation: sample each slot's groups at the
            // server that will train them. slot_verts[t*n+srv] collects
            // the flattened vertices trained on srv at step t; slot_v /
            // slot_e the matching vertex/edge totals.
            slot_v.clear();
            slot_v.resize(t_steps * n, 0);
            slot_e.clear();
            slot_e.resize(t_steps * n, 0);
            for (d, per_server) in groups.iter().enumerate() {
                for (t, loads) in slot_loads.iter_mut().enumerate() {
                    let srv = schedule.visits[d][t];
                    for src in std::iter::once(srv)
                        .chain(schedule.extras[d][t].iter().copied())
                    {
                        let roots = &per_server[src];
                        if roots.is_empty() {
                            continue;
                        }
                        loads[srv] += roots.len() as u64;
                        let idx = t * n + srv;
                        let (v, e) = sample_group(
                            env,
                            roots,
                            &mut rng,
                            scratch,
                            &mut tape,
                            &mut slot_verts[idx],
                        );
                        slot_v[idx] += v;
                        slot_e[idx] += e;
                        b.op(srv, Op::Sample { vertices: v });
                    }
                }
            }

            // (3a) pre-gathering (§5.2): one merged fetch per server for
            // the whole iteration. The per-step payload buffers are moved
            // into the op and recycled through the builder pools.
            if pregather {
                for srv in 0..n {
                    let mut steps = b.sbuf();
                    for t in 0..t_steps {
                        let mut buf = b.vbuf();
                        std::mem::swap(&mut buf, &mut slot_verts[t * n + srv]);
                        steps.push(buf);
                    }
                    b.op(srv, Op::gather_merged(cached, steps, true));
                }
                b.barrier();
            }

            // (3b) the T time steps
            for t in 0..t_steps {
                for srv in 0..n {
                    let idx = t * n + srv;
                    if slot_v[idx] == 0 {
                        continue; // §5.1 special case: idle this step
                    }
                    if !pregather {
                        let mut verts = b.vbuf();
                        std::mem::swap(&mut verts, &mut slot_verts[idx]);
                        b.op(srv, Op::gather(cached, verts, true));
                    }
                    let (v, e) = (slot_v[idx], slot_e[idx]);
                    ideal_secs[srv] +=
                        env.cfg.cost.train_time(&env.shape, v, e);
                    b.op(srv, Op::Compute { v, e });
                }

                // step barrier + model migration (params + accumulated
                // grads travel together, Fig 9)
                b.barrier();
                if t + 1 < t_steps {
                    for d in 0..n {
                        let from = schedule.visits[d][t];
                        let to = schedule.visits[d][t + 1];
                        if from == to {
                            continue;
                        }
                        b.op(to, Op::Migrate {
                            from,
                            kind: TransferKind::ModelParams,
                            bytes: param_bytes,
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                        b.op(to, Op::Migrate {
                            from,
                            kind: TransferKind::Gradient,
                            bytes: param_bytes,
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                    }
                    b.sync_all();
                    b.barrier();
                }
            }

            // (4) final gradient synchronization
            b.allreduce();
            let program = b.take();
            driver.exec(&program);
            b.recycle(program);
        }

        tape.finish();
        self.builder = Some(b);
        let (mut m, state) = driver.finish_state();
        if env.cfg.cache_persist {
            self.tiers = Some(state.tiers);
        }
        self.pool = state.pool;
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = t_steps as f64;
        m.dropped_roots = env.dropped_roots;

        // merging feedback (§5.3): adapt the schedule between epochs.
        // Weights = observed lane busy seconds / emitted compute
        // seconds, i.e. each server's measured slowdown (exactly 1.0 on
        // a uniform fabric, so min-load behavior is unchanged there).
        let weights: Vec<f64> = (0..n)
            .map(|s| {
                let busy = m.per_server_busy.get(s).copied().unwrap_or(0.0);
                if ideal_secs[s] > 0.0 && busy > 0.0 {
                    busy / ideal_secs[s]
                } else {
                    1.0
                }
            })
            .collect();
        let controller = self.controller.as_mut().unwrap();
        controller.end_epoch_observed(m.epoch_time, &slot_loads, &weights);
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::model_centric::ModelCentric;
    use crate::featstore::cache::CachePolicy;
    use crate::graph::datasets::small_test_dataset;

    fn cfg() -> RunConfig {
        RunConfig {
            batch_size: 64,
            num_servers: 4,
            layers: 2,
            fanout: 4,
            vmax: 32,
            max_iterations: Some(4),
            ..Default::default()
        }
    }

    #[test]
    fn mg_reduces_feature_bytes_vs_dgl() {
        // The paper's headline mechanism: micrograph training moves fewer
        // feature bytes than model-centric training (Fig 14/15).
        let d = small_test_dataset(30);
        let mut dgl_env = SimEnv::new(&d, cfg());
        let dgl = ModelCentric::new().run_epoch(&mut dgl_env);
        let mut hop_env = SimEnv::new(&d, cfg());
        let hop = HopGnn::mg_only().run_epoch(&mut hop_env);
        assert!(
            hop.bytes(TransferKind::Feature) < dgl.bytes(TransferKind::Feature),
            "hop {} !< dgl {}",
            hop.bytes(TransferKind::Feature),
            dgl.bytes(TransferKind::Feature)
        );
        assert!(hop.miss_rate() < dgl.miss_rate());
    }

    #[test]
    fn pregather_reduces_requests_and_transfers() {
        let d = small_test_dataset(31);
        let mg = HopGnn::mg_only().run_epoch(&mut SimEnv::new(&d, cfg()));
        let pg = HopGnn::mg_pg().run_epoch(&mut SimEnv::new(&d, cfg()));
        assert!(
            pg.remote_requests < mg.remote_requests,
            "pg {} !< mg {}",
            pg.remote_requests,
            mg.remote_requests
        );
        assert!(pg.remote_vertices <= mg.remote_vertices);
        // same training schedule => same compute
        assert!((pg.time_compute - mg.time_compute).abs() / mg.time_compute
                < 0.05);
    }

    #[test]
    fn merging_reduces_time_steps_over_epochs() {
        let d = small_test_dataset(32);
        let mut env = SimEnv::new(&d, cfg());
        let mut strat = HopGnn::full();
        let epochs = strat.run(&mut env, 5);
        let first = epochs.first().unwrap().time_steps_per_iter;
        let last = epochs.last().unwrap().time_steps_per_iter;
        assert_eq!(first, 4.0);
        assert!(last <= first, "steps went {first} -> {last}");
        // controller history recorded
        assert_eq!(strat.merge_history().len(), 5);
    }

    #[test]
    fn models_accumulate_migration_bytes() {
        let d = small_test_dataset(33);
        let m = HopGnn::mg_only().run_epoch(&mut SimEnv::new(&d, cfg()));
        assert!(m.bytes(TransferKind::ModelParams) > 0);
        assert!(m.bytes(TransferKind::Gradient) > 0);
        assert_eq!(m.time_steps_per_iter, 4.0);
    }

    #[test]
    fn deterministic() {
        let d = small_test_dataset(34);
        let a = HopGnn::full().run_epoch(&mut SimEnv::new(&d, cfg()));
        let b = HopGnn::full().run_epoch(&mut SimEnv::new(&d, cfg()));
        assert_eq!(a.total_bytes(), b.total_bytes());
        assert!((a.epoch_time - b.epoch_time).abs() < 1e-12);
    }

    #[test]
    fn random_merge_is_reachable_and_adapts() {
        let d = small_test_dataset(35);
        let mut env = SimEnv::new(&d, cfg());
        let mut strat = HopGnn::random_merge();
        let epochs = strat.run(&mut env, 4);
        assert_eq!(strat.merge_history().len(), 4);
        // RD still merges (selection differs, mechanism identical)
        let last_steps = epochs.last().unwrap().time_steps_per_iter;
        assert!(last_steps <= 4.0);
    }

    #[test]
    fn cache_composes_with_pregather() {
        // §5.2 dedups *within* an iteration; the feature cache dedups
        // *across* iterations on top of it
        let d = small_test_dataset(37);
        let pg = HopGnn::mg_pg().run_epoch(&mut SimEnv::new(&d, cfg()));
        let pc = HopGnn::mg_pg().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                cache_policy: CachePolicy::Lru,
                cache_mb: 64,
                ..cfg()
            },
        ));
        assert!(pc.cache_hits > 0, "cross-iteration reuse must hit");
        assert!(
            pc.bytes(TransferKind::Feature)
                < pg.bytes(TransferKind::Feature)
        );
        assert_eq!(
            pc.cache_hit_bytes + pc.cache_miss_bytes,
            pg.bytes(TransferKind::Feature)
        );
    }

    #[test]
    fn cache_persist_carries_hits_across_epochs() {
        let d = small_test_dataset(38);
        let mk = |persist| RunConfig {
            cache_policy: CachePolicy::Lru,
            cache_mb: 64,
            cache_persist: persist,
            ..cfg()
        };
        let mut cold = HopGnn::mg_pg();
        let cold_epochs = cold.run(&mut SimEnv::new(&d, mk(false)), 3);
        let mut warm = HopGnn::mg_pg();
        let warm_epochs = warm.run(&mut SimEnv::new(&d, mk(true)), 3);
        // epoch 0 starts cold either way
        assert_eq!(
            cold_epochs[0].cache_hits, warm_epochs[0].cache_hits,
            "first epoch has no prior cache to inherit"
        );
        // later epochs reuse the previous epochs' residency
        assert!(
            warm_epochs[2].cache_hits > cold_epochs[2].cache_hits,
            "persisted caches must out-hit per-epoch caches ({} !> {})",
            warm_epochs[2].cache_hits,
            cold_epochs[2].cache_hits
        );
        assert!(
            warm_epochs[2].bytes(TransferKind::Feature)
                < cold_epochs[2].bytes(TransferKind::Feature)
        );
    }

    #[test]
    fn fabric_aware_on_uniform_fabric_stays_deterministic() {
        // FA on a uniform fabric sees weights of exactly 1.0, so its
        // selection equals min-load; it must adapt and replay
        // deterministically like the other merge modes
        let d = small_test_dataset(39);
        let mut a = HopGnn::fabric_aware();
        let ea = a.run(&mut SimEnv::new(&d, cfg()), 4);
        let mut b = HopGnn::fabric_aware();
        let eb = b.run(&mut SimEnv::new(&d, cfg()), 4);
        for (x, y) in ea.iter().zip(&eb) {
            assert_eq!(x.total_bytes(), y.total_bytes());
            assert_eq!(x.epoch_time.to_bits(), y.epoch_time.to_bits());
        }
        assert_eq!(a.merge_history().len(), 4);
        assert!(
            ea.last().unwrap().time_steps_per_iter <= 4.0,
            "FA must still merge on a uniform fabric"
        );
        assert_eq!(a.name(), "HopGNN-FA");
    }

    #[test]
    fn overlap_prefetches_the_pregather() {
        let d = small_test_dataset(36);
        let serial = HopGnn::mg_pg().run_epoch(&mut SimEnv::new(&d, cfg()));
        let over = HopGnn::mg_pg().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                overlap: true,
                ..cfg()
            },
        ));
        assert_eq!(serial.total_bytes(), over.total_bytes());
        assert!(
            over.epoch_time <= serial.epoch_time,
            "overlap {} !<= serial {}",
            over.epoch_time,
            serial.epoch_time
        );
        assert!(over.time_overlap_hidden > 0.0, "prefetch must hide time");
    }
}
