//! Model-centric baseline: DGL-style data-parallel training (§2, Fig 3).
//!
//! Models never move. Each iteration every server samples the subgraph
//! for its mini-batch, gathers all its vertex features (remote misses go
//! over the network — the Fig 4 bottleneck), computes locally, and
//! allreduces gradients. The epoch compiles to one lane segment per
//! iteration (sample → gather → compute on every server) followed by an
//! allreduce; the gather is overlap-eligible, modeling DGL's prefetching
//! dataloader when the driver's overlap mode is on.

use super::ops::{Op, ProgramBuilder};
use super::{sample_group, EpochDriver, SampleTape, SimEnv, Strategy};
use crate::featstore::tier::TierStack;
use crate::metrics::EpochMetrics;
use crate::sampler::SampleScratch;
use crate::util::pool::LanePool;
use crate::util::stamp::StampedSet;

pub struct ModelCentric {
    /// Warm feature tier stacks held across epochs under
    /// `--cache-persist`.
    tiers: Option<Vec<TierStack>>,
    /// The persistent lane-executor pool, carried across epochs like
    /// the scratch/builder state: the whole run pays the lane-worker
    /// spawn cost once.
    pool: Option<LanePool>,
    epoch_idx: u64,
    /// Reusable sampler scratch (zero steady-state allocation).
    scratch: SampleScratch,
    /// Generation-stamped dedup set replaying `Subgraph::union_of`'s
    /// first-occurrence order without rebuilding a hash set per batch.
    seen: StampedSet,
    /// Persistent program builder; op and payload buffers recycle
    /// through its pools across iterations.
    builder: Option<ProgramBuilder>,
}

impl ModelCentric {
    pub fn new() -> Self {
        Self {
            tiers: None,
            pool: None,
            epoch_idx: 0,
            scratch: SampleScratch::new(),
            seen: StampedSet::default(),
            builder: None,
        }
    }
}

impl Default for ModelCentric {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for ModelCentric {
    fn name(&self) -> &'static str {
        "DGL"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let cached = env.cfg.cache_enabled();
        // Sampled-epoch memoization (baseline epochs have no merge
        // schedule, so the schedule fingerprint slot is constant).
        let mut tape = SampleTape::for_epoch(env, 0xD61, self.epoch_idx, 0);
        let mut rng = env.rng.fork(0xD61 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        let mut db = EpochDriver::builder(env);
        if let Some(t) = self.tiers.take() {
            db = db.tiers(t);
        }
        if let Some(p) = self.pool.take() {
            db = db.pool(p);
        }
        let mut driver = db.build();
        let mut b = match self.builder.take() {
            Some(b) if b.num_servers() == n => b,
            _ => ProgramBuilder::new(n),
        };
        let ModelCentric { scratch, seen, .. } = self;
        for minibatches in &iterations {
            for (server, roots) in minibatches.iter().enumerate() {
                // sample the mini-batch's micrographs; DGL merges them
                // into one subgraph (dedup) before gathering
                let mut concat = b.vbuf();
                let (summed, edges) = sample_group(
                    env,
                    roots,
                    &mut rng,
                    scratch,
                    &mut tape,
                    &mut concat,
                );
                b.op(server, Op::Sample { vertices: summed });

                // compute on the deduplicated subgraph:
                // dedup factor = unique vertices / summed vertices.
                // First-occurrence dedup matches Subgraph::union_of.
                let mut uniq = b.vbuf();
                seen.reset();
                for &v in concat.iter() {
                    if seen.insert(v) {
                        uniq.push(v);
                    }
                }
                b.give(concat);
                let dedup = if summed == 0 {
                    1.0
                } else {
                    uniq.len() as f64 / summed as f64
                };
                let e_ded = (edges as f64 * dedup) as u64;
                let v_uniq = uniq.len() as u64;

                // gather: one batched fetch per remote source, served
                // through the feature cache when one is configured
                b.op(server, Op::gather(cached, uniq, true));
                b.op(server, Op::Compute { v: v_uniq, e: e_ded });
            }
            b.allreduce();
            let program = b.take();
            driver.exec(&program);
            b.recycle(program);
        }

        tape.finish();
        self.builder = Some(b);
        let (mut m, state) = driver.finish_state();
        if env.cfg.cache_persist {
            self.tiers = Some(state.tiers);
        }
        self.pool = state.pool;
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = 1.0;
        m.dropped_roots = env.dropped_roots;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TransferKind;
    use crate::config::RunConfig;
    use crate::featstore::cache::CachePolicy;
    use crate::graph::datasets::tiny_test_dataset;

    #[test]
    fn epoch_produces_sane_metrics() {
        let d = tiny_test_dataset(20);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(3),
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let mut s = ModelCentric::new();
        let m = s.run_epoch(&mut env);
        assert!(m.epoch_time > 0.0);
        assert!(m.time_gather > 0.0, "must gather remotely");
        assert!(m.time_compute > 0.0);
        assert!(m.remote_vertices > 0);
        assert!(m.local_hits > 0);
        assert!(m.miss_rate() > 0.0 && m.miss_rate() < 1.0);
        assert_eq!(m.iterations, 3);
    }

    #[test]
    fn gather_dominates_on_highdim_features() {
        // The Fig 4 observation: with large features over a slow network,
        // gathering is the bottleneck.
        let d = crate::graph::datasets::small_test_dataset(21);
        let cfg = RunConfig {
            batch_size: 256,
            num_servers: 4,
            max_iterations: Some(3),
            feat_dim_override: Some(600),
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let m = ModelCentric::new().run_epoch(&mut env);
        assert!(
            m.gather_fraction() > 0.4,
            "gather fraction {} too low",
            m.gather_fraction()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let d = tiny_test_dataset(22);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 2,
            max_iterations: Some(2),
            ..Default::default()
        };
        let m1 = ModelCentric::new().run_epoch(&mut SimEnv::new(&d, cfg.clone()));
        let m2 = ModelCentric::new().run_epoch(&mut SimEnv::new(&d, cfg));
        assert_eq!(m1.total_bytes(), m2.total_bytes());
        assert_eq!(m1.remote_vertices, m2.remote_vertices);
        assert!((m1.epoch_time - m2.epoch_time).abs() < 1e-12);
    }

    #[test]
    fn feature_cache_cuts_refetches_across_iterations() {
        // the motivation for the cache tier: across iterations DGL
        // re-fetches the same hot remote vertices; an LRU big enough to
        // hold them turns every re-fetch into a hit
        let d = tiny_test_dataset(24);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(4),
            ..Default::default()
        };
        let base =
            ModelCentric::new().run_epoch(&mut SimEnv::new(&d, cfg.clone()));
        let cached = ModelCentric::new().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                cache_policy: CachePolicy::Lru,
                cache_mb: 64,
                ..cfg
            },
        ));
        assert!(cached.cache_hits > 0, "hot vertices must repeat");
        assert!(
            cached.bytes(TransferKind::Feature)
                < base.bytes(TransferKind::Feature)
        );
        // byte conservation: requested = skipped-by-hit + transferred
        assert_eq!(
            cached.cache_hit_bytes + cached.cache_miss_bytes,
            base.bytes(TransferKind::Feature)
        );
        assert_eq!(
            cached.cache_miss_bytes,
            cached.bytes(TransferKind::Feature)
        );
        assert!(cached.epoch_time < base.epoch_time);
    }

    #[test]
    fn overlap_hides_gather_behind_compute() {
        let d = crate::graph::datasets::small_test_dataset(23);
        let cfg = RunConfig {
            batch_size: 256,
            num_servers: 4,
            max_iterations: Some(3),
            feat_dim_override: Some(300),
            ..Default::default()
        };
        let serial = ModelCentric::new()
            .run_epoch(&mut SimEnv::new(&d, cfg.clone()));
        let overlapped = ModelCentric::new().run_epoch(&mut SimEnv::new(
            &d,
            RunConfig {
                overlap: true,
                ..cfg
            },
        ));
        assert_eq!(serial.total_bytes(), overlapped.total_bytes());
        assert!(
            overlapped.epoch_time < serial.epoch_time,
            "overlap {} !< serial {}",
            overlapped.epoch_time,
            serial.epoch_time
        );
        assert!(overlapped.time_overlap_hidden > 0.0);
    }
}
