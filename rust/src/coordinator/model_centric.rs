//! Model-centric baseline: DGL-style data-parallel training (§2, Fig 3).
//!
//! Models never move. Each iteration every server samples the subgraph
//! for its mini-batch, gathers all its vertex features (remote misses go
//! over the network — the Fig 4 bottleneck), computes locally, and
//! allreduces gradients.

use super::{SimEnv, Strategy};
use crate::cluster::{Clocks, NetStats};
use crate::metrics::EpochMetrics;
use crate::sampler::Subgraph;

pub struct ModelCentric {
    epoch_idx: u64,
}

impl ModelCentric {
    pub fn new() -> Self {
        Self { epoch_idx: 0 }
    }
}

impl Default for ModelCentric {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for ModelCentric {
    fn name(&self) -> &'static str {
        "DGL"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let mut clocks = Clocks::new(n);
        let mut stats = NetStats::new(n);
        let mut m = EpochMetrics::default();
        let mut rng = env.rng.fork(0xD61 ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = 1.0;
        let store = env.store();

        for minibatches in &iterations {
            for (server, roots) in minibatches.iter().enumerate() {
                // sample the mini-batch's micrographs; DGL merges them
                // into one subgraph (dedup) before gathering
                let mgs = env.sample_batch(roots, &mut rng, server,
                                           &mut clocks, &mut m);
                let sub = Subgraph::union_of(&mgs);

                // gather: one batched fetch per remote source
                let plan = store.plan(server, sub.vertices.iter().copied());
                store.execute_sim(&plan, &env.cfg.net, &env.cfg.cost,
                                  &mut clocks, &mut stats, &mut m);

                // compute on the deduplicated subgraph
                let edges: u64 = mgs.iter()
                    .map(|g| g.edges.len() as u64)
                    .sum::<u64>();
                // dedup factor: unique vertices / summed vertices
                let summed: u64 = mgs.iter()
                    .map(|g| g.num_vertices() as u64)
                    .sum::<u64>();
                let dedup = if summed == 0 {
                    1.0
                } else {
                    sub.vertices.len() as f64 / summed as f64
                };
                let e_ded = (edges as f64 * dedup) as u64;
                let dt = env.cfg.cost.train_time(
                    &env.shape,
                    sub.vertices.len() as u64,
                    e_ded,
                );
                clocks.advance_busy(server, dt);
                m.time_compute += dt;
            }
            env.allreduce_grads(&mut clocks, &mut stats, &mut m);
        }

        stats.validate().expect("byte accounting");
        m.absorb_net(&stats);
        m.epoch_time = clocks.max();
        m.gpu_busy_fraction = clocks.busy_fraction();
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::graph::datasets::tiny_test_dataset;

    #[test]
    fn epoch_produces_sane_metrics() {
        let d = tiny_test_dataset(20);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(3),
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let mut s = ModelCentric::new();
        let m = s.run_epoch(&mut env);
        assert!(m.epoch_time > 0.0);
        assert!(m.time_gather > 0.0, "must gather remotely");
        assert!(m.time_compute > 0.0);
        assert!(m.remote_vertices > 0);
        assert!(m.local_hits > 0);
        assert!(m.miss_rate() > 0.0 && m.miss_rate() < 1.0);
        assert_eq!(m.iterations, 3);
    }

    #[test]
    fn gather_dominates_on_highdim_features() {
        // The Fig 4 observation: with large features over a slow network,
        // gathering is the bottleneck.
        let d = crate::graph::datasets::small_test_dataset(21);
        let cfg = RunConfig {
            batch_size: 256,
            num_servers: 4,
            max_iterations: Some(3),
            feat_dim_override: Some(600),
            ..Default::default()
        };
        let mut env = SimEnv::new(&d, cfg);
        let m = ModelCentric::new().run_epoch(&mut env);
        assert!(
            m.gather_fraction() > 0.4,
            "gather fraction {} too low",
            m.gather_fraction()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let d = tiny_test_dataset(22);
        let cfg = RunConfig {
            batch_size: 40,
            num_servers: 2,
            max_iterations: Some(2),
            ..Default::default()
        };
        let m1 = ModelCentric::new().run_epoch(&mut SimEnv::new(&d, cfg.clone()));
        let m2 = ModelCentric::new().run_epoch(&mut SimEnv::new(&d, cfg));
        assert_eq!(m1.total_bytes(), m2.total_bytes());
        assert_eq!(m1.remote_vertices, m2.remote_vertices);
        assert!((m1.epoch_time - m2.epoch_time).abs() < 1e-12);
    }
}
