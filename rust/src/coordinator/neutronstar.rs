//! Full-batch training strategies for the §7.7 comparison (Fig 21):
//! NeutronStar-style hybrid dependency management, and the DGL full-batch
//! baseline it is compared against. Sampling is disabled in all systems
//! for this experiment (NeutronStar does not support it).
//!
//! Full-batch epoch = every vertex computes all L layers. For a
//! partitioned graph the question is how each server obtains the
//! embeddings of its *boundary* in-neighbors at every layer:
//!
//! * **DGL-FB** — always communicate: fetch raw remote features at layer
//!   0 and remote hidden embeddings at every subsequent layer.
//! * **NeutronStar** — per boundary vertex, choose the cheaper of
//!   (a) fetching its embedding each layer, or (b) redundantly computing
//!   it locally from (fetched-once) raw features — the paper's hybrid
//!   dependency management.
//! * **HopGNN-FB** (implemented in the harness by running HopGNN with
//!   fanout = full and one mega-micrograph per partition) — feature-
//!   centric: models migrate between partitions, so only boundary raw
//!   features move, once.
//!
//! Boundary fetches are overlap-eligible (they are known before the
//! epoch starts — the full-batch analogue of a deterministic prefetch
//! schedule); model migration and the per-layer barriers are not.
//!
//! Full-batch training is outside the feature-cache tier
//! (`featstore::cache`): each boundary vertex is fetched exactly once
//! per epoch already (the boundary census above is itself a perfect
//! intra-epoch dedup), and the caches are per-epoch state, so there is
//! no cross-iteration redundancy left for a cache to remove — the
//! builder keeps its aggregated per-source `Migrate` transfers and
//! `--cache` is a no-op here.

use super::ops::{Op, Phase, ProgramBuilder};
use super::{EpochDriver, SimEnv, Strategy};
use crate::cluster::TransferKind;
use crate::metrics::EpochMetrics;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FullBatchMode {
    /// Always communicate (DGL full-batch baseline).
    DglFb,
    /// Hybrid dependency management (NeutronStar).
    Hybrid,
    /// Feature-centric: models migrate across partitions (HopGNN-FB) —
    /// boundary raw features move once per epoch; per-step model
    /// migration replaces per-layer embedding exchange.
    HopFb,
}

pub struct NeutronStar {
    mode: FullBatchMode,
}

impl NeutronStar {
    pub fn new(dgl_baseline: bool) -> Self {
        Self {
            mode: if dgl_baseline {
                FullBatchMode::DglFb
            } else {
                FullBatchMode::Hybrid
            },
        }
    }

    pub fn with_mode(mode: FullBatchMode) -> Self {
        Self { mode }
    }
}

impl Strategy for NeutronStar {
    fn name(&self) -> &'static str {
        match self.mode {
            FullBatchMode::DglFb => "DGL-FB",
            FullBatchMode::Hybrid => "NeutronStar",
            FullBatchMode::HopFb => "HopGNN-FB",
        }
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let g = &env.dataset.graph;
        let part = &env.partition;
        let feat_bytes = env.feat_bytes;
        let hid_bytes = (env.shape.hidden * 4) as u64;
        let layers = env.cfg.layers as u64;

        // per server: local vertices/edges + boundary census
        let mut local_v = vec![0u64; n];
        let mut local_e = vec![0u64; n];
        // boundary_in[s][src] = remote in-neighbor instances of server s
        // homed at src (deduplicated per vertex)
        let mut boundary: Vec<std::collections::HashMap<u32, u32>> =
            vec![std::collections::HashMap::new(); n];
        for v in 0..g.num_vertices() as u32 {
            let s = part.home(v) as usize;
            local_v[s] += 1;
            for &u in g.neighbors(v) {
                local_e[s] += 1;
                if part.home(u) as usize != s {
                    // u's embedding is needed on s
                    *boundary[s].entry(u).or_insert(0) += 1;
                }
            }
        }

        let mut b = ProgramBuilder::new(n);
        let mut steps_per_iter = layers as f64;

        if self.mode == FullBatchMode::HopFb {
            // feature-centric full batch: models migrate round-robin over
            // the N partition blocks; each block's boundary raw features
            // are fetched once per epoch (pre-gathered), then every model
            // computes the block locally during its visit.
            let param_bytes = env.shape.param_bytes();
            steps_per_iter = n as f64;
            for s in 0..n {
                let mut by_src = vec![0u64; n];
                let mut remote = 0u64;
                for &u in boundary[s].keys() {
                    by_src[part.home(u) as usize] += feat_bytes;
                    remote += 1;
                }
                for (src, bytes) in by_src.iter().enumerate() {
                    if *bytes == 0 {
                        continue;
                    }
                    b.op(s, Op::Migrate {
                        from: src,
                        kind: TransferKind::Feature,
                        bytes: *bytes,
                        phase: Phase::Gather,
                        overlap: true,
                    });
                    b.op(s, Op::Tally {
                        remote_requests: 1,
                        remote_vertices: 0,
                        local_hits: 0,
                    });
                }
                b.op(s, Op::Tally {
                    remote_requests: 0,
                    remote_vertices: remote,
                    local_hits: local_v[s],
                });
            }
            for t in 0..n {
                for d in 0..n {
                    let s = (d + t) % n;
                    // each model trains its 1/N share of the block's
                    // roots during its visit
                    b.op(s, Op::Compute {
                        v: local_v[s] / n as u64,
                        e: local_e[s] / n as u64,
                    });
                }
                b.barrier();
                if t + 1 < n {
                    for d in 0..n {
                        let from = (d + t) % n;
                        let to = (d + t + 1) % n;
                        b.op(to, Op::Migrate {
                            from,
                            kind: TransferKind::ModelParams,
                            bytes: 2 * param_bytes,
                            phase: Phase::Migrate,
                            overlap: false,
                        });
                    }
                    b.sync_all();
                }
            }
        } else {
            for s in 0..n {
                // boundary handling (decided up front; the fetches are
                // emitted *before* the block compute so the driver's
                // overlap mode can stream them in behind it)
                let dgl_baseline = self.mode == FullBatchMode::DglFb;
                let mut fetch_bytes_by_src = vec![0u64; n];
                let mut remote = 0u64;
                let mut recompute_v = 0u64;
                let mut recompute_e = 0u64;
                for &u in boundary[s].keys() {
                    let src = part.home(u) as usize;
                    // (a) communicate: embedding each layer, fwd+bwd
                    let comm = 2 * layers * hid_bytes;
                    // (b) recompute: fetch raw feature once + local flops
                    // for u's 1-hop recomputation each layer
                    let deg = g.degree(u) as u64;
                    let recompute_flops = env.shape.train_flops(1, deg);
                    let recompute_cost_secs =
                        recompute_flops / env.cfg.cost.flops_per_sec;
                    // transfers are batched per source: amortized cost is
                    // bandwidth-only (latency paid once per source),
                    // priced on the actual (src -> s) fabric link
                    let comm_cost_secs =
                        comm as f64 / env.fabric.link_bandwidth(src, s);
                    if dgl_baseline || comm_cost_secs <= recompute_cost_secs
                    {
                        fetch_bytes_by_src[src] += comm;
                        remote += 1;
                    } else {
                        // raw feature moves once; compute is duplicated
                        fetch_bytes_by_src[src] += feat_bytes;
                        recompute_v += 1;
                        recompute_e += deg;
                        remote += 1;
                    }
                }
                let kind = if dgl_baseline {
                    TransferKind::Hidden
                } else {
                    TransferKind::Feature
                };
                for (src, bytes) in fetch_bytes_by_src.iter().enumerate() {
                    if *bytes == 0 {
                        continue;
                    }
                    b.op(s, Op::Migrate {
                        from: src,
                        kind,
                        bytes: *bytes,
                        phase: Phase::Gather,
                        overlap: true,
                    });
                    b.op(s, Op::Tally {
                        remote_requests: 1,
                        remote_vertices: 0,
                        local_hits: 0,
                    });
                }
                b.op(s, Op::Tally {
                    remote_requests: 0,
                    remote_vertices: remote,
                    local_hits: local_v[s],
                });

                // local compute over the partition block
                b.op(s, Op::Compute {
                    v: local_v[s],
                    e: local_e[s],
                });
                if recompute_v > 0 {
                    // incremental compute inside the same epoch executable
                    // — no extra kernel launches
                    b.op(s, Op::ComputeSecs {
                        secs: env.shape.train_flops(recompute_v, recompute_e)
                            / env.cfg.cost.flops_per_sec,
                    });
                }
            }
        }

        // per-layer barriers + final allreduce
        for _ in 0..layers {
            b.barrier();
            b.sync_all();
        }
        b.allreduce();

        let mut m = EpochDriver::run(env, &b.finish());
        m.iterations = 1;
        m.time_steps_per_iter = steps_per_iter;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::graph::datasets::tiny_test_dataset;

    fn cfg() -> RunConfig {
        RunConfig {
            num_servers: 4,
            ..Default::default()
        }
    }

    #[test]
    fn hybrid_beats_always_communicate() {
        // NeutronStar's whole point (Fig 21): hybrid dependency management
        // is no slower than always communicating.
        let d = tiny_test_dataset(70);
        let ns = NeutronStar::new(false).run_epoch(&mut SimEnv::new(&d, cfg()));
        let fb = NeutronStar::new(true).run_epoch(&mut SimEnv::new(&d, cfg()));
        assert!(
            ns.epoch_time <= fb.epoch_time,
            "ns {} !<= dgl-fb {}",
            ns.epoch_time,
            fb.epoch_time
        );
        assert!(ns.total_bytes() <= fb.total_bytes());
    }

    #[test]
    fn full_batch_touches_every_vertex() {
        let d = tiny_test_dataset(71);
        let m = NeutronStar::new(false).run_epoch(&mut SimEnv::new(&d, cfg()));
        assert_eq!(m.local_hits, 400);
        assert!(m.remote_vertices > 0);
    }
}
