//! Locality-optimized (LO) baseline (§5.1 "Limitations", §7.9, Table 3).
//!
//! Like HopGNN it redistributes roots to their feature home servers — but
//! the models never migrate: each server's model trains only the roots
//! that happen to live there. Maximum locality, minimum communication —
//! and a *biased* training sequence (each model only ever sees its own
//! partition's vertices), which is exactly the accuracy problem Table 3
//! demonstrates. Included as the accuracy foil; its epoch time is a lower
//! bound HopGNN approaches without the bias.

use super::ops::{Op, Phase, ProgramBuilder};
use super::{EpochDriver, SimEnv, Strategy};
use crate::cluster::TransferKind;
use crate::featstore::tier::TierStack;
use crate::metrics::EpochMetrics;
use crate::sampler::{sample_batch_into, SampleScratch};
use crate::util::pool::LanePool;

pub struct LocalityOpt {
    /// Warm feature tier stacks held across epochs under
    /// `--cache-persist`.
    tiers: Option<Vec<TierStack>>,
    /// The persistent lane-executor pool, carried across epochs like
    /// the scratch/builder state: the whole run pays the lane-worker
    /// spawn cost once.
    pool: Option<LanePool>,
    epoch_idx: u64,
    /// Reusable sampler scratch (zero steady-state allocation).
    scratch: SampleScratch,
    /// Persistent program builder; op and payload buffers recycle
    /// through its pools across iterations.
    builder: Option<ProgramBuilder>,
    /// Flattened iteration roots + per-home groups, reused per
    /// iteration.
    all: Vec<u32>,
    groups: Vec<Vec<u32>>,
}

impl LocalityOpt {
    pub fn new() -> Self {
        Self {
            tiers: None,
            pool: None,
            epoch_idx: 0,
            scratch: SampleScratch::new(),
            builder: None,
            all: Vec::new(),
            groups: Vec::new(),
        }
    }
}

impl Default for LocalityOpt {
    fn default() -> Self {
        Self::new()
    }
}

impl Strategy for LocalityOpt {
    fn name(&self) -> &'static str {
        "LO"
    }

    fn run_epoch(&mut self, env: &mut SimEnv) -> EpochMetrics {
        let n = env.num_servers();
        let cached = env.cfg.cache_enabled();
        let mut rng = env.rng.fork(0x10C ^ self.epoch_idx);
        self.epoch_idx += 1;

        let iterations = env.epoch_iterations();
        let mut db = EpochDriver::builder(env);
        if let Some(t) = self.tiers.take() {
            db = db.tiers(t);
        }
        if let Some(p) = self.pool.take() {
            db = db.pool(p);
        }
        let mut driver = db.build();
        let mut b = match self.builder.take() {
            Some(b) if b.num_servers() == n => b,
            _ => ProgramBuilder::new(n),
        };
        let scfg = env.cfg.sample_config();
        let LocalityOpt {
            scratch,
            all,
            groups,
            ..
        } = self;
        if groups.len() != n {
            *groups = vec![Vec::new(); n];
        }

        for minibatches in &iterations {
            // redistribute ALL roots of the iteration by home server;
            // each server's local model trains whatever landed on it
            all.clear();
            for mb in minibatches {
                all.extend_from_slice(mb);
            }
            for g in groups.iter_mut() {
                g.clear();
            }
            for &r in all.iter() {
                groups[env.partition.home(r) as usize].push(r);
            }
            for (s, roots) in groups.iter().enumerate() {
                if roots.is_empty() {
                    continue;
                }
                // ship root ids (control plane); scheduler side — only
                // the bytes matter, so charge no phase time
                b.op(s, Op::Migrate {
                    from: (s + 1) % n,
                    kind: TransferKind::Control,
                    bytes: 4 * roots.len() as u64,
                    phase: Phase::Untimed,
                    overlap: false,
                });

                let mut verts = b.vbuf();
                let stats = sample_batch_into(
                    &env.dataset.graph,
                    roots,
                    &scfg,
                    &mut rng,
                    scratch,
                    &mut verts,
                );
                b.op(s, Op::Sample {
                    vertices: stats.vertices,
                });
                // the few remote halo vertices LO's local micrographs
                // still touch are exactly the hot-set a cache retains
                b.op(s, Op::gather(cached, verts, true));
                b.op(s, Op::Compute {
                    v: stats.vertices,
                    e: stats.edges,
                });
            }
            b.allreduce();
            let program = b.take();
            driver.exec(&program);
            b.recycle(program);
        }

        self.builder = Some(b);
        let (mut m, state) = driver.finish_state();
        if env.cfg.cache_persist {
            self.tiers = Some(state.tiers);
        }
        self.pool = state.pool;
        m.iterations = iterations.len() as u64;
        m.time_steps_per_iter = 1.0;
        m.dropped_roots = env.dropped_roots;
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::hopgnn::HopGnn;
    use crate::graph::datasets::tiny_test_dataset;

    fn cfg() -> RunConfig {
        RunConfig {
            batch_size: 40,
            num_servers: 4,
            max_iterations: Some(4),
            ..Default::default()
        }
    }

    #[test]
    fn lo_moves_fewest_feature_bytes() {
        let d = tiny_test_dataset(40);
        let lo = LocalityOpt::new().run_epoch(&mut SimEnv::new(&d, cfg()));
        let hop = HopGnn::mg_only().run_epoch(&mut SimEnv::new(&d, cfg()));
        // LO trains the same micrographs HopGNN does, minus migration;
        // its feature traffic is equal (same local sampling) but it pays
        // no model migration at all.
        assert_eq!(lo.bytes(TransferKind::ModelParams), 0);
        assert!(
            lo.bytes(TransferKind::Feature)
                <= hop.bytes(TransferKind::Feature),
        );
        assert!(lo.epoch_time <= hop.epoch_time);
    }

    #[test]
    fn lo_runs_single_step() {
        let d = tiny_test_dataset(41);
        let m = LocalityOpt::new().run_epoch(&mut SimEnv::new(&d, cfg()));
        assert_eq!(m.time_steps_per_iter, 1.0);
        assert!(m.epoch_time > 0.0);
    }
}
