//! The synthetic dataset suite standing in for the paper's Table 2.
//!
//! The paper evaluates on OGB-Arxiv, OGB-Products (real features) and
//! WebGraph UK / IN / IT (random 600-d features, same as the paper, which
//! also assigns random features to these three). None of those corpora is
//! available offline, so each dataset here is a community-structured
//! power-law graph scaled to laptop size, with the same *feature
//! dimensions* as the paper and community-correlated labels + features so
//! accuracy experiments (Table 3) are meaningful. See DESIGN.md §2 for the
//! substitution argument.
//!
//! | name       | paper     | #V paper | #V here | dim | classes |
//! |------------|-----------|----------|---------|-----|---------|
//! | arxiv-s    | Arxiv     | 169 K    | 60 K    | 128 | 10      |
//! | products-s | Products  | 2.45 M   | 250 K   | 100 | 10      |
//! | uk-s       | UK        | 1 M      | 150 K   | 600 | 10      |
//! | in-s       | IN        | 1.38 M   | 200 K   | 600 | 10      |
//! | it-s       | IT        | 41.3 M   | 600 K   | 600 | 10      |
//!
//! Sizes are chosen so a paper-scale mini-batch (1024 roots, fanout 10,
//! 3 hops ≈ 110 K sampled vertex instances) touches well under half of
//! each graph — preserving the (lack of) cross-micrograph overlap that
//! the model-centric union-dedup depends on at the paper's scale.

use super::generator::{community_graph, CommunityGraphSpec};
use super::CsrGraph;
use crate::util::rng::Rng;

/// A loaded dataset: topology + labels (+ feature *generator*, so large
/// feature matrices are never materialized unless a numeric run needs
/// them — Table 2's IT features are 92 GB in the paper).
pub struct Dataset {
    pub name: &'static str,
    pub graph: CsrGraph,
    pub feat_dim: usize,
    pub classes: usize,
    pub labels: Vec<u16>,
    pub train_vertices: Vec<u32>,
    pub val_vertices: Vec<u32>,
    /// Community assignment (kept for test introspection only).
    pub community: Vec<u32>,
    feature_seed: u64,
    /// Per-class feature means, precomputed at load ([classes * feat_dim]).
    /// Regenerating these per vertex was the hot spot of tensor staging
    /// (see EXPERIMENTS.md §Perf: 6.9 µs/vertex -> 2.6 µs/vertex).
    class_means: Vec<f32>,
}

#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub feat_dim: usize,
    pub classes: usize,
    pub num_communities: usize,
    pub train_fraction: f64,
    pub seed: u64,
}

pub const ALL_SPECS: [DatasetSpec; 5] = [
    DatasetSpec {
        name: "arxiv-s",
        num_vertices: 60_000,
        num_edges: 420_000,
        feat_dim: 128,
        classes: 10,
        num_communities: 150,
        train_fraction: 0.5,
        seed: 11,
    },
    DatasetSpec {
        name: "products-s",
        num_vertices: 250_000,
        num_edges: 3_000_000,
        feat_dim: 100,
        classes: 10,
        num_communities: 600,
        train_fraction: 0.1,
        seed: 12,
    },
    DatasetSpec {
        name: "uk-s",
        num_vertices: 150_000,
        num_edges: 2_200_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 350,
        train_fraction: 0.1,
        seed: 13,
    },
    DatasetSpec {
        name: "in-s",
        num_vertices: 200_000,
        num_edges: 2_000_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 450,
        train_fraction: 0.1,
        seed: 14,
    },
    DatasetSpec {
        name: "it-s",
        num_vertices: 600_000,
        num_edges: 8_000_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 1_400,
        train_fraction: 0.05,
        seed: 15,
    },
];

pub fn spec_by_name(name: &str) -> Option<&'static DatasetSpec> {
    ALL_SPECS.iter().find(|s| s.name == name)
}

/// A tiny dataset for unit/integration tests (not part of the paper set).
pub fn tiny_test_dataset(seed: u64) -> Dataset {
    load_spec(&DatasetSpec {
        name: "tiny",
        num_vertices: 400,
        num_edges: 2_400,
        feat_dim: 16,
        classes: 4,
        num_communities: 8,
        train_fraction: 0.5,
        seed,
    })
}

/// A small-but-not-saturating dataset for strategy tests: big enough that
/// a mini-batch's micrographs do not cover the whole graph (which would
/// make the model-centric union-dedup unrealistically strong).
pub fn small_test_dataset(seed: u64) -> Dataset {
    load_spec(&DatasetSpec {
        name: "small",
        num_vertices: 3_000,
        num_edges: 20_000,
        feat_dim: 32,
        classes: 5,
        num_communities: 40,
        train_fraction: 0.3,
        seed,
    })
}

pub fn load(name: &str) -> Dataset {
    let spec = spec_by_name(name)
        .unwrap_or_else(|| panic!("unknown dataset '{name}' (try arxiv-s, products-s, uk-s, in-s, it-s)"));
    load_spec(spec)
}

pub fn load_spec(spec: &DatasetSpec) -> Dataset {
    // p_intra 0.93 reproduces the micrograph-locality levels the paper
    // measures on METIS-partitioned real graphs (Table 1: R_micro 75-95%
    // at 2-4 servers) — real social/web graphs are strongly clustered.
    let gspec = CommunityGraphSpec {
        num_vertices: spec.num_vertices,
        num_edges: spec.num_edges,
        num_communities: spec.num_communities,
        p_intra: 0.93,
        alpha: 2.5,
        seed: spec.seed,
    };
    let gen = community_graph(&gspec);
    let n = spec.num_vertices;
    let mut rng = Rng::new(spec.seed.wrapping_mul(0x9E3779B97F4A7C15));

    // Labels: community id modulo classes, with 5% label noise — enough
    // signal for a GNN to reach well-above-chance accuracy (Table 3).
    let labels: Vec<u16> = (0..n)
        .map(|v| {
            if rng.coin(0.05) {
                rng.below(spec.classes) as u16
            } else {
                (gen.community[v] as usize % spec.classes) as u16
            }
        })
        .collect();

    // Train/val split over all vertices.
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let n_train = ((n as f64) * spec.train_fraction) as usize;
    let n_val = (n / 10).min(n - n_train);
    let train_vertices = ids[..n_train].to_vec();
    let val_vertices = ids[n_train..n_train + n_val].to_vec();

    let feature_seed = spec.seed ^ 0xFEA7;
    let class_means = build_class_means(feature_seed, spec.classes,
                                        spec.feat_dim);
    Dataset {
        name: spec.name,
        graph: gen.graph,
        feat_dim: spec.feat_dim,
        classes: spec.classes,
        labels,
        train_vertices,
        val_vertices,
        community: gen.community,
        feature_seed,
        class_means,
    }
}

/// Class-conditional feature means (computed once per dataset; the per-
/// vertex synthesis used to redo these draws for every vertex).
fn build_class_means(
    feature_seed: u64,
    classes: usize,
    feat_dim: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; classes * feat_dim];
    for label in 0..classes as u64 {
        let mut class_rng = Rng::new(
            feature_seed ^ (label + 1).wrapping_mul(0x517C_C1B7_2722_0A95),
        );
        for x in out[label as usize * feat_dim..][..feat_dim].iter_mut() {
            *x = (class_rng.normal() * 1.2) as f32;
        }
    }
    out
}

impl Dataset {
    /// Bytes of one vertex's feature vector (f32).
    #[inline]
    pub fn feature_bytes(&self) -> u64 {
        (self.feat_dim * 4) as u64
    }

    /// Table 2's Vol_F.
    pub fn feature_volume_bytes(&self) -> u64 {
        self.feature_bytes() * self.graph.num_vertices() as u64
    }

    /// Synthesize the feature vector of one vertex into `out`
    /// (len == feat_dim). Features are class-conditional Gaussians:
    /// mean = unit direction per label class (deterministic), sigma = 1.
    /// Deterministic per vertex, so every server reconstructs identical
    /// features without a shared feature file.
    pub fn write_features(&self, v: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.feat_dim);
        let label = self.labels[v as usize] as usize;
        let mean = &self.class_means[label * self.feat_dim..][..self.feat_dim];
        let mut vert_rng = Rng::new(
            self.feature_seed
                ^ (v as u64 + 1).wrapping_mul(0x2545_F491_4F6C_DD1D),
        );
        // paired Box-Muller: two normals per (ln, sqrt, sincos)
        let mut i = 0;
        while i + 1 < self.feat_dim {
            let (a, b) = vert_rng.normal_pair();
            out[i] = mean[i] + a as f32;
            out[i + 1] = mean[i + 1] + b as f32;
            i += 2;
        }
        if i < self.feat_dim {
            out[i] = mean[i] + vert_rng.normal() as f32;
        }
    }

    /// Convenience: materialize features for a set of vertices (row-major).
    pub fn features_for(&self, vertices: &[u32]) -> Vec<f32> {
        let mut out = vec![0f32; vertices.len() * self.feat_dim];
        for (i, &v) in vertices.iter().enumerate() {
            self.write_features(
                v,
                &mut out[i * self.feat_dim..(i + 1) * self.feat_dim],
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dataset_loads() {
        let d = tiny_test_dataset(1);
        assert_eq!(d.graph.num_vertices(), 400);
        assert_eq!(d.labels.len(), 400);
        assert!(!d.train_vertices.is_empty());
        assert!(!d.val_vertices.is_empty());
        // train and val are disjoint
        for v in &d.val_vertices {
            assert!(!d.train_vertices.contains(v));
        }
    }

    #[test]
    fn labels_follow_communities() {
        let d = tiny_test_dataset(2);
        let mut agree = 0usize;
        for v in 0..d.graph.num_vertices() {
            if d.labels[v] as u32 == d.community[v] % d.classes as u32 {
                agree += 1;
            }
        }
        assert!(agree as f64 / d.graph.num_vertices() as f64 > 0.9);
    }

    #[test]
    fn features_deterministic_and_class_separated() {
        let d = tiny_test_dataset(3);
        let mut a = vec![0f32; d.feat_dim];
        let mut b = vec![0f32; d.feat_dim];
        d.write_features(5, &mut a);
        d.write_features(5, &mut b);
        assert_eq!(a, b);
        // two vertices with the same label share the class mean: their
        // feature dot-product should on average exceed cross-class pairs
        let same: Vec<u32> = (0..400u32)
            .filter(|&v| d.labels[v as usize] == d.labels[0])
            .take(10)
            .collect();
        let diff: Vec<u32> = (0..400u32)
            .filter(|&v| d.labels[v as usize] != d.labels[0])
            .take(10)
            .collect();
        let dot = |x: &[f32], y: &[f32]| -> f64 {
            x.iter().zip(y).map(|(a, b)| (*a * *b) as f64).sum()
        };
        d.write_features(0, &mut a);
        let mut same_sum = 0.0;
        for &v in &same[1..] {
            d.write_features(v, &mut b);
            same_sum += dot(&a, &b);
        }
        let mut diff_sum = 0.0;
        for &v in &diff {
            d.write_features(v, &mut b);
            diff_sum += dot(&a, &b);
        }
        assert!(
            same_sum / (same.len() - 1) as f64 > diff_sum / diff.len() as f64,
            "same {same_sum} diff {diff_sum}"
        );
    }

    #[test]
    fn volumes_scale_with_dim() {
        let d = tiny_test_dataset(4);
        assert_eq!(d.feature_bytes(), 64);
        assert_eq!(d.feature_volume_bytes(), 64 * 400);
    }

    #[test]
    fn all_specs_resolvable() {
        for s in &ALL_SPECS {
            assert!(spec_by_name(s.name).is_some());
        }
        assert!(spec_by_name("nope").is_none());
    }
}
