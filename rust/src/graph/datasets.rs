//! The synthetic dataset suite standing in for the paper's Table 2.
//!
//! The paper evaluates on OGB-Arxiv, OGB-Products (real features) and
//! WebGraph UK / IN / IT (random 600-d features, same as the paper, which
//! also assigns random features to these three). None of those corpora is
//! available offline, so each dataset here is a community-structured
//! power-law graph scaled to laptop size, with the same *feature
//! dimensions* as the paper and community-correlated labels + features so
//! accuracy experiments (Table 3) are meaningful. See DESIGN.md §2 for the
//! substitution argument.
//!
//! | name       | paper     | #V paper | #V here | dim | classes |
//! |------------|-----------|----------|---------|-----|---------|
//! | arxiv-s    | Arxiv     | 169 K    | 60 K    | 128 | 10      |
//! | products-s | Products  | 2.45 M   | 250 K   | 100 | 10      |
//! | uk-s       | UK        | 1 M      | 150 K   | 600 | 10      |
//! | in-s       | IN        | 1.38 M   | 200 K   | 600 | 10      |
//! | it-s       | IT        | 41.3 M   | 600 K   | 600 | 10      |
//!
//! Sizes are chosen so a paper-scale mini-batch (1024 roots, fanout 10,
//! 3 hops ≈ 110 K sampled vertex instances) touches well under half of
//! each graph — preserving the (lack of) cross-micrograph overlap that
//! the model-centric union-dedup depends on at the paper's scale.
//!
//! # `synth:` — parametric datasets beyond the named suite
//!
//! Anywhere a dataset name is accepted (CLI `--dataset`, sweep axes),
//! a `synth:` spec generates a community power-law graph on demand via
//! the memory-bounded chunk-streamed builder
//! ([`generator::community_graph_chunked`]), so billion-edge graphs
//! never materialize an unsorted edge list:
//!
//! ```text
//! synth:v=1e8,e=1e9,alpha=2.1
//! ```
//!
//! Keys (`v` and `e` required, the rest optional):
//!
//! | key     | meaning                        | default          |
//! |---------|--------------------------------|------------------|
//! | `v`     | vertices (int or 1e8 notation) | — required       |
//! | `e`     | target undirected edges        | — required       |
//! | `alpha` | degree power-law exponent      | 2.5              |
//! | `k`     | communities                    | max(v/400, 2)    |
//! | `p`     | intra-community stub fraction  | 0.93             |
//! | `d`     | feature dim                    | 128              |
//! | `c`     | label classes                  | 10               |
//! | `train` | train fraction                 | 0.1              |
//! | `seed`  | RNG seed                       | 42               |
//! | `chunk` | edges per streaming chunk      | 4 Mi (32 MiB)    |

use super::generator::{
    community_graph, community_graph_chunked, CommunityGraphSpec,
    GeneratedGraph, DEFAULT_CHUNK_EDGES,
};
use super::CsrGraph;
use crate::util::rng::Rng;
use crate::util::specs;

/// A loaded dataset: topology + labels (+ feature *generator*, so large
/// feature matrices are never materialized unless a numeric run needs
/// them — Table 2's IT features are 92 GB in the paper).
pub struct Dataset {
    pub name: &'static str,
    pub graph: CsrGraph,
    pub feat_dim: usize,
    pub classes: usize,
    pub labels: Vec<u16>,
    pub train_vertices: Vec<u32>,
    pub val_vertices: Vec<u32>,
    /// Community assignment (kept for test introspection only).
    pub community: Vec<u32>,
    feature_seed: u64,
    /// Per-class feature means, precomputed at load ([classes * feat_dim]).
    /// Regenerating these per vertex was the hot spot of tensor staging
    /// (see EXPERIMENTS.md §Perf: 6.9 µs/vertex -> 2.6 µs/vertex).
    class_means: Vec<f32>,
}

#[derive(Clone, Copy, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub num_vertices: usize,
    pub num_edges: usize,
    pub feat_dim: usize,
    pub classes: usize,
    pub num_communities: usize,
    pub train_fraction: f64,
    pub seed: u64,
}

pub const ALL_SPECS: [DatasetSpec; 5] = [
    DatasetSpec {
        name: "arxiv-s",
        num_vertices: 60_000,
        num_edges: 420_000,
        feat_dim: 128,
        classes: 10,
        num_communities: 150,
        train_fraction: 0.5,
        seed: 11,
    },
    DatasetSpec {
        name: "products-s",
        num_vertices: 250_000,
        num_edges: 3_000_000,
        feat_dim: 100,
        classes: 10,
        num_communities: 600,
        train_fraction: 0.1,
        seed: 12,
    },
    DatasetSpec {
        name: "uk-s",
        num_vertices: 150_000,
        num_edges: 2_200_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 350,
        train_fraction: 0.1,
        seed: 13,
    },
    DatasetSpec {
        name: "in-s",
        num_vertices: 200_000,
        num_edges: 2_000_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 450,
        train_fraction: 0.1,
        seed: 14,
    },
    DatasetSpec {
        name: "it-s",
        num_vertices: 600_000,
        num_edges: 8_000_000,
        feat_dim: 600,
        classes: 10,
        num_communities: 1_400,
        train_fraction: 0.05,
        seed: 15,
    },
];

pub fn spec_by_name(name: &str) -> Option<&'static DatasetSpec> {
    ALL_SPECS.iter().find(|s| s.name == name)
}

/// Prefix selecting the parametric generator grammar (module docs).
pub const SYNTH_PREFIX: &str = "synth:";

/// A parsed `synth:` dataset spec (see module docs for the grammar).
#[derive(Clone, Debug, PartialEq)]
pub struct SynthSpec {
    pub num_vertices: usize,
    pub num_edges: usize,
    pub num_communities: usize,
    pub p_intra: f64,
    pub alpha: f64,
    pub feat_dim: usize,
    pub classes: usize,
    pub train_fraction: f64,
    pub seed: u64,
    /// Streaming-build chunk size (edges per counting/scatter pass).
    pub chunk_edges: usize,
}

/// Parse `1e9` / `250_000` / `4096` into a count (shared grammar:
/// [`specs::parse_count`] under the `synth key '<k>'` subject).
fn parse_count(key: &str, s: &str) -> Result<usize, String> {
    specs::parse_count(&format!("synth key '{key}'"), s)
}

fn parse_frac(key: &str, s: &str) -> Result<f64, String> {
    specs::parse_frac(&format!("synth key '{key}'"), s)
}

impl SynthSpec {
    /// Parse a full `synth:k=v,...` dataset name. Fails fast with a
    /// message naming the offending key, so sweep validation can reject
    /// a bad grid before any cell runs.
    pub fn parse(name: &str) -> Result<Self, String> {
        let body = name
            .strip_prefix(SYNTH_PREFIX)
            .ok_or_else(|| format!("not a synth spec: '{name}'"))?;
        let (mut v, mut e) = (None, None);
        let mut k = None;
        let mut p = 0.93f64;
        let mut alpha = 2.5f64;
        let mut d = 128usize;
        let mut c = 10usize;
        let mut train = 0.1f64;
        let mut seed = 42u64;
        let mut chunk = DEFAULT_CHUNK_EDGES;
        for pair in body.split(',').filter(|p| !p.is_empty()) {
            let (key, val) =
                specs::split_kv(&format!("synth spec '{name}'"), pair)?;
            match key {
                "v" => v = Some(parse_count(key, val)?),
                "e" => e = Some(parse_count(key, val)?),
                "k" => k = Some(parse_count(key, val)?),
                "p" => p = parse_frac(key, val)?,
                "alpha" => alpha = parse_frac(key, val)?,
                "d" => d = parse_count(key, val)?,
                "c" => c = parse_count(key, val)?,
                "train" => train = parse_frac(key, val)?,
                "seed" => seed = parse_count(key, val)? as u64,
                "chunk" => chunk = parse_count(key, val)?,
                _ => {
                    return Err(specs::unknown_key(
                        &format!("synth spec '{name}'"),
                        key,
                        &[
                            "v", "e", "k", "p", "alpha", "d", "c", "train",
                            "seed", "chunk",
                        ],
                    ))
                }
            }
        }
        let num_vertices =
            v.ok_or_else(|| format!("synth spec '{name}': missing v="))?;
        let num_edges =
            e.ok_or_else(|| format!("synth spec '{name}': missing e="))?;
        if num_vertices < 2 || num_vertices > u32::MAX as usize {
            return Err(format!(
                "synth spec '{name}': v must be in 2..=u32::MAX"
            ));
        }
        if num_edges == 0 {
            return Err(format!("synth spec '{name}': e must be positive"));
        }
        if !(1.2..=10.0).contains(&alpha) {
            return Err(format!(
                "synth spec '{name}': alpha must be in 1.2..=10"
            ));
        }
        if !(0.0..=1.0).contains(&p) {
            return Err(format!("synth spec '{name}': p must be in 0..=1"));
        }
        if !(0.0..=1.0).contains(&train) || train == 0.0 {
            return Err(format!(
                "synth spec '{name}': train must be in (0, 1]"
            ));
        }
        if c < 2 || c > u16::MAX as usize {
            return Err(format!("synth spec '{name}': c must be in 2..=65535"));
        }
        if d == 0 {
            return Err(format!("synth spec '{name}': d must be positive"));
        }
        let num_communities = k
            .unwrap_or_else(|| (num_vertices / 400).max(2))
            .clamp(1, num_vertices);
        if chunk == 0 {
            return Err(format!("synth spec '{name}': chunk must be positive"));
        }
        Ok(Self {
            num_vertices,
            num_edges,
            num_communities,
            p_intra: p,
            alpha,
            feat_dim: d,
            classes: c,
            train_fraction: train,
            seed,
            chunk_edges: chunk,
        })
    }
}

/// Cheap name validation (no loading): used by the sweep engine to
/// fail a whole grid before any cell runs.
pub fn validate_name(name: &str) -> Result<(), String> {
    if name.starts_with(SYNTH_PREFIX) {
        SynthSpec::parse(name).map(|_| ())
    } else if spec_by_name(name).is_some() {
        Ok(())
    } else {
        Err(format!(
            "unknown dataset '{name}' (try arxiv-s, products-s, uk-s, in-s, \
             it-s, or synth:v=...,e=...)"
        ))
    }
}

/// A tiny dataset for unit/integration tests (not part of the paper set).
pub fn tiny_test_dataset(seed: u64) -> Dataset {
    load_spec(&DatasetSpec {
        name: "tiny",
        num_vertices: 400,
        num_edges: 2_400,
        feat_dim: 16,
        classes: 4,
        num_communities: 8,
        train_fraction: 0.5,
        seed,
    })
}

/// A small-but-not-saturating dataset for strategy tests: big enough that
/// a mini-batch's micrographs do not cover the whole graph (which would
/// make the model-centric union-dedup unrealistically strong).
pub fn small_test_dataset(seed: u64) -> Dataset {
    load_spec(&DatasetSpec {
        name: "small",
        num_vertices: 3_000,
        num_edges: 20_000,
        feat_dim: 32,
        classes: 5,
        num_communities: 40,
        train_fraction: 0.3,
        seed,
    })
}

pub fn load(name: &str) -> Dataset {
    if name.starts_with(SYNTH_PREFIX) {
        let spec = SynthSpec::parse(name).unwrap_or_else(|e| panic!("{e}"));
        return load_synth(name, &spec);
    }
    let spec = spec_by_name(name)
        .unwrap_or_else(|| panic!("unknown dataset '{name}' (try arxiv-s, products-s, uk-s, in-s, it-s, or synth:v=...,e=...)"));
    load_spec(spec)
}

pub fn load_spec(spec: &DatasetSpec) -> Dataset {
    // p_intra 0.93 reproduces the micrograph-locality levels the paper
    // measures on METIS-partitioned real graphs (Table 1: R_micro 75-95%
    // at 2-4 servers) — real social/web graphs are strongly clustered.
    let gspec = CommunityGraphSpec {
        num_vertices: spec.num_vertices,
        num_edges: spec.num_edges,
        num_communities: spec.num_communities,
        p_intra: 0.93,
        alpha: 2.5,
        seed: spec.seed,
    };
    let gen = community_graph(&gspec);
    assemble(
        spec.name,
        gen,
        spec.feat_dim,
        spec.classes,
        spec.train_fraction,
        spec.seed,
    )
}

/// Load a parametric `synth:` dataset via the memory-bounded
/// chunk-streamed generator — the path that keeps a `v=1e8,e=1e9`
/// graph inside the CSR-plus-one-chunk RSS budget (see
/// `generator` module docs).
pub fn load_synth(name: &str, spec: &SynthSpec) -> Dataset {
    let gspec = CommunityGraphSpec {
        num_vertices: spec.num_vertices,
        num_edges: spec.num_edges,
        num_communities: spec.num_communities,
        p_intra: spec.p_intra,
        alpha: spec.alpha,
        seed: spec.seed,
    };
    let gen = community_graph_chunked(&gspec, spec.chunk_edges);
    // datasets are process-lifetime leased (`bench::memo` leaks them),
    // so leaking the one name string per distinct spec is bounded
    let leaked: &'static str = Box::leak(name.to_string().into_boxed_str());
    assemble(
        leaked,
        gen,
        spec.feat_dim,
        spec.classes,
        spec.train_fraction,
        spec.seed,
    )
}

/// Shared tail of dataset construction (labels, split, feature means);
/// identical draw order for the named suite and `synth:` specs.
fn assemble(
    name: &'static str,
    gen: GeneratedGraph,
    feat_dim: usize,
    classes: usize,
    train_fraction: f64,
    seed: u64,
) -> Dataset {
    let n = gen.graph.num_vertices();
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B97F4A7C15));

    // Labels: community id modulo classes, with 5% label noise — enough
    // signal for a GNN to reach well-above-chance accuracy (Table 3).
    let labels: Vec<u16> = (0..n)
        .map(|v| {
            if rng.coin(0.05) {
                rng.below(classes) as u16
            } else {
                (gen.community[v] as usize % classes) as u16
            }
        })
        .collect();

    // Train/val split over all vertices.
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let n_train = ((n as f64) * train_fraction) as usize;
    let n_val = (n / 10).min(n - n_train);
    let train_vertices = ids[..n_train].to_vec();
    let val_vertices = ids[n_train..n_train + n_val].to_vec();

    let feature_seed = seed ^ 0xFEA7;
    let class_means = build_class_means(feature_seed, classes, feat_dim);
    Dataset {
        name,
        graph: gen.graph,
        feat_dim,
        classes,
        labels,
        train_vertices,
        val_vertices,
        community: gen.community,
        feature_seed,
        class_means,
    }
}

/// Class-conditional feature means (computed once per dataset; the per-
/// vertex synthesis used to redo these draws for every vertex).
fn build_class_means(
    feature_seed: u64,
    classes: usize,
    feat_dim: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; classes * feat_dim];
    for label in 0..classes as u64 {
        let mut class_rng = Rng::new(
            feature_seed ^ (label + 1).wrapping_mul(0x517C_C1B7_2722_0A95),
        );
        for x in out[label as usize * feat_dim..][..feat_dim].iter_mut() {
            *x = (class_rng.normal() * 1.2) as f32;
        }
    }
    out
}

impl Dataset {
    /// Bytes of one vertex's feature vector (f32).
    #[inline]
    pub fn feature_bytes(&self) -> u64 {
        (self.feat_dim * 4) as u64
    }

    /// Table 2's Vol_F.
    pub fn feature_volume_bytes(&self) -> u64 {
        self.feature_bytes() * self.graph.num_vertices() as u64
    }

    /// Synthesize the feature vector of one vertex into `out`
    /// (len == feat_dim). Features are class-conditional Gaussians:
    /// mean = unit direction per label class (deterministic), sigma = 1.
    /// Deterministic per vertex, so every server reconstructs identical
    /// features without a shared feature file.
    pub fn write_features(&self, v: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.feat_dim);
        let label = self.labels[v as usize] as usize;
        let mean = &self.class_means[label * self.feat_dim..][..self.feat_dim];
        let mut vert_rng = Rng::new(
            self.feature_seed
                ^ (v as u64 + 1).wrapping_mul(0x2545_F491_4F6C_DD1D),
        );
        // paired Box-Muller: two normals per (ln, sqrt, sincos)
        let mut i = 0;
        while i + 1 < self.feat_dim {
            let (a, b) = vert_rng.normal_pair();
            out[i] = mean[i] + a as f32;
            out[i + 1] = mean[i + 1] + b as f32;
            i += 2;
        }
        if i < self.feat_dim {
            out[i] = mean[i] + vert_rng.normal() as f32;
        }
    }

    /// Convenience: materialize features for a set of vertices (row-major).
    pub fn features_for(&self, vertices: &[u32]) -> Vec<f32> {
        let mut out = vec![0f32; vertices.len() * self.feat_dim];
        for (i, &v) in vertices.iter().enumerate() {
            self.write_features(
                v,
                &mut out[i * self.feat_dim..(i + 1) * self.feat_dim],
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dataset_loads() {
        let d = tiny_test_dataset(1);
        assert_eq!(d.graph.num_vertices(), 400);
        assert_eq!(d.labels.len(), 400);
        assert!(!d.train_vertices.is_empty());
        assert!(!d.val_vertices.is_empty());
        // train and val are disjoint
        for v in &d.val_vertices {
            assert!(!d.train_vertices.contains(v));
        }
    }

    #[test]
    fn labels_follow_communities() {
        let d = tiny_test_dataset(2);
        let mut agree = 0usize;
        for v in 0..d.graph.num_vertices() {
            if d.labels[v] as u32 == d.community[v] % d.classes as u32 {
                agree += 1;
            }
        }
        assert!(agree as f64 / d.graph.num_vertices() as f64 > 0.9);
    }

    #[test]
    fn features_deterministic_and_class_separated() {
        let d = tiny_test_dataset(3);
        let mut a = vec![0f32; d.feat_dim];
        let mut b = vec![0f32; d.feat_dim];
        d.write_features(5, &mut a);
        d.write_features(5, &mut b);
        assert_eq!(a, b);
        // two vertices with the same label share the class mean: their
        // feature dot-product should on average exceed cross-class pairs
        let same: Vec<u32> = (0..400u32)
            .filter(|&v| d.labels[v as usize] == d.labels[0])
            .take(10)
            .collect();
        let diff: Vec<u32> = (0..400u32)
            .filter(|&v| d.labels[v as usize] != d.labels[0])
            .take(10)
            .collect();
        let dot = |x: &[f32], y: &[f32]| -> f64 {
            x.iter().zip(y).map(|(a, b)| (*a * *b) as f64).sum()
        };
        d.write_features(0, &mut a);
        let mut same_sum = 0.0;
        for &v in &same[1..] {
            d.write_features(v, &mut b);
            same_sum += dot(&a, &b);
        }
        let mut diff_sum = 0.0;
        for &v in &diff {
            d.write_features(v, &mut b);
            diff_sum += dot(&a, &b);
        }
        assert!(
            same_sum / (same.len() - 1) as f64 > diff_sum / diff.len() as f64,
            "same {same_sum} diff {diff_sum}"
        );
    }

    #[test]
    fn volumes_scale_with_dim() {
        let d = tiny_test_dataset(4);
        assert_eq!(d.feature_bytes(), 64);
        assert_eq!(d.feature_volume_bytes(), 64 * 400);
    }

    #[test]
    fn all_specs_resolvable() {
        for s in &ALL_SPECS {
            assert!(spec_by_name(s.name).is_some());
        }
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn validate_name_accepts_suite_and_synth() {
        for s in &ALL_SPECS {
            assert!(validate_name(s.name).is_ok());
        }
        assert!(validate_name("synth:v=1e4,e=5e4").is_ok());
        assert!(validate_name("synth:v=1e8,e=1e9,alpha=2.1").is_ok());
    }

    #[test]
    fn validate_name_rejects_with_diagnostics() {
        let e = validate_name("prodcts-s").unwrap_err();
        assert!(e.contains("unknown dataset 'prodcts-s'"), "{e}");
        let e = validate_name("synth:e=5e4").unwrap_err();
        assert!(e.contains("missing v="), "{e}");
        let e = validate_name("synth:v=1e4,e=5e4,fanout=10").unwrap_err();
        assert!(e.contains("unknown key 'fanout'"), "{e}");
        let e = validate_name("synth:v=abc,e=5e4").unwrap_err();
        assert!(e.contains("cannot parse number 'abc'"), "{e}");
        let e = validate_name("synth:v=1e4,e=5e4,alpha=0.3").unwrap_err();
        assert!(e.contains("alpha"), "{e}");
    }

    #[test]
    fn synth_spec_defaults_and_overrides() {
        let s = SynthSpec::parse("synth:v=2_000,e=8000").unwrap();
        assert_eq!(s.num_vertices, 2000);
        assert_eq!(s.num_edges, 8000);
        assert_eq!(s.num_communities, 5); // v/400
        assert_eq!(s.feat_dim, 128);
        assert_eq!(s.classes, 10);
        assert_eq!(s.seed, 42);
        assert_eq!(s.chunk_edges, DEFAULT_CHUNK_EDGES);
        let s = SynthSpec::parse(
            "synth:v=1e4,e=4e4,k=32,p=0.8,alpha=2.1,d=16,c=4,train=0.3,seed=7,chunk=512",
        )
        .unwrap();
        assert_eq!(s.num_communities, 32);
        assert_eq!(s.p_intra, 0.8);
        assert_eq!(s.alpha, 2.1);
        assert_eq!(s.feat_dim, 16);
        assert_eq!(s.classes, 4);
        assert_eq!(s.train_fraction, 0.3);
        assert_eq!(s.seed, 7);
        assert_eq!(s.chunk_edges, 512);
    }

    #[test]
    fn synth_dataset_loads_end_to_end() {
        let d = load("synth:v=2000,e=8000,d=16,c=4,seed=7");
        assert_eq!(d.graph.num_vertices(), 2000);
        assert_eq!(d.feat_dim, 16);
        assert_eq!(d.classes, 4);
        assert_eq!(d.labels.len(), 2000);
        assert!(!d.train_vertices.is_empty());
        assert!(!d.val_vertices.is_empty());
        let mut f = vec![0f32; d.feat_dim];
        d.write_features(3, &mut f);
        assert!(f.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn synth_chunk_size_does_not_change_the_dataset() {
        // chunk is a buffering knob, not a semantic one: any chunk size
        // yields a bit-identical graph and labels
        let base = load("synth:v=1500,e=6000,seed=9");
        let alt = load("synth:v=1500,e=6000,seed=9,chunk=64");
        assert_eq!(base.graph, alt.graph);
        assert_eq!(base.labels, alt.labels);
        assert_eq!(base.train_vertices, alt.train_vertices);
    }

    #[test]
    #[should_panic(expected = "unknown dataset 'nope'")]
    fn load_panics_on_unknown() {
        load("nope");
    }
}
