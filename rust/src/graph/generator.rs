//! Synthetic graph generators.
//!
//! The paper's locality phenomenon (Table 1) arises because real graphs
//! have community structure that METIS-style partitioners recover. The
//! planted-partition + power-law generator reproduces exactly that: a
//! power-law degree sequence (Chung–Lu stubs) with a tunable fraction of
//! intra-community edges. An R-MAT generator is included for adversarial
//! low-locality workloads (used by ablation benches).

use super::CsrGraph;
use crate::util::rng::Rng;

/// Parameters for the community-structured power-law generator.
#[derive(Clone, Debug)]
pub struct CommunityGraphSpec {
    pub num_vertices: usize,
    /// Target undirected edge count (approximate; duplicates collapse).
    pub num_edges: usize,
    pub num_communities: usize,
    /// Fraction of stubs that stay within the endpoint's community.
    pub p_intra: f64,
    /// Power-law exponent for the degree sequence (2 < alpha <= 3.5 typical).
    pub alpha: f64,
    pub seed: u64,
}

impl Default for CommunityGraphSpec {
    fn default() -> Self {
        Self {
            num_vertices: 10_000,
            num_edges: 80_000,
            num_communities: 64,
            p_intra: 0.85,
            alpha: 2.5,
            seed: 1,
        }
    }
}

/// Result of generation: the graph plus each vertex's community id
/// (used downstream for label synthesis, never leaked to partitioners).
pub struct GeneratedGraph {
    pub graph: CsrGraph,
    pub community: Vec<u32>,
}

pub fn community_graph(spec: &CommunityGraphSpec) -> GeneratedGraph {
    let n = spec.num_vertices;
    let k = spec.num_communities.max(1);
    let mut rng = Rng::new(spec.seed);

    // Contiguous community blocks of roughly equal size (block layout makes
    // the ground truth easy to reason about in tests; partitioners never
    // see it).
    let community: Vec<u32> = (0..n).map(|v| ((v * k) / n) as u32).collect();
    let mut comm_start = vec![0usize; k + 1];
    for v in 0..n {
        comm_start[community[v] as usize + 1] = v + 1;
    }
    for c in 1..=k {
        if comm_start[c] == 0 {
            comm_start[c] = comm_start[c - 1];
        }
    }

    // Power-law degree targets, scaled to hit num_edges total stubs.
    let mut degs: Vec<f64> = (0..n)
        .map(|_| 1.0 + rng.powerlaw(n, spec.alpha) as f64)
        .collect();
    let total: f64 = degs.iter().sum();
    let scale = (2 * spec.num_edges) as f64 / total;
    for d in degs.iter_mut() {
        *d *= scale;
    }

    let mut edges = Vec::with_capacity(spec.num_edges + spec.num_edges / 8);
    for v in 0..n {
        let dv = degs[v];
        let stubs = dv.floor() as usize + usize::from(rng.coin(dv.fract()));
        let c = community[v] as usize;
        let (cs, ce) = (comm_start[c], comm_start[c + 1]);
        for _ in 0..stubs.div_ceil(2) {
            // each undirected edge accounts for 2 stubs
            let u = if ce > cs + 1 && rng.coin(spec.p_intra) {
                rng.range(cs, ce) as u32
            } else {
                rng.below(n) as u32
            };
            if u != v as u32 {
                edges.push((v as u32, u));
            }
        }
    }
    GeneratedGraph {
        graph: CsrGraph::from_edges(n, &edges),
        community,
    }
}

/// R-MAT (Chakrabarti et al.) — skewed but community-free; the locality
/// stress case.
pub fn rmat_graph(n_log2: u32, num_edges: usize, seed: u64) -> CsrGraph {
    let (a, b, c) = (0.57, 0.19, 0.19); // Graph500 defaults
    let n = 1usize << n_log2;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(num_edges);
    for _ in 0..num_edges {
        let (mut x, mut y) = (0usize, 0usize);
        for _ in 0..n_log2 {
            let r = rng.f64();
            let (dx, dy) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            x = (x << 1) | dx;
            y = (y << 1) | dy;
        }
        if x != y {
            edges.push((x as u32, y as u32));
        }
    }
    CsrGraph::from_edges(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn community_graph_basic_shape() {
        let spec = CommunityGraphSpec {
            num_vertices: 2000,
            num_edges: 12_000,
            num_communities: 16,
            ..Default::default()
        };
        let g = community_graph(&spec);
        assert_eq!(g.graph.num_vertices(), 2000);
        // duplicates collapse, so within 40% of target is fine
        let m = g.graph.num_edges();
        assert!(m > 7_000 && m < 16_000, "edges {m}");
        assert_eq!(g.community.len(), 2000);
        assert_eq!(*g.community.iter().max().unwrap(), 15);
    }

    #[test]
    fn intra_community_fraction_dominates() {
        let spec = CommunityGraphSpec {
            num_vertices: 4000,
            num_edges: 30_000,
            num_communities: 20,
            p_intra: 0.9,
            ..Default::default()
        };
        let g = community_graph(&spec);
        let mut intra = 0usize;
        let mut total = 0usize;
        for (u, v) in g.graph.edges() {
            total += 1;
            if g.community[u as usize] == g.community[v as usize] {
                intra += 1;
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.7, "intra fraction {frac}");
    }

    #[test]
    fn degree_sequence_is_skewed() {
        let spec = CommunityGraphSpec::default();
        let g = community_graph(&spec).graph;
        let mut degs: Vec<usize> =
            (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // top 1% of vertices should hold well above 1% of edges
        let top: usize = degs[..degs.len() / 100].iter().sum();
        let all: usize = degs.iter().sum();
        assert!(top as f64 / all as f64 > 0.05, "top share {}", top as f64 / all as f64);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = CommunityGraphSpec::default();
        let a = community_graph(&spec).graph;
        let b = community_graph(&spec).graph;
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.neighbors(7), b.neighbors(7));
    }

    #[test]
    fn rmat_shape() {
        let g = rmat_graph(10, 8000, 3);
        assert_eq!(g.num_vertices(), 1024);
        assert!(g.num_edges() > 4000);
    }
}
