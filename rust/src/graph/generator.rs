//! Synthetic graph generators.
//!
//! The paper's locality phenomenon (Table 1) arises because real graphs
//! have community structure that METIS-style partitioners recover. The
//! planted-partition + power-law generator reproduces exactly that: a
//! power-law degree sequence (Chung–Lu stubs) with a tunable fraction of
//! intra-community edges. An R-MAT generator is included for adversarial
//! low-locality workloads (used by ablation benches).
//!
//! # Memory-bounded chunk-streamed path
//!
//! Both generators also come in a chunk-streamed variant
//! ([`community_graph_chunked`], [`rmat_graph_chunked`]) that builds
//! the CSR with **counting-sort passes over fixed-size edge chunks**,
//! never materializing the unsorted edge list: pass 1 streams the
//! (deterministic, replayable) edge sequence and counts symmetrized
//! degrees; pass 2 replays the identical sequence and scatters
//! neighbors straight into the final CSR allocation, which is then
//! sorted + deduplicated *in place*. Peak RSS is therefore
//! `≈ 16·V + 8·E + 16·chunk` bytes (offsets + scatter cursors + the
//! pre-dedup neighbor array + one chunk buffer) — e.g. ~1 GiB for a
//! `V = 10⁷, E = 10⁸` graph — instead of the edge list *and* CSR
//! coexisting. The small-graph generators are the one-chunk special
//! case: [`community_graph`] and [`community_graph_chunked`] are locked
//! bit-identical for every chunk size (this module's tests +
//! `tests/generator_scale.rs`).

use super::CsrGraph;
use crate::util::rng::Rng;

/// Default chunk size (edges buffered per counting-sort pass): 4 Mi
/// edges = 32 MiB of buffer, far below the CSR arrays it avoids.
pub const DEFAULT_CHUNK_EDGES: usize = 4 << 20;

/// Parameters for the community-structured power-law generator.
#[derive(Clone, Debug)]
pub struct CommunityGraphSpec {
    pub num_vertices: usize,
    /// Target undirected edge count (approximate; duplicates collapse).
    pub num_edges: usize,
    pub num_communities: usize,
    /// Fraction of stubs that stay within the endpoint's community.
    pub p_intra: f64,
    /// Power-law exponent for the degree sequence (2 < alpha <= 3.5 typical).
    pub alpha: f64,
    pub seed: u64,
}

impl Default for CommunityGraphSpec {
    fn default() -> Self {
        Self {
            num_vertices: 10_000,
            num_edges: 80_000,
            num_communities: 64,
            p_intra: 0.85,
            alpha: 2.5,
            seed: 1,
        }
    }
}

/// Result of generation: the graph plus each vertex's community id
/// (used downstream for label synthesis, never leaked to partitioners).
pub struct GeneratedGraph {
    pub graph: CsrGraph,
    pub community: Vec<u32>,
}

/// Contiguous community blocks of roughly equal size (block layout makes
/// the ground truth easy to reason about in tests; partitioners never
/// see it). Returns per-vertex community ids and the block boundaries
/// (`comm_start[c]..comm_start[c+1]` = community `c`).
fn community_layout(n: usize, k: usize) -> (Vec<u32>, Vec<usize>) {
    let community: Vec<u32> = (0..n).map(|v| ((v * k) / n) as u32).collect();
    let mut comm_start = vec![0usize; k + 1];
    for v in 0..n {
        comm_start[community[v] as usize + 1] = v + 1;
    }
    for c in 1..=k {
        if comm_start[c] == 0 {
            comm_start[c] = comm_start[c - 1];
        }
    }
    (community, comm_start)
}

/// Stream the community generator's edge sequence to `emit`, in the
/// exact order (and from the exact RNG draws) the original in-memory
/// generator used — so the stream is replayable: calling this twice
/// with the same spec emits the identical sequence, which is what lets
/// the chunked builder regenerate edges for its second pass instead of
/// storing them. Degree targets are re-derived on the fly from a
/// cloned RNG cursor (no `O(V)` f64 array); self-loops are filtered.
fn stream_community_edges(
    spec: &CommunityGraphSpec,
    community: &[u32],
    comm_start: &[usize],
    mut emit: impl FnMut(u32, u32),
) {
    let n = spec.num_vertices;
    // two cursors over one logical stream: `deg_rng` replays the n
    // power-law degree draws; `rng` first consumes those same n draws
    // (summing them for the stub scale) and then continues as the edge
    // RNG — bit-identical to the historical "draw all degrees, scale,
    // then draw edges" order.
    let mut deg_rng = Rng::new(spec.seed);
    let mut rng = deg_rng.clone();
    let mut total = 0.0f64;
    for _ in 0..n {
        total += 1.0 + rng.powerlaw(n, spec.alpha) as f64;
    }
    let scale = (2 * spec.num_edges) as f64 / total;
    for v in 0..n {
        let dv = (1.0 + deg_rng.powerlaw(n, spec.alpha) as f64) * scale;
        let stubs = dv.floor() as usize + usize::from(rng.coin(dv.fract()));
        let c = community[v] as usize;
        let (cs, ce) = (comm_start[c], comm_start[c + 1]);
        for _ in 0..stubs.div_ceil(2) {
            // each undirected edge accounts for 2 stubs
            let u = if ce > cs + 1 && rng.coin(spec.p_intra) {
                rng.range(cs, ce) as u32
            } else {
                rng.below(n) as u32
            };
            if u != v as u32 {
                emit(v as u32, u);
            }
        }
    }
}

pub fn community_graph(spec: &CommunityGraphSpec) -> GeneratedGraph {
    let n = spec.num_vertices;
    let k = spec.num_communities.max(1);
    let (community, comm_start) = community_layout(n, k);
    let mut edges = Vec::with_capacity(spec.num_edges + spec.num_edges / 8);
    stream_community_edges(spec, &community, &comm_start, |a, b| {
        edges.push((a, b))
    });
    GeneratedGraph {
        graph: CsrGraph::from_edges(n, &edges),
        community,
    }
}

/// Chunk-streamed [`community_graph`]: identical output for every
/// `chunk_edges` (the buffer only batches counting/scatter work), with
/// peak memory bounded by the CSR arrays plus one chunk buffer.
pub fn community_graph_chunked(
    spec: &CommunityGraphSpec,
    chunk_edges: usize,
) -> GeneratedGraph {
    let n = spec.num_vertices;
    let k = spec.num_communities.max(1);
    let (community, comm_start) = community_layout(n, k);
    let graph = csr_from_stream(n, chunk_edges, |emit| {
        stream_community_edges(spec, &community, &comm_start, emit)
    });
    GeneratedGraph { graph, community }
}

/// Stream the R-MAT edge sequence (replayable, self-loops filtered).
fn stream_rmat_edges(
    n_log2: u32,
    num_edges: usize,
    seed: u64,
    mut emit: impl FnMut(u32, u32),
) {
    let (a, b, c) = (0.57, 0.19, 0.19); // Graph500 defaults
    let mut rng = Rng::new(seed);
    for _ in 0..num_edges {
        let (mut x, mut y) = (0usize, 0usize);
        for _ in 0..n_log2 {
            let r = rng.f64();
            let (dx, dy) = if r < a {
                (0, 0)
            } else if r < a + b {
                (0, 1)
            } else if r < a + b + c {
                (1, 0)
            } else {
                (1, 1)
            };
            x = (x << 1) | dx;
            y = (y << 1) | dy;
        }
        if x != y {
            emit(x as u32, y as u32);
        }
    }
}

/// R-MAT (Chakrabarti et al.) — skewed but community-free; the locality
/// stress case.
pub fn rmat_graph(n_log2: u32, num_edges: usize, seed: u64) -> CsrGraph {
    let mut edges = Vec::with_capacity(num_edges);
    stream_rmat_edges(n_log2, num_edges, seed, |a, b| edges.push((a, b)));
    CsrGraph::from_edges(1usize << n_log2, &edges)
}

/// Chunk-streamed [`rmat_graph`]: identical output for every chunk
/// size, memory bounded like [`community_graph_chunked`].
pub fn rmat_graph_chunked(
    n_log2: u32,
    num_edges: usize,
    seed: u64,
    chunk_edges: usize,
) -> CsrGraph {
    csr_from_stream(1usize << n_log2, chunk_edges, |emit| {
        stream_rmat_edges(n_log2, num_edges, seed, emit)
    })
}

/// Count one chunk's symmetrized degree contributions (pass 1).
fn count_chunk(chunk: &[(u32, u32)], deg: &mut [u64]) {
    for &(a, b) in chunk {
        deg[a as usize] += 1;
        deg[b as usize] += 1;
    }
}

/// Scatter one chunk's edges (both directions) at the write cursors
/// (pass 2).
fn scatter_chunk(
    chunk: &[(u32, u32)],
    cursor: &mut [u64],
    neighbors: &mut [u32],
) {
    for &(a, b) in chunk {
        neighbors[cursor[a as usize] as usize] = b;
        cursor[a as usize] += 1;
        neighbors[cursor[b as usize] as usize] = a;
        cursor[b as usize] += 1;
    }
}

/// Build a symmetrized, sorted, deduplicated CSR from a replayable edge
/// stream via two counting-sort passes over fixed-size chunks. The
/// `stream` closure must emit the identical self-loop-free sequence on
/// every call; equivalent to `CsrGraph::from_edges` on the materialized
/// list (same per-vertex neighbor *sets*, so the same sorted CSR) —
/// without ever holding that list.
fn csr_from_stream(
    n: usize,
    chunk_edges: usize,
    stream: impl Fn(&mut dyn FnMut(u32, u32)),
) -> CsrGraph {
    let chunk_cap = chunk_edges.max(1);
    // grow the buffer lazily toward the chunk size: a huge requested
    // chunk must not pre-allocate more than the stream will fill
    let buf_cap = chunk_cap.min(1 << 22);

    // pass 1: count symmetrized degrees, one chunk at a time
    let mut deg = vec![0u64; n];
    let mut chunk: Vec<(u32, u32)> = Vec::with_capacity(buf_cap);
    stream(&mut |a, b| {
        debug_assert!((a as usize) < n && (b as usize) < n);
        debug_assert_ne!(a, b, "streams must filter self-loops");
        chunk.push((a, b));
        if chunk.len() >= chunk_cap {
            count_chunk(&chunk, &mut deg);
            chunk.clear();
        }
    });
    count_chunk(&chunk, &mut deg);
    chunk.clear();

    // prefix-sum offsets; reuse the degree allocation as the scatter
    // cursors (one less O(V) array at peak)
    let mut offsets = vec![0u64; n + 1];
    for v in 0..n {
        offsets[v + 1] = offsets[v] + deg[v];
    }
    let mut cursor = deg;
    cursor.copy_from_slice(&offsets[..n]);

    // pass 2: replay the identical stream, scattering into the final
    // allocation
    let mut neighbors = vec![0u32; offsets[n] as usize];
    stream(&mut |a, b| {
        chunk.push((a, b));
        if chunk.len() >= chunk_cap {
            scatter_chunk(&chunk, &mut cursor, &mut neighbors);
            chunk.clear();
        }
    });
    scatter_chunk(&chunk, &mut cursor, &mut neighbors);
    drop(chunk);
    drop(cursor);

    // in-place per-vertex sort + dedup, compacting within the same
    // allocation (the write head never passes the read head)
    let mut out_offsets = vec![0u64; n + 1];
    let mut write = 0usize;
    for v in 0..n {
        let (s, e) = (offsets[v] as usize, offsets[v + 1] as usize);
        neighbors[s..e].sort_unstable();
        let mut prev = None;
        for i in s..e {
            let x = neighbors[i];
            if prev != Some(x) {
                neighbors[write] = x;
                write += 1;
                prev = Some(x);
            }
        }
        out_offsets[v + 1] = write as u64;
    }
    neighbors.truncate(write);
    neighbors.shrink_to_fit();
    CsrGraph::from_sorted_parts(out_offsets, neighbors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn community_graph_basic_shape() {
        let spec = CommunityGraphSpec {
            num_vertices: 2000,
            num_edges: 12_000,
            num_communities: 16,
            ..Default::default()
        };
        let g = community_graph(&spec);
        assert_eq!(g.graph.num_vertices(), 2000);
        // duplicates collapse, so within 40% of target is fine
        let m = g.graph.num_edges();
        assert!(m > 7_000 && m < 16_000, "edges {m}");
        assert_eq!(g.community.len(), 2000);
        assert_eq!(*g.community.iter().max().unwrap(), 15);
    }

    #[test]
    fn intra_community_fraction_dominates() {
        let spec = CommunityGraphSpec {
            num_vertices: 4000,
            num_edges: 30_000,
            num_communities: 20,
            p_intra: 0.9,
            ..Default::default()
        };
        let g = community_graph(&spec);
        let mut intra = 0usize;
        let mut total = 0usize;
        for (u, v) in g.graph.edges() {
            total += 1;
            if g.community[u as usize] == g.community[v as usize] {
                intra += 1;
            }
        }
        let frac = intra as f64 / total as f64;
        assert!(frac > 0.7, "intra fraction {frac}");
    }

    #[test]
    fn degree_sequence_is_skewed() {
        let spec = CommunityGraphSpec::default();
        let g = community_graph(&spec).graph;
        let mut degs: Vec<usize> =
            (0..g.num_vertices() as u32).map(|v| g.degree(v)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // top 1% of vertices should hold well above 1% of edges
        let top: usize = degs[..degs.len() / 100].iter().sum();
        let all: usize = degs.iter().sum();
        assert!(top as f64 / all as f64 > 0.05, "top share {}", top as f64 / all as f64);
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = CommunityGraphSpec::default();
        let a = community_graph(&spec).graph;
        let b = community_graph(&spec).graph;
        assert_eq!(a.num_edges(), b.num_edges());
        assert_eq!(a.neighbors(7), b.neighbors(7));
    }

    #[test]
    fn chunked_is_bit_identical_to_unchunked() {
        // the one-chunk special case *and* aggressive chunking must
        // reproduce the in-memory generator exactly — CSR arrays and
        // community labels both
        let spec = CommunityGraphSpec {
            num_vertices: 3000,
            num_edges: 18_000,
            num_communities: 24,
            seed: 5,
            ..Default::default()
        };
        let base = community_graph(&spec);
        for chunk in [1, 97, 4096, usize::MAX] {
            let g = community_graph_chunked(&spec, chunk);
            assert_eq!(g.graph, base.graph, "chunk={chunk}");
            assert_eq!(g.community, base.community, "chunk={chunk}");
        }
    }

    #[test]
    fn rmat_shape() {
        let g = rmat_graph(10, 8000, 3);
        assert_eq!(g.num_vertices(), 1024);
        assert!(g.num_edges() > 4000);
    }

    #[test]
    fn rmat_chunked_matches_unchunked() {
        let base = rmat_graph(10, 8000, 3);
        for chunk in [1, 513, 1 << 20] {
            assert_eq!(rmat_graph_chunked(10, 8000, 3, chunk), base);
        }
    }
}
