//! Graph substrate: CSR storage, generators, and the synthetic dataset
//! suite standing in for the paper's OGB / WebGraph corpora (Table 2).

pub mod datasets;
pub mod generator;

/// Compressed-sparse-row graph. Stored symmetrized (GNN aggregation treats
/// edges as undirected, matching DGL's default for these benchmarks);
/// neighbor lists are sorted and deduplicated.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CsrGraph {
    offsets: Vec<u64>,
    neighbors: Vec<u32>,
}

impl CsrGraph {
    /// Build from an (unordered, possibly duplicated) undirected edge list.
    /// Self-loops are dropped (models add their own), duplicates merged.
    pub fn from_edges(num_vertices: usize, edges: &[(u32, u32)]) -> Self {
        let n = num_vertices;
        let mut deg = vec![0u64; n];
        for &(a, b) in edges {
            assert!((a as usize) < n && (b as usize) < n, "edge out of range");
            if a != b {
                deg[a as usize] += 1;
                deg[b as usize] += 1;
            }
        }
        let mut offsets = vec![0u64; n + 1];
        for v in 0..n {
            offsets[v + 1] = offsets[v] + deg[v];
        }
        let mut neighbors = vec![0u32; offsets[n] as usize];
        let mut cursor: Vec<u64> = offsets[..n].to_vec();
        for &(a, b) in edges {
            if a != b {
                neighbors[cursor[a as usize] as usize] = b;
                cursor[a as usize] += 1;
                neighbors[cursor[b as usize] as usize] = a;
                cursor[b as usize] += 1;
            }
        }
        // sort + dedup each adjacency list, then re-compact
        let mut out_neighbors = Vec::with_capacity(neighbors.len());
        let mut out_offsets = vec![0u64; n + 1];
        for v in 0..n {
            let s = offsets[v] as usize;
            let e = offsets[v + 1] as usize;
            let list = &mut neighbors[s..e];
            list.sort_unstable();
            let mut prev = None;
            for &x in list.iter() {
                if prev != Some(x) {
                    out_neighbors.push(x);
                    prev = Some(x);
                }
            }
            out_offsets[v + 1] = out_neighbors.len() as u64;
        }
        Self {
            offsets: out_offsets,
            neighbors: out_neighbors,
        }
    }

    /// Build directly from finished CSR arrays: `offsets.len() == n+1`,
    /// each adjacency list already sorted, deduplicated, self-loop-free,
    /// and symmetric. The memory-bounded chunk-streamed generator path
    /// (`generator::community_graph_chunked`) constructs these in place
    /// without ever materializing an unsorted edge list; invariants are
    /// spot-checked in debug builds only.
    pub fn from_sorted_parts(offsets: Vec<u64>, neighbors: Vec<u32>) -> Self {
        assert!(!offsets.is_empty(), "offsets must have n+1 entries");
        assert_eq!(
            *offsets.last().unwrap() as usize,
            neighbors.len(),
            "offsets must cover the neighbor array"
        );
        debug_assert!(offsets.windows(2).all(|w| w[0] <= w[1]));
        #[cfg(debug_assertions)]
        for v in 0..offsets.len() - 1 {
            let list =
                &neighbors[offsets[v] as usize..offsets[v + 1] as usize];
            debug_assert!(
                list.windows(2).all(|w| w[0] < w[1]),
                "adjacency of {v} not sorted+deduped"
            );
            debug_assert!(
                !list.contains(&(v as u32)),
                "self-loop at {v}"
            );
        }
        Self { offsets, neighbors }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges (each stored twice).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.neighbors[s..e]
    }

    /// Topology volume in bytes (CSR arrays) — Table 2's Vol_G.
    pub fn topology_bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.neighbors.len() * 4) as u64
    }

    /// Iterate unique undirected edges (a < b).
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        (0..self.num_vertices() as u32).flat_map(move |v| {
            self.neighbors(v)
                .iter()
                .filter(move |&&u| v < u)
                .map(move |&u| (v, u))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CsrGraph {
        // 0-1, 0-2, 1-2, 2-3 with a duplicate and a self-loop thrown in
        CsrGraph::from_edges(5, &[(0, 1), (2, 0), (1, 2), (2, 3), (1, 0), (4, 4)])
    }

    #[test]
    fn degrees_and_neighbors() {
        let g = tiny();
        assert_eq!(g.num_vertices(), 5);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(2), &[0, 1, 3]);
        assert_eq!(g.degree(4), 0); // self-loop dropped
    }

    #[test]
    fn symmetric() {
        let g = tiny();
        for v in 0..g.num_vertices() as u32 {
            for &u in g.neighbors(v) {
                assert!(g.neighbors(u).contains(&v), "{u}->{v} missing");
            }
        }
    }

    #[test]
    fn sorted_dedup() {
        let g = tiny();
        for v in 0..g.num_vertices() as u32 {
            let ns = g.neighbors(v);
            for w in ns.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn edge_iterator_unique() {
        let g = tiny();
        let es: Vec<_> = g.edges().collect();
        assert_eq!(es, vec![(0, 1), (0, 2), (1, 2), (2, 3)]);
    }

    #[test]
    #[should_panic(expected = "edge out of range")]
    fn rejects_out_of_range() {
        CsrGraph::from_edges(2, &[(0, 5)]);
    }

    #[test]
    fn from_sorted_parts_roundtrips() {
        let g = tiny();
        let g2 = CsrGraph::from_sorted_parts(
            g.offsets.clone(),
            g.neighbors.clone(),
        );
        assert_eq!(g, g2);
    }

    #[test]
    #[should_panic(expected = "cover the neighbor array")]
    fn from_sorted_parts_rejects_mismatched_arrays() {
        CsrGraph::from_sorted_parts(vec![0, 2], vec![1]);
    }
}
