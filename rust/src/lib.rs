//! # HopGNN — feature-centric distributed GNN training
//!
//! Reproduction of *HopGNN: Boosting Distributed GNN Training Efficiency
//! via Feature-Centric Model Migration* (CS.DC 2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the distributed training coordinator: graph
//!   substrate, partitioners, samplers, the cluster/network simulator,
//!   the six training strategies (DGL, P³, Naive-FC, HopGNN, LO,
//!   NeutronStar), the PJRT runtime, and the experiment harness.
//! * **L2 (python/compile/model.py)** — GNN forward/backward in jax,
//!   AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for aggregation,
//!   feature transform, and GAT attention.
//!
//! Python never runs at training time: the rust binary loads the HLO
//! artifacts through PJRT (`runtime::engine`) and is self-contained.
//!
//! Quickstart: `cargo run --release --example quickstart` — or see
//! `README.md`.

// Deliberate seed-tree idiom, allowed crate-wide so the CI clippy gate
// (`-D warnings`, blocking since the cache-subsystem PR) stays
// deterministic: the zero-dependency substrate uses inherent
// `from_str(&str) -> Option<Self>` parsers on every enum (no `FromStr`
// because the error type would be the only use of an error enum).
#![allow(clippy::should_implement_trait)]

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod featstore;
pub mod graph;
pub mod metrics;
pub mod partition;
pub mod runtime;
pub mod sampler;
pub mod serve;
pub mod train;
pub mod util;
