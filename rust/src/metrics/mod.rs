//! Epoch-level metrics: the quantities every figure in §7 reports.

use crate::cluster::network::NUM_KINDS;
use crate::cluster::{NetStats, TransferKind};
use crate::featstore::tier::NUM_TIER_KINDS;
use crate::util::table::{fmt_bytes, fmt_secs, Table};

/// Everything one simulated (or real) epoch produces.
#[derive(Clone, Debug, Default)]
pub struct EpochMetrics {
    /// Wall time of the epoch (max over server clocks).
    pub epoch_time: f64,
    /// Per-phase time sums across servers (for the Fig 4 breakdown; each
    /// server contributes its own phase time, report as fraction of
    /// total server-time).
    pub time_sample: f64,
    pub time_gather: f64,
    pub time_compute: f64,
    pub time_migrate: f64,
    pub time_sync: f64,
    /// Async transfer seconds hidden behind compute by the driver's
    /// overlap mode (0 when `RunConfig::overlap` is off). `time_gather`
    /// still counts the full gather *work*; this records how much of it
    /// never reached the critical path.
    pub time_overlap_hidden: f64,
    /// Exact byte counts by kind (from NetStats).
    pub bytes_by_kind: [u64; NUM_KINDS],
    /// Remote fetch *operations* (batched requests, Fig 16 x-axis).
    pub remote_requests: u64,
    /// Remote vertices actually moved (feature misses, Fig 14/16).
    pub remote_vertices: u64,
    /// Locally served feature reads.
    pub local_hits: u64,
    /// Feature-cache accounting (all zero unless a
    /// [`crate::featstore::cache::CachePolicy`] is configured). A cache
    /// hit is a remote vertex served without a transfer: it counts
    /// neither as a `remote_vertices` move nor as a `local_hits` shard
    /// read. Byte conservation: `cache_hit_bytes + cache_miss_bytes`
    /// is exactly what the same schedule would have transferred with
    /// the cache off, and `cache_miss_bytes` is what it did transfer.
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Bytes that never hit the network thanks to cache hits.
    pub cache_hit_bytes: u64,
    /// Bytes transferred through the cache path (the misses).
    pub cache_miss_bytes: u64,
    /// Bytes displaced by eviction while admitting misses.
    pub cache_evict_bytes: u64,
    /// Per-tier-kind rows served, indexed by
    /// [`crate::featstore::tier::TierKind::index`] (hbm, dram, ssd,
    /// remote). The remote slot counts the backstop fetches; the cache
    /// slots sum to `cache_hits`.
    pub tier_hits: [u64; NUM_TIER_KINDS],
    /// Per-tier-kind bytes served (`tier_hits * feat_bytes`).
    pub tier_hit_bytes: [u64; NUM_TIER_KINDS],
    /// Bytes whose lookup probed a tier of this kind and missed there
    /// (a row descending the stack misses once per tier it passes).
    pub tier_miss_bytes: [u64; NUM_TIER_KINDS],
    /// Bytes promoted *into* a tier of this kind on a lower-tier hit.
    pub tier_promote_bytes: [u64; NUM_TIER_KINDS],
    /// Bytes demoted *into* a tier of this kind by displacement.
    pub tier_demote_bytes: [u64; NUM_TIER_KINDS],
    /// GPU busy fraction proxy (Fig 20).
    pub gpu_busy_fraction: f64,
    /// Per-server busy (compute) seconds — the observed lane times.
    /// Under a heterogeneous fabric the slow servers show
    /// proportionally more seconds for the same work, which is what
    /// HopGNN's fabric-aware merge mode feeds back into its schedule.
    /// Empty in lane-local deltas; filled by the driver at session end.
    pub per_server_busy: Vec<f64>,
    /// Time steps per iteration, averaged (Fig 17).
    pub time_steps_per_iter: f64,
    /// Iterations in this epoch.
    pub iterations: u64,
    /// Train roots the epoch schedule discarded (DGL-style `drop_last`
    /// ragged tail + uneven mini-batch splits) — reported instead of
    /// silently losing them.
    pub dropped_roots: u64,
}

impl EpochMetrics {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_by_kind.iter().sum()
    }

    pub fn bytes(&self, kind: TransferKind) -> u64 {
        self.bytes_by_kind[kind.index()]
    }

    /// Feature-gathering miss rate: remote / (remote + local).
    pub fn miss_rate(&self) -> f64 {
        let total = self.remote_vertices + self.local_hits;
        if total == 0 {
            0.0
        } else {
            self.remote_vertices as f64 / total as f64
        }
    }

    /// Feature-cache hit rate: hits / (hits + misses) over the remote
    /// vertices that went through the cache path (0 with the cache off).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }

    /// Fraction of per-server time spent gathering (Fig 4's headline).
    pub fn gather_fraction(&self) -> f64 {
        let total = self.time_sample
            + self.time_gather
            + self.time_compute
            + self.time_migrate
            + self.time_sync;
        if total == 0.0 {
            0.0
        } else {
            self.time_gather / total
        }
    }

    pub fn absorb_net(&mut self, net: &NetStats) {
        self.bytes_by_kind = net.bytes_by_kind;
    }

    /// Zero every field, keeping `per_server_busy`'s capacity. Used by
    /// the epoch driver's reusable lane scratch; a reset metrics value
    /// is indistinguishable from `EpochMetrics::default()`.
    pub fn reset(&mut self) {
        let per_server_busy = {
            let mut v = std::mem::take(&mut self.per_server_busy);
            v.clear();
            v
        };
        *self = EpochMetrics {
            per_server_busy,
            ..EpochMetrics::default()
        };
    }

    /// Fold another metrics delta into this one (every additive field).
    /// Used by the epoch driver to reduce per-server lane deltas in
    /// deterministic server order; derived fields (`epoch_time`,
    /// `gpu_busy_fraction`) are zero in lane deltas and recomputed by
    /// the driver at epoch end.
    pub fn accumulate(&mut self, other: &EpochMetrics) {
        self.epoch_time += other.epoch_time;
        self.time_sample += other.time_sample;
        self.time_gather += other.time_gather;
        self.time_compute += other.time_compute;
        self.time_migrate += other.time_migrate;
        self.time_sync += other.time_sync;
        self.time_overlap_hidden += other.time_overlap_hidden;
        for k in 0..NUM_KINDS {
            self.bytes_by_kind[k] += other.bytes_by_kind[k];
        }
        self.remote_requests += other.remote_requests;
        self.remote_vertices += other.remote_vertices;
        self.local_hits += other.local_hits;
        self.cache_hits += other.cache_hits;
        self.cache_misses += other.cache_misses;
        self.cache_hit_bytes += other.cache_hit_bytes;
        self.cache_miss_bytes += other.cache_miss_bytes;
        self.cache_evict_bytes += other.cache_evict_bytes;
        for k in 0..NUM_TIER_KINDS {
            self.tier_hits[k] += other.tier_hits[k];
            self.tier_hit_bytes[k] += other.tier_hit_bytes[k];
            self.tier_miss_bytes[k] += other.tier_miss_bytes[k];
            self.tier_promote_bytes[k] += other.tier_promote_bytes[k];
            self.tier_demote_bytes[k] += other.tier_demote_bytes[k];
        }
        self.gpu_busy_fraction += other.gpu_busy_fraction;
        if !other.per_server_busy.is_empty() {
            if self.per_server_busy.is_empty() {
                self.per_server_busy = vec![0.0; other.per_server_busy.len()];
            }
            for (a, b) in
                self.per_server_busy.iter_mut().zip(&other.per_server_busy)
            {
                *a += b;
            }
        }
        self.time_steps_per_iter += other.time_steps_per_iter;
        self.iterations += other.iterations;
        self.dropped_roots += other.dropped_roots;
    }

    /// Merge a later epoch into a running average (used by multi-epoch
    /// runs that report the mean epoch, as the paper does: "train each
    /// model for ten epochs and report the average").
    pub fn average_of(epochs: &[EpochMetrics]) -> EpochMetrics {
        let n = epochs.len().max(1) as f64;
        let nu = epochs.len().max(1) as u64;
        let mut out = EpochMetrics::default();
        // sum first, divide once (per-element integer division would
        // truncate small counters to zero)
        for e in epochs {
            out.accumulate(e);
        }
        out.epoch_time /= n;
        out.time_sample /= n;
        out.time_gather /= n;
        out.time_compute /= n;
        out.time_migrate /= n;
        out.time_sync /= n;
        out.time_overlap_hidden /= n;
        for k in 0..NUM_KINDS {
            out.bytes_by_kind[k] /= nu;
        }
        out.remote_requests /= nu;
        out.remote_vertices /= nu;
        out.local_hits /= nu;
        out.cache_hits /= nu;
        out.cache_misses /= nu;
        out.cache_hit_bytes /= nu;
        out.cache_miss_bytes /= nu;
        out.cache_evict_bytes /= nu;
        for k in 0..NUM_TIER_KINDS {
            out.tier_hits[k] /= nu;
            out.tier_hit_bytes[k] /= nu;
            out.tier_miss_bytes[k] /= nu;
            out.tier_promote_bytes[k] /= nu;
            out.tier_demote_bytes[k] /= nu;
        }
        out.gpu_busy_fraction /= n;
        for b in out.per_server_busy.iter_mut() {
            *b /= n;
        }
        out.time_steps_per_iter /= n;
        out.iterations /= nu;
        out.dropped_roots /= nu;
        out
    }

    /// Pretty one-line summary. Dropped roots are appended when any
    /// were discarded — the counter exists to be *seen*, not just
    /// accumulated.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "epoch {} | gather {} ({:.0}%) compute {} | {} moved (feat {}) | miss {:.1}% | busy {:.0}%",
            fmt_secs(self.epoch_time),
            fmt_secs(self.time_gather),
            self.gather_fraction() * 100.0,
            fmt_secs(self.time_compute),
            fmt_bytes(self.total_bytes()),
            fmt_bytes(self.bytes(TransferKind::Feature)),
            self.miss_rate() * 100.0,
            self.gpu_busy_fraction * 100.0,
        );
        if self.dropped_roots > 0 {
            s.push_str(&format!(" | dropped {} roots", self.dropped_roots));
        }
        s
    }

    /// Render the Fig-4-style phase breakdown.
    pub fn breakdown_table(&self) -> Table {
        let total = (self.time_sample
            + self.time_gather
            + self.time_compute
            + self.time_migrate
            + self.time_sync)
            .max(1e-12);
        let mut t = Table::new(["phase", "time", "fraction"]);
        for (name, v) in [
            ("sample", self.time_sample),
            ("gather", self.time_gather),
            ("compute", self.time_compute),
            ("migrate", self.time_migrate),
            ("sync", self.time_sync),
        ] {
            t.row([
                name.to_string(),
                fmt_secs(v),
                format!("{:.1}%", v / total * 100.0),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_rate_and_fractions() {
        let m = EpochMetrics {
            remote_vertices: 75,
            local_hits: 25,
            time_gather: 3.0,
            time_compute: 1.0,
            ..Default::default()
        };
        assert!((m.miss_rate() - 0.75).abs() < 1e-12);
        assert!((m.gather_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn empty_metrics_are_safe() {
        let m = EpochMetrics::default();
        assert_eq!(m.miss_rate(), 0.0);
        assert_eq!(m.gather_fraction(), 0.0);
        assert_eq!(m.cache_hit_rate(), 0.0);
        assert_eq!(m.total_bytes(), 0);
    }

    #[test]
    fn cache_counters_accumulate_and_average() {
        let a = EpochMetrics {
            cache_hits: 30,
            cache_misses: 10,
            cache_hit_bytes: 3000,
            cache_miss_bytes: 1000,
            cache_evict_bytes: 200,
            ..Default::default()
        };
        assert!((a.cache_hit_rate() - 0.75).abs() < 1e-12);
        let avg = EpochMetrics::average_of(&[a.clone(), a]);
        assert_eq!(avg.cache_hits, 30);
        assert_eq!(avg.cache_hit_bytes, 3000);
        assert_eq!(avg.cache_evict_bytes, 200);
    }

    #[test]
    fn tier_arrays_accumulate_and_average() {
        let a = EpochMetrics {
            tier_hits: [4, 2, 0, 6],
            tier_hit_bytes: [400, 200, 0, 600],
            tier_miss_bytes: [100, 300, 0, 0],
            tier_promote_bytes: [200, 0, 0, 0],
            tier_demote_bytes: [0, 200, 0, 0],
            ..Default::default()
        };
        let mut sum = EpochMetrics::default();
        sum.accumulate(&a);
        sum.accumulate(&a);
        assert_eq!(sum.tier_hits, [8, 4, 0, 12]);
        assert_eq!(sum.tier_promote_bytes, [400, 0, 0, 0]);
        let avg = EpochMetrics::average_of(&[a.clone(), a]);
        assert_eq!(avg.tier_hits, [4, 2, 0, 6]);
        assert_eq!(avg.tier_hit_bytes, [400, 200, 0, 600]);
        assert_eq!(avg.tier_miss_bytes, [100, 300, 0, 0]);
        assert_eq!(avg.tier_demote_bytes, [0, 200, 0, 0]);
    }

    #[test]
    fn per_server_busy_and_dropped_roots_average() {
        let a = EpochMetrics {
            per_server_busy: vec![2.0, 4.0],
            dropped_roots: 6,
            ..Default::default()
        };
        let b = EpochMetrics {
            per_server_busy: vec![4.0, 8.0],
            dropped_roots: 2,
            ..Default::default()
        };
        let avg = EpochMetrics::average_of(&[a, b]);
        assert_eq!(avg.per_server_busy, vec![3.0, 6.0]);
        assert_eq!(avg.dropped_roots, 4);
    }

    #[test]
    fn averaging() {
        let a = EpochMetrics {
            epoch_time: 2.0,
            remote_vertices: 100,
            local_hits: 100,
            ..Default::default()
        };
        let b = EpochMetrics {
            epoch_time: 4.0,
            remote_vertices: 200,
            local_hits: 200,
            ..Default::default()
        };
        let avg = EpochMetrics::average_of(&[a, b]);
        assert!((avg.epoch_time - 3.0).abs() < 1e-12);
        assert_eq!(avg.remote_vertices, 150);
    }

    #[test]
    fn summary_surfaces_dropped_roots() {
        let clean = EpochMetrics::default();
        assert!(!clean.summary().contains("dropped"), "{}", clean.summary());
        let m = EpochMetrics {
            dropped_roots: 3,
            ..Default::default()
        };
        assert!(
            m.summary().contains("dropped 3 roots"),
            "{}",
            m.summary()
        );
    }

    #[test]
    fn breakdown_table_renders() {
        let m = EpochMetrics {
            time_gather: 0.8,
            time_compute: 0.2,
            ..Default::default()
        };
        let s = m.breakdown_table().render();
        assert!(s.contains("80.0%"), "{s}");
    }
}
