//! Shared spec-string grammar: the one place that splits, parses, and
//! complains about the CLI's little languages.
//!
//! Three front-end grammars ride on this module so they parse and error
//! uniformly (same shapes, same message style, same fail-fast sweep
//! validation):
//!
//! * `--fabric` — `uniform` / `rack:<k>` / `hetero-mix` /
//!   `straggler:<s>` ([`crate::cluster::FabricSpec`]);
//! * `synth:` dataset names — `synth:v=1e6,e=1e7,seed=3`
//!   ([`crate::graph::datasets::SynthSpec`]);
//! * `--tiers` — `hbm:2g+dram:16g:lru+remote`
//!   ([`crate::featstore::tier::TierSpec`]).
//!
//! The helpers take a `subject` (or `ctx`) string naming the thing being
//! parsed — e.g. `synth key 'v'` or `tiers segment 'dram:64m'` — so
//! every error self-identifies without the caller re-wrapping it.

/// Split one `key=value` pair, erroring in the shared style:
/// `"{ctx}: expected key=value, got '{pair}'"`.
pub fn split_kv<'a>(ctx: &str, pair: &'a str) -> Result<(&'a str, &'a str), String> {
    pair.split_once('=')
        .ok_or_else(|| format!("{ctx}: expected key=value, got '{pair}'"))
}

/// The shared unknown-key error, listing every valid key:
/// `"{ctx}: unknown key '{key}' (valid: a,b,c)"`.
pub fn unknown_key(ctx: &str, key: &str, valid: &[&str]) -> String {
    format!("{ctx}: unknown key '{key}' (valid: {})", valid.join(","))
}

/// The shared unknown-spec error for whole-string grammars, listing the
/// valid forms pipe-separated: `"unknown {kind} '{got}' (a|b|c)"`.
pub fn unknown_spec(kind: &str, got: &str, forms: &[&str]) -> String {
    format!("unknown {kind} '{got}' ({})", forms.join("|"))
}

/// Parse `1e9` / `250_000` / `4096` into a count. Accepts scientific
/// notation and `_` group separators; rejects non-integers, negatives,
/// and anything above 9e15 (where f64 still represents every integer).
pub fn parse_count(subject: &str, s: &str) -> Result<usize, String> {
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    let x: f64 = cleaned
        .parse()
        .map_err(|_| format!("{subject}: cannot parse number '{s}'"))?;
    if !x.is_finite() || x < 0.0 || x > 9.0e15 {
        return Err(format!("{subject}: value '{s}' out of range"));
    }
    let r = x.round();
    if (x - r).abs() > 1e-6 * x.abs().max(1.0) {
        return Err(format!("{subject}: expected an integer, got '{s}'"));
    }
    Ok(r as usize)
}

/// Parse a finite float (fractions, exponents — anything f64).
pub fn parse_frac(subject: &str, s: &str) -> Result<f64, String> {
    s.parse::<f64>()
        .ok()
        .filter(|x| x.is_finite())
        .ok_or_else(|| format!("{subject}: cannot parse number '{s}'"))
}

/// Parse a byte capacity: a count with an optional binary-unit suffix —
/// `512k` (KiB), `64m` (MiB), `2g` (GiB), or a bare byte count.
pub fn parse_bytes(subject: &str, s: &str) -> Result<u64, String> {
    let (body, shift) = match s.chars().last() {
        Some('k') | Some('K') => (&s[..s.len() - 1], 10u32),
        Some('m') | Some('M') => (&s[..s.len() - 1], 20),
        Some('g') | Some('G') => (&s[..s.len() - 1], 30),
        _ => (s, 0),
    };
    if body.is_empty() {
        return Err(format!(
            "{subject}: cannot parse capacity '{s}' (use e.g. 512k, 64m, 2g, \
             or a byte count)"
        ));
    }
    let n = parse_count(subject, body)? as u64;
    n.checked_shl(shift)
        .filter(|&b| b >> shift == n)
        .ok_or_else(|| format!("{subject}: capacity '{s}' overflows"))
}

/// Render a byte capacity in the same grammar [`parse_bytes`] reads, at
/// the largest exact unit — so every spec round-trips canonically.
pub fn fmt_bytes_spec(bytes: u64) -> String {
    const G: u64 = 1 << 30;
    const M: u64 = 1 << 20;
    const K: u64 = 1 << 10;
    if bytes > 0 && bytes % G == 0 {
        format!("{}g", bytes / G)
    } else if bytes > 0 && bytes % M == 0 {
        format!("{}m", bytes / M)
    } else if bytes > 0 && bytes % K == 0 {
        format!("{}k", bytes / K)
    } else {
        format!("{bytes}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_split_errors_in_the_shared_style() {
        assert_eq!(split_kv("spec 'x'", "a=b"), Ok(("a", "b")));
        let e = split_kv("spec 'x'", "ab").unwrap_err();
        assert_eq!(e, "spec 'x': expected key=value, got 'ab'");
    }

    #[test]
    fn unknown_key_lists_the_valid_keys() {
        let e = unknown_key("synth spec 's'", "fanout", &["v", "e", "k"]);
        assert_eq!(e, "synth spec 's': unknown key 'fanout' (valid: v,e,k)");
    }

    #[test]
    fn counts_accept_scientific_and_underscores() {
        assert_eq!(parse_count("t", "1e6"), Ok(1_000_000));
        assert_eq!(parse_count("t", "250_000"), Ok(250_000));
        assert!(parse_count("t", "1.5").unwrap_err().contains("integer"));
        assert!(parse_count("t", "-4").unwrap_err().contains("out of range"));
        assert!(parse_count("t", "x").unwrap_err().contains("cannot parse"));
    }

    #[test]
    fn byte_capacities_parse_and_roundtrip() {
        assert_eq!(parse_bytes("t", "512k"), Ok(512 << 10));
        assert_eq!(parse_bytes("t", "64m"), Ok(64 << 20));
        assert_eq!(parse_bytes("t", "2g"), Ok(2 << 30));
        assert_eq!(parse_bytes("t", "4096"), Ok(4096));
        assert_eq!(parse_bytes("t", "0"), Ok(0));
        assert!(parse_bytes("t", "g").is_err());
        assert!(parse_bytes("t", "1.5m").is_err());
        for b in [0u64, 4096, 512 << 10, 64 << 20, 2 << 30, 12345] {
            let s = fmt_bytes_spec(b);
            assert_eq!(parse_bytes("t", &s), Ok(b), "{b} -> {s}");
        }
    }
}
