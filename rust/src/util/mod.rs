//! Hand-rolled substrate libraries (the offline vendor set has no serde /
//! clap / rand / proptest / criterion — see DESIGN.md "Vendored-crate
//! constraint").

pub mod alloc;
pub mod cli;
pub mod error;
pub mod fxhash;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod specs;
pub mod stamp;
pub mod stats;
pub mod table;
