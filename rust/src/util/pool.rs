//! The process's one parallelism substrate (std-only; the offline
//! vendor set has no rayon): a scoped pool for job grids, a persistent
//! pool for epoch lane execution, and the global `--jobs` thread budget
//! both draw from.
//!
//! ## The `--jobs` thread budget
//!
//! Every thread this crate spawns comes from one budget
//! ([`set_thread_budget`], wired to the CLI `--jobs` flags; `0` =
//! unset). The sweep engine splits it deterministically: with `B`
//! budget threads and `C` grid cells, `min(B, C)` cell runners execute
//! cells concurrently and each runner's epoch drivers may use
//! `B / min(B, C)` threads for lane execution (the
//! [`LaneAllowanceGuard`]). The split depends only on `(B, C)` — never
//! on which worker picks up which cell — so `bench sweep --jobs N`
//! with `parallel_lanes` on runs at most `B` live threads total
//! (`tests/pool_budget.rs` asserts it through [`peak_workers`])
//! instead of the pre-budget `cells × lanes` oversubscription.
//! Standalone drivers outside a sweep (`sim`, unit tests) see an
//! uncapped allowance when no budget is set, matching the historical
//! spawn-per-lane degree.
//!
//! ## [`run_indexed`] — scoped grid pool
//!
//! Executes jobs `0..n` on a fixed number of workers pulling indices
//! off a shared atomic counter and returns the results **in job-index
//! order** regardless of which worker finished first — the property
//! the sweep engine's `--jobs` parity guarantee
//! (`tests/sweep_parallel.rs`) is built on: parallelism may only
//! change wall-clock, never what any cell computes or where its result
//! lands. The calling thread is worker #0, so `workers` is the *total*
//! thread count, not an increment on top of the caller.
//!
//! ## [`LanePool`] — persistent lane executor
//!
//! `run_indexed`'s scoped spawns are fine for seconds-scale sweep
//! cells but far too heavy for the epoch driver's microseconds-scale
//! `Item::Lanes` fragments (one per iteration step). [`LanePool`]
//! keeps its workers alive across dispatches — parked between
//! fragments, woken by an unpark + generation bump, claiming lane
//! indices off a generation-tagged atomic word (no channels). The
//! dispatching thread participates in the claim loop, blocks until
//! every lane of the fragment completed, and only then returns — which
//! is what makes handing the workers a borrowed closure sound. A
//! panicking lane task is caught, recorded, and re-raised on the
//! dispatcher *after* the fragment drains, so parked workers are never
//! deadlocked by a dying session. Strategies hold the pool across
//! epochs next to their scratch/builder state, so a whole training run
//! pays the thread-spawn cost once.

use std::marker::PhantomData;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle, Thread};
use std::time::Duration;

/// Resolve a `--jobs` request: `0` means "auto" — one worker per
/// available hardware thread (falling back to 1 if the platform cannot
/// say).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

/// The process-wide `--jobs` thread budget (`0` = unset). Sweeps
/// without an explicit per-spec `jobs` fall back to it, and it caps
/// the lane allowance of standalone epoch drivers.
static THREAD_BUDGET: AtomicUsize = AtomicUsize::new(0);

/// Install the global `--jobs` budget (the CLI entry points call this
/// once, before any sweep or driver runs). `0` = unset: sweeps resolve
/// to auto, standalone lane pools are uncapped (legacy spawn-per-lane
/// degree).
pub fn set_thread_budget(jobs: usize) {
    THREAD_BUDGET.store(jobs, Ordering::Relaxed);
}

/// The installed `--jobs` budget (`0` = unset).
pub fn thread_budget() -> usize {
    THREAD_BUDGET.load(Ordering::Relaxed)
}

thread_local! {
    /// Per-driver lane-thread allowance installed by the sweep
    /// engine's budget split (`0` = no guard active). Thread-local —
    /// the guard is installed inside the cell-runner closure, on
    /// whichever thread executes the cell, so concurrent sweeps (the
    /// test harness) can never race each other's split.
    static LANE_ALLOWANCE: std::cell::Cell<usize> =
        const { std::cell::Cell::new(0) };
}

/// How many threads one epoch driver may use for parallel lane
/// execution (including the dispatching thread). Inside a sweep cell
/// this is the [`LaneAllowanceGuard`] share of the budget; outside one
/// it is the whole budget, or uncapped (`usize::MAX`) when no budget
/// was set — the historical one-thread-per-lane degree.
pub fn lane_allowance() -> usize {
    match LANE_ALLOWANCE.with(|c| c.get()) {
        0 => match thread_budget() {
            0 => usize::MAX,
            b => b,
        },
        k => k,
    }
}

/// RAII installer for the sweep engine's per-cell lane allowance on
/// the current thread; restores the previous value on drop. Drivers
/// read the allowance when they first need a lane pool, so the guard
/// must live for the duration of the cell run that installed it.
pub struct LaneAllowanceGuard {
    prev: usize,
}

impl LaneAllowanceGuard {
    pub fn set(allowance: usize) -> Self {
        Self {
            prev: LANE_ALLOWANCE
                .with(|c| c.replace(allowance.max(1))),
        }
    }
}

impl Drop for LaneAllowanceGuard {
    fn drop(&mut self) {
        LANE_ALLOWANCE.with(|c| c.set(self.prev));
    }
}

/// Live count of pool-spawned threads (sweep grid workers + lane pool
/// workers; the participating caller threads are not spawned and not
/// counted).
static LIVE_WORKERS: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of [`LIVE_WORKERS`] since the last reset.
static PEAK_WORKERS: AtomicUsize = AtomicUsize::new(0);

fn register_worker() {
    let live = LIVE_WORKERS.fetch_add(1, Ordering::SeqCst) + 1;
    PEAK_WORKERS.fetch_max(live, Ordering::SeqCst);
}

/// Decrements the live-worker count when a worker thread exits (runs
/// in the worker via drop, so a panicking worker still unregisters).
struct WorkerGuard;

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        LIVE_WORKERS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Currently live pool-spawned threads.
pub fn live_workers() -> usize {
    LIVE_WORKERS.load(Ordering::SeqCst)
}

/// High-water mark of live pool-spawned threads since
/// [`reset_peak_workers`]. Under a budget of `B` this never exceeds
/// `B - 1` (the caller is the remaining thread).
pub fn peak_workers() -> usize {
    PEAK_WORKERS.load(Ordering::SeqCst)
}

/// Reset the peak to the current live count (test hook).
pub fn reset_peak_workers() {
    PEAK_WORKERS.store(live_workers(), Ordering::SeqCst);
}

/// Shared-reference access to disjoint `&mut` elements of a slice,
/// for claim-loop workers that each own a distinct index.
///
/// The claim protocols in this module hand every index to exactly one
/// worker, which makes the aliasing contract trivially satisfiable —
/// but the compiler cannot see that through a shared closure, hence
/// the unsafe accessor.
pub struct IndexedCells<'a, T> {
    ptr: *mut T,
    len: usize,
    _slice: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for IndexedCells<'_, T> {}
unsafe impl<T: Send> Sync for IndexedCells<'_, T> {}

impl<'a, T> IndexedCells<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _slice: PhantomData,
        }
    }

    /// # Safety
    ///
    /// At most one thread may hold the reference for index `i` at any
    /// time (guaranteed when `i` was claimed off an atomic counter).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn get(&self, i: usize) -> &mut T {
        assert!(i < self.len, "index {i} out of bounds ({})", self.len);
        unsafe { &mut *self.ptr.add(i) }
    }
}

/// Run `n` independent jobs on up to `workers` threads **total**
/// (`workers - 1` spawned, the caller is worker #0) and return the
/// results in job-index order.
///
/// `f(i)` must be pure with respect to shared state (interior
/// synchronization like the `bench::memo` per-key entry locks is fine);
/// it may be called from any worker thread. With `workers <= 1` (or a
/// single job) everything runs inline on the caller's thread — the
/// `--jobs 1` path is exactly the pre-pool sequential loop.
pub fn run_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let cells = IndexedCells::new(&mut slots);
        let claim = |_w: usize| loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            let out = f(i);
            // safety: `i` came off the shared counter, so this worker
            // is the only one touching slot `i`
            unsafe { *cells.get(i) = Some(out) };
        };
        std::thread::scope(|scope| {
            for w in 1..workers {
                let claim = &claim;
                // registered from the spawning side so the peak
                // accounting can never lag the spawn
                register_worker();
                scope.spawn(move || {
                    let _guard = WorkerGuard;
                    claim(w)
                });
            }
            claim(0);
        });
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("job {i} never claimed")))
        .collect()
}

/// Lane indices fit in the low bits of the claim word; the rest tags
/// the dispatch generation so a worker waking from a long sleep can
/// never claim into (or run the dangling closure of) a generation it
/// did not observe. 16 bits bound the lane count at 65535 servers —
/// far above any simulated cluster — and leave 48 generation bits
/// (years of microsecond-scale dispatches before wrap).
const IDX_BITS: u32 = 16;
const IDX_MASK: u64 = (1 << IDX_BITS) - 1;

fn claim_tag(generation: u64) -> u64 {
    generation << IDX_BITS
}

/// The published fragment: a type-erased borrowed task closure plus
/// its lane count. Only dereferenced by claim loops that validated the
/// generation, which is what makes holding a raw pointer across
/// threads sound.
#[derive(Clone, Copy)]
struct LaneJob {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
}

unsafe impl Send for LaneJob {}

/// The mutex-guarded dispatch slot: generation, current job, and the
/// dispatcher thread to unpark when the last lane finishes. The mutex
/// is taken once per worker per dispatch (snapshot) and once per
/// dispatch for the final wake — never inside the per-lane loop.
struct JobSlot {
    generation: u64,
    job: Option<LaneJob>,
    caller: Option<Thread>,
}

struct PoolShared {
    /// Latest published generation; workers park while it matches the
    /// one they last served.
    epoch: AtomicU64,
    /// Generation-tagged lane claim word (see [`IDX_BITS`]).
    claim: AtomicU64,
    /// Lanes completed in the current generation.
    done: AtomicUsize,
    shutdown: AtomicBool,
    slot: Mutex<JobSlot>,
    /// First panic payload of the current generation, re-raised on the
    /// dispatcher after the fragment drains.
    panicked: Mutex<Option<String>>,
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Claim and execute lanes of generation `generation` until the claim
/// word runs out of indices or moves to another generation. Panics are
/// caught and recorded so `done` always reaches `n` and parked peers
/// are never deadlocked.
fn claim_loop(
    sh: &PoolShared,
    generation: u64,
    f: &(dyn Fn(usize) + Sync),
    n: usize,
) {
    let tag = claim_tag(generation);
    loop {
        let cur = sh.claim.load(Ordering::Acquire);
        if cur & !IDX_MASK != tag {
            return; // the claim word belongs to another generation
        }
        let idx = (cur & IDX_MASK) as usize;
        if idx >= n {
            return;
        }
        if sh
            .claim
            .compare_exchange_weak(
                cur,
                cur + 1,
                Ordering::AcqRel,
                Ordering::Relaxed,
            )
            .is_err()
        {
            continue;
        }
        if let Err(payload) = catch_unwind(AssertUnwindSafe(|| f(idx))) {
            let msg = panic_message(payload);
            sh.panicked.lock().unwrap().get_or_insert(msg);
        }
        // Release pairs with the dispatcher's Acquire on `done`: lane
        // results written above are visible once it observes the count
        let finished = sh.done.fetch_add(1, Ordering::Release) + 1;
        if finished == n {
            if let Some(t) = sh.slot.lock().unwrap().caller.as_ref() {
                t.unpark();
            }
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    let _guard = WorkerGuard;
    let sh = &*shared;
    let mut seen = 0u64;
    loop {
        if sh.shutdown.load(Ordering::Acquire) {
            return;
        }
        let e = sh.epoch.load(Ordering::Acquire);
        if e == seen {
            thread::park();
            continue;
        }
        seen = e;
        // snapshot under the slot mutex: the lock acquisition is also
        // what makes every dispatcher-side write (the program, the
        // scratch slices) visible to this worker
        let (generation, job) = {
            let slot = sh.slot.lock().unwrap();
            (slot.generation, slot.job)
        };
        let Some(job) = job else { continue };
        // the slot may already hold a generation newer than `e`; the
        // claim loop runs under the snapshot's own generation either way
        let f = unsafe { &*job.f };
        claim_loop(sh, generation, f, job.n);
    }
}

/// A persistent pool of parked lane workers (see the module docs for
/// the dispatch protocol). Created once per driver session — or held
/// across epochs by a strategy — instead of spawning threads per
/// `Item::Lanes` fragment.
pub struct LanePool {
    shared: Arc<PoolShared>,
    handles: Vec<JoinHandle<()>>,
}

impl LanePool {
    /// Spawn `workers` persistent lane workers. Total parallelism of a
    /// dispatch is `workers + 1`: the dispatching thread claims lanes
    /// too.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            epoch: AtomicU64::new(0),
            claim: AtomicU64::new(0),
            done: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            slot: Mutex::new(JobSlot {
                generation: 0,
                job: None,
                caller: None,
            }),
            panicked: Mutex::new(None),
        });
        let handles = (0..workers)
            .map(|i| {
                let sh = Arc::clone(&shared);
                register_worker();
                thread::Builder::new()
                    .name(format!("lane-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn lane worker")
            })
            .collect();
        Self { shared, handles }
    }

    /// Spawned (non-dispatcher) worker count.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Dispatch one fragment: run `f(0..n)` across the workers plus
    /// the calling thread, blocking until every lane completed.
    ///
    /// If any lane panicked, the first panic is re-raised here — after
    /// the fragment drained, so no worker is left parked mid-claim.
    /// `&mut self` makes dispatch exclusive at compile time (the
    /// protocol has one in-flight generation).
    pub fn run(&mut self, n: usize, f: &(dyn Fn(usize) + Sync)) {
        if n == 0 {
            return;
        }
        assert!(
            n <= IDX_MASK as usize,
            "lane count {n} exceeds the claim-word index capacity"
        );
        let sh = &*self.shared;
        // Erase the borrow's lifetime to publish it to the workers.
        // Sound because this call does not return until `done == n`
        // and late wakers validate the generation tag before every
        // claim, so `f` is never dereferenced after this frame ends.
        let erased: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f) };
        let generation = sh.epoch.load(Ordering::Relaxed) + 1;
        {
            let mut slot = sh.slot.lock().unwrap();
            slot.generation = generation;
            slot.job = Some(LaneJob { f: erased, n });
            slot.caller = Some(thread::current());
        }
        sh.done.store(0, Ordering::Relaxed);
        sh.claim.store(claim_tag(generation), Ordering::Release);
        sh.epoch.store(generation, Ordering::Release);
        for h in &self.handles {
            h.thread().unpark();
        }
        // the dispatcher is claimant #0
        claim_loop(sh, generation, f, n);
        // wait out straggler lanes: spin briefly (fragments are
        // microseconds-scale), then park; the timeout is a lost-wakeup
        // backstop, correctness only needs the done count
        let mut spins = 0u32;
        while sh.done.load(Ordering::Acquire) < n {
            if spins < 1 << 14 {
                spins += 1;
                std::hint::spin_loop();
            } else {
                thread::park_timeout(Duration::from_millis(1));
            }
        }
        // retire the job so no later waker can even snapshot it
        sh.slot.lock().unwrap().job = None;
        if let Some(msg) = sh.panicked.lock().unwrap().take() {
            panic!(
                "lane worker panicked: {msg}; epoch session aborted \
                 (all lanes drained, no worker left parked)"
            );
        }
    }
}

impl Drop for LanePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        for h in &self.handles {
            h.thread().unpark();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_are_in_index_order() {
        for workers in [1, 2, 4, 9] {
            let out = run_indexed(23, workers, |i| i * i);
            let expect: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(out, expect, "workers={workers}");
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = run_indexed(100, 4, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn degenerate_shapes() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(1, 8, |i| i + 1), vec![1]);
        // more workers than jobs is clamped, not an error
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
    }

    #[test]
    fn auto_jobs_resolves_to_at_least_one() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }

    #[test]
    fn lane_pool_runs_every_task_exactly_once_per_dispatch() {
        let mut pool = LanePool::new(3);
        assert_eq!(pool.workers(), 3);
        let hits: Vec<AtomicUsize> =
            (0..16).map(|_| AtomicUsize::new(0)).collect();
        // many generations through the same parked workers — the
        // whole point of the pool
        for round in 0..200 {
            pool.run(16, &|i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(
                    h.load(Ordering::Relaxed),
                    round + 1,
                    "task {i} after round {round}"
                );
            }
        }
    }

    #[test]
    fn lane_pool_tasks_see_and_mutate_disjoint_slots() {
        let mut pool = LanePool::new(2);
        let mut data = vec![0usize; 64];
        {
            let cells = IndexedCells::new(&mut data);
            pool.run(64, &|i| {
                // safety: each index claimed exactly once
                unsafe { *cells.get(i) = i * 7 };
            });
        }
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i * 7);
        }
    }

    #[test]
    fn lane_pool_zero_tasks_is_a_no_op() {
        let mut pool = LanePool::new(2);
        pool.run(0, &|_| panic!("must not run"));
    }

    #[test]
    fn panicking_lane_aborts_the_session_with_a_clear_message() {
        // the satellite lock: a dying lane must re-raise on the
        // dispatcher instead of deadlocking parked peers
        let ran = AtomicUsize::new(0);
        let mut pool = LanePool::new(2);
        let err = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                ran.fetch_add(1, Ordering::Relaxed);
                if i == 3 {
                    panic!("lane 3 exploded on purpose");
                }
            });
        }))
        .expect_err("the dispatch must re-raise the lane panic");
        let msg = panic_message(err);
        assert!(
            msg.contains("lane 3 exploded on purpose"),
            "panic must carry the lane's own message: {msg}"
        );
        assert!(
            msg.contains("epoch session aborted"),
            "panic must say the session aborted: {msg}"
        );
        // every lane still ran (the fragment drained despite the
        // panic), and the pool is neither deadlocked nor poisoned:
        // a fresh dispatch works and drop joins cleanly
        assert_eq!(ran.load(Ordering::Relaxed), 8);
        let ok = AtomicUsize::new(0);
        pool.run(4, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn worker_accounting_tracks_spawns() {
        // the counters are process-global and sibling unit tests spawn
        // pools concurrently, so only lower bounds are race-free here;
        // exact join-back-to-zero accounting is locked by
        // tests/pool_budget.rs, which owns its whole process
        let pool = LanePool::new(3);
        assert!(live_workers() >= 3);
        assert!(peak_workers() >= 3);
        drop(pool);
    }

    #[test]
    fn lane_allowance_guard_nests_and_restores_on_drop() {
        // thread-local, so this is exact even with concurrent tests
        {
            let _g = LaneAllowanceGuard::set(7);
            assert_eq!(lane_allowance(), 7);
            {
                let _inner = LaneAllowanceGuard::set(3);
                assert_eq!(lane_allowance(), 3);
            }
            assert_eq!(lane_allowance(), 7);
        }
        // unset again: falls back to the budget (uncapped when 0)
        assert!(lane_allowance() >= 1);
    }
}
