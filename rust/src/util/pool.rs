//! Scoped worker pool for embarrassingly parallel job grids (std-only;
//! the offline vendor set has no rayon).
//!
//! [`run_indexed`] executes jobs `0..n` on a fixed number of
//! `std::thread::scope` workers pulling indices off a shared atomic
//! counter, and returns the results **in job-index order** regardless
//! of which worker finished first — the property the sweep engine's
//! `--jobs` parity guarantee (`tests/sweep_parallel.rs`) is built on:
//! parallelism may only change wall-clock, never what any cell computes
//! or where its result lands.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Resolve a `--jobs` request: `0` means "auto" — one worker per
/// available hardware thread (falling back to 1 if the platform cannot
/// say).
pub fn resolve_jobs(requested: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    }
}

/// Run `n` independent jobs on up to `workers` threads and return the
/// results in job-index order.
///
/// `f(i)` must be pure with respect to shared state (interior
/// synchronization like the `bench::memo` per-key entry locks is fine);
/// it may be called from any worker thread. With `workers <= 1` (or a
/// single job) everything runs inline on the caller's thread — the
/// `--jobs 1` path is exactly the pre-pool sequential loop.
pub fn run_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let workers = workers.clamp(1, n.max(1));
    if workers <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    // each worker collects (index, result) pairs; the deterministic
    // order is restored after the join, exactly like the epoch
    // driver's lane reduction
    let per_worker: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                let next = &next;
                let f = &f;
                scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(i)));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("pool worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    for (i, t) in per_worker.into_iter().flatten() {
        debug_assert!(slots[i].is_none(), "job {i} claimed twice");
        slots[i] = Some(t);
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(i, s)| s.unwrap_or_else(|| panic!("job {i} never claimed")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn results_are_in_index_order() {
        for workers in [1, 2, 4, 9] {
            let out = run_indexed(23, workers, |i| i * i);
            let expect: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(out, expect, "workers={workers}");
        }
    }

    #[test]
    fn every_job_runs_exactly_once() {
        let counter = AtomicUsize::new(0);
        let out = run_indexed(100, 4, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(out.len(), 100);
    }

    #[test]
    fn degenerate_shapes() {
        assert!(run_indexed(0, 4, |i| i).is_empty());
        assert_eq!(run_indexed(1, 8, |i| i + 1), vec![1]);
        // more workers than jobs is clamped, not an error
        assert_eq!(run_indexed(2, 64, |i| i), vec![0, 1]);
    }

    #[test]
    fn auto_jobs_resolves_to_at_least_one() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }
}
