//! Mini property-testing framework (offline replacement for `proptest`).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs
//! from `gen`; on failure it performs greedy shrinking via the input's
//! `Shrink` implementation and panics with the minimal counterexample and
//! the seed to replay it. Seeds derive from `PROP_SEED` (env) so CI can
//! pin them.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose structurally smaller variants of themselves.
pub trait Shrink: Sized {
    /// Candidate smaller inputs, most aggressive first. Default: none.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Clone + Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec()); // drop back half
        out.push(self[self.len() / 2..].to_vec()); // drop front half
        let mut minus_one = self.clone();
        minus_one.pop();
        out.push(minus_one);
        // shrink one element
        for (i, x) in self.iter().enumerate().take(4) {
            for sx in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Clone + Shrink, B: Clone + Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property. `gen` draws an input from the RNG; `prop` returns
/// `Err(reason)` on violation.
pub fn check<T, G, P>(name: &str, cases: usize, mut gen: G, prop: P)
where
    T: Clone + Debug + Shrink,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let seed = std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE_u64);
    let mut rng = Rng::new(seed ^ hash_name(name));
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(reason) = prop(&input) {
            let (min_input, min_reason) = shrink_loop(input, reason, &prop);
            panic!(
                "property '{name}' failed (case {case}, seed {seed}):\n  \
                 reason: {min_reason}\n  minimal input: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<T, P>(mut input: T, mut reason: String, prop: &P) -> (T, String)
where
    T: Clone + Debug + Shrink,
    P: Fn(&T) -> Result<(), String>,
{
    // Greedy descent, bounded to keep failing tests fast.
    for _ in 0..200 {
        let mut advanced = false;
        for cand in input.shrink() {
            if let Err(r) = prop(&cand) {
                input = cand;
                reason = r;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    (input, reason)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            100,
            |r| {
                (0..8).map(|_| r.below(100)).collect::<Vec<usize>>()
            },
            |v| {
                let a: usize = v.iter().sum();
                let b: usize = v.iter().rev().sum();
                if a == b {
                    Ok(())
                } else {
                    Err("sum not commutative?!".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn failing_property_shrinks() {
        check(
            "no-vec-longer-than-3",
            100,
            |r| (0..r.below(20)).map(|_| r.below(10)).collect::<Vec<usize>>(),
            |v| {
                if v.len() <= 3 {
                    Ok(())
                } else {
                    Err(format!("len {}", v.len()))
                }
            },
        );
    }

    #[test]
    fn shrink_usize_descends() {
        assert!(5usize.shrink().contains(&0));
        assert!(0usize.shrink().is_empty());
    }
}
