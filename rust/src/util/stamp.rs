//! Generation-stamped hash containers for allocation-free hot loops.
//!
//! A stamped set/map is cleared by bumping a generation counter instead
//! of dropping its storage: `reset()` is O(1), membership is "present
//! *and* stamped with the current generation", and the underlying
//! `FxHashMap` keeps its capacity across resets. After a warm-up pass
//! over the touched key range the containers stop allocating entirely,
//! which is what lets the sampler and gather-planning scratch state
//! ([`crate::sampler::SampleScratch`],
//! [`crate::featstore::pregather::PlanScratch`]) run steady-state
//! iterations with zero heap traffic. Memory is bounded by the set of
//! keys ever touched (stale entries are overwritten in place on their
//! next insert, never scanned).

use crate::util::fxhash::FxHashMap;

/// Reusable `u32` set with O(1) clear.
#[derive(Debug, Default)]
pub struct StampedSet {
    gen: u64,
    slots: FxHashMap<u32, u64>,
}

impl StampedSet {
    /// Logically empty the set (O(1): bumps the generation).
    #[inline]
    pub fn reset(&mut self) {
        self.gen += 1;
    }

    /// Insert `v`; returns `true` if it was not yet present this
    /// generation (i.e. first occurrence since the last `reset`).
    #[inline]
    pub fn insert(&mut self, v: u32) -> bool {
        let gen = self.gen;
        match self.slots.insert(v, gen) {
            Some(prev) => prev != gen,
            None => true,
        }
    }

    /// Membership in the current generation.
    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        self.slots.get(&v) == Some(&self.gen)
    }
}

/// Reusable `u32 -> u32` map with O(1) clear (the sampler's local-index
/// interner table).
#[derive(Debug, Default)]
pub struct StampedMap {
    gen: u64,
    slots: FxHashMap<u32, (u64, u32)>,
}

impl StampedMap {
    /// Logically empty the map (O(1): bumps the generation).
    #[inline]
    pub fn reset(&mut self) {
        self.gen += 1;
    }

    /// Value for `v` if it was inserted this generation.
    #[inline]
    pub fn get(&self, v: u32) -> Option<u32> {
        match self.slots.get(&v) {
            Some(&(gen, idx)) if gen == self.gen => Some(idx),
            _ => None,
        }
    }

    /// Insert or overwrite `v -> idx` in the current generation.
    #[inline]
    pub fn insert(&mut self, v: u32, idx: u32) {
        self.slots.insert(v, (self.gen, idx));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_resets_in_o1_and_dedups_per_generation() {
        let mut s = StampedSet::default();
        assert!(s.insert(7));
        assert!(!s.insert(7));
        assert!(s.contains(7));
        s.reset();
        assert!(!s.contains(7), "stale generation must read as absent");
        assert!(s.insert(7), "first occurrence again after reset");
        assert!(!s.insert(7));
    }

    #[test]
    fn map_generation_semantics() {
        let mut m = StampedMap::default();
        assert_eq!(m.get(5), None);
        m.insert(5, 0);
        m.insert(9, 1);
        assert_eq!(m.get(5), Some(0));
        assert_eq!(m.get(9), Some(1));
        m.reset();
        assert_eq!(m.get(5), None);
        m.insert(5, 3);
        assert_eq!(m.get(5), Some(3));
    }

    #[test]
    fn many_generations_do_not_grow_past_touched_keys() {
        let mut s = StampedSet::default();
        for round in 0..100u32 {
            s.reset();
            for v in 0..32 {
                assert!(s.insert(v), "round {round} vertex {v}");
            }
        }
        assert_eq!(s.slots.len(), 32, "storage bounded by touched keys");
    }
}
