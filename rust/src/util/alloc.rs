//! Counting global allocator for allocation-budget tests.
//!
//! [`CountingAlloc`] wraps the system allocator and counts every
//! allocation event (`alloc`, `alloc_zeroed`, and `realloc` — the three
//! ways code acquires or grows heap memory; frees are not counted). It
//! is **test instrumentation only**: nothing in the library installs
//! it, so production binaries pay zero overhead. The allocation-budget
//! integration test (`tests/alloc_budget.rs`) installs it as its
//! `#[global_allocator]` and asserts that the steady-state iteration
//! hot loop — scratch-based sampling, buffer-reusing gather planning,
//! and recycled op programs — performs zero allocations after warm-up.
//!
//! The counter is a process-global atomic, so a meaningful budget
//! measurement needs a single-threaded window (the budget test runs as
//! the sole test of its integration-test binary and drives the driver
//! with `parallel_lanes` off).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCATION_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Number of allocation events since process start (monotone; take a
/// before/after delta around the region of interest).
pub fn allocation_count() -> u64 {
    ALLOCATION_EVENTS.load(Ordering::SeqCst)
}

/// System-allocator wrapper that counts allocation events. Install in a
/// test binary with `#[global_allocator] static A: CountingAlloc =
/// CountingAlloc;`.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCATION_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotone() {
        // The wrapper is not installed in unit tests, so the counter
        // only moves if some other binary installed it — all we can
        // assert here is monotonicity of the read API.
        let a = allocation_count();
        let b = allocation_count();
        assert!(b >= a);
    }
}
