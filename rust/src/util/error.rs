//! Minimal `anyhow`-style error type (the offline vendor set has no
//! anyhow — see DESIGN.md "Vendored-crate constraint").
//!
//! An [`Error`] is a context chain: the root cause plus the messages
//! layered on via [`Context::context`]/[`Context::with_context`].
//! `{}` prints the outermost message, `{:#}` the whole chain
//! outermost-first, `: `-separated — matching anyhow's conventions so
//! existing `eprintln!("{e:#}")` call sites keep their output shape.

use std::fmt;

/// String-chain error. Cheap, non-generic, and good enough for the
/// runtime/training paths, which only ever *report* errors.
#[derive(Clone, Debug)]
pub struct Error {
    /// Innermost (root cause) first; contexts appended as added.
    chain: Vec<String>,
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Self {
            chain: vec![m.into()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl Into<String>) -> Self {
        self.chain.push(c.into());
        self
    }

    /// Outermost message (what `{}` prints).
    pub fn top(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // outermost-first chain, like anyhow's `{:#}`
            for (i, m) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{m}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.top())
        }
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e.to_string())
    }
}

/// `.context("...")` / `.with_context(|| ...)` on `Result` and `Option`.
pub trait Context<T> {
    fn context(self, c: impl Into<String>) -> Result<T>;
    fn with_context<C: Into<String>, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context(self, c: impl Into<String>) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: Into<String>, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, c: impl Into<String>) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Into<String>, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `format!`-style error constructor (anyhow's `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted error (anyhow's `bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Assert-or-error (anyhow's `ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::err!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(Error::msg("root cause"))
    }

    #[test]
    fn context_chain_formats() {
        let e = fails().context("loading artifact").unwrap_err();
        assert_eq!(format!("{e}"), "loading artifact");
        assert_eq!(format!("{e:#}"), "loading artifact: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert!(f(3).is_ok());
        assert!(f(5).is_err());
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
    }
}
