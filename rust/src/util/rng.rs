//! Deterministic pseudo-random generators (no external crates).
//!
//! `SplitMix64` seeds `Xoshiro256PlusPlus` (the reference constructions of
//! Blackman & Vigna). Every stochastic component in the system — graph
//! generation, sampling, mini-batch shuffling, parameter init — draws from
//! one of these with an explicit seed, so whole experiments replay
//! bit-identically (the integration tests assert this).

/// SplitMix64: tiny, full-period 2^64 generator; used for seeding.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++: the general-purpose generator for everything else.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent stream (for per-server / per-worker RNGs).
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let [s0, s1, s2, s3] = self.s;
        let result = s0
            .wrapping_add(s3)
            .rotate_left(23)
            .wrapping_add(s0);
        let t = s1 << 17;
        let mut s = [s0, s1, s2, s3];
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        self.s = s;
        result
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift, unbiased enough for
    /// simulation purposes; n must be > 0).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    #[inline]
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal (Box-Muller; one value per call, simple & adequate).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Two independent standard normals for one (ln, sqrt, sin, cos) —
    /// the full Box-Muller pair. Feature synthesis is 2x faster with it
    /// (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        (r * c, r * s)
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn coin(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm when
    /// k << n, full shuffle otherwise). Order is not specified.
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut out = Vec::with_capacity(k);
        self.sample_distinct_into(n, k, &mut out);
        out
    }

    /// [`Self::sample_distinct`] into a caller-owned buffer (cleared
    /// first): the sampler hot loop reuses one buffer across all draws
    /// so steady-state sampling allocates nothing. Draw-for-draw
    /// identical to `sample_distinct` — both branches consume the
    /// generator in the same order as the allocating version always
    /// has, so replayed experiments stay bit-identical.
    pub fn sample_distinct_into(
        &mut self,
        n: usize,
        k: usize,
        out: &mut Vec<usize>,
    ) {
        assert!(k <= n, "cannot sample {k} from {n}");
        out.clear();
        if k * 3 > n {
            out.extend(0..n);
            self.shuffle(out);
            out.truncate(k);
            return;
        }
        // Floyd: guarantees distinctness with expected O(k) work.
        for j in n - k..n {
            let t = self.below(j + 1);
            if out.contains(&t) {
                out.push(j);
            } else {
                out.push(t);
            }
        }
    }

    /// Weighted index draw proportional to `weights` (linear scan; fine for
    /// the small weight vectors used in generators).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Draw from a Zipf-ish power-law over `[0, n)` with exponent `alpha`
    /// via inverse-CDF on a continuous Pareto, clamped. Used by the graph
    /// generators for degree sequences.
    pub fn powerlaw(&mut self, n: usize, alpha: f64) -> usize {
        let u = self.f64().max(1e-12);
        let x = u.powf(-1.0 / (alpha - 1.0)) - 1.0;
        (x as usize).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forked_streams_differ() {
        let mut a = Rng::new(42);
        let mut f1 = a.fork(1);
        let mut f2 = a.fork(2);
        let x: Vec<u64> = (0..8).map(|_| f1.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| f2.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_mean() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        let n = 100_000;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_complete() {
        let mut r = Rng::new(5);
        for (n, k) in [(10, 10), (100, 3), (50, 25), (1, 1), (64, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_distinct_into_matches_allocating_version() {
        // Both branches (shuffle and Floyd) must consume the stream
        // identically — the scratch-based samplers rely on it.
        for (n, k) in [(10, 10), (100, 3), (50, 25), (1, 1), (64, 0), (30, 11)]
        {
            let mut a = Rng::new(99);
            let mut b = Rng::new(99);
            let mut buf = vec![777usize; 4]; // stale content must not leak
            for round in 0..5 {
                let v = a.sample_distinct(n, k);
                b.sample_distinct_into(n, k, &mut buf);
                assert_eq!(v, buf, "n={n} k={k} round={round}");
            }
            assert_eq!(a.next_u64(), b.next_u64(), "stream diverged n={n}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(13);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[1] > 8_000, "{counts:?}");
    }

    #[test]
    fn powerlaw_bounds_and_skew() {
        let mut r = Rng::new(17);
        let mut lo = 0usize;
        for _ in 0..10_000 {
            let x = r.powerlaw(1000, 2.5);
            assert!(x < 1000);
            if x < 10 {
                lo += 1;
            }
        }
        // power-law mass concentrates near 0
        assert!(lo > 7_000, "low-bucket count {lo}");
    }
}
