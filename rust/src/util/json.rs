//! Minimal JSON: recursive-descent parser + writer.
//!
//! Exists because the offline vendor set has no `serde`; used to read
//! `artifacts/manifest.json` (the Rust<->python ABI) and to emit experiment
//! reports. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (not needed for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `obj.path("a.b.c")`.
    pub fn path(&self, dotted: &str) -> Option<&Value> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Serialize a `Value` (compact). `pretty` adds two-space indentation.
pub fn write(v: &Value, pretty: bool) -> String {
    let mut out = String::new();
    write_into(v, pretty, 0, &mut out);
    out
}

fn write_into(v: &Value, pretty: bool, depth: usize, out: &mut String) {
    let pad = |out: &mut String, d: usize| {
        if pretty {
            out.push('\n');
            for _ in 0..d {
                out.push_str("  ");
            }
        }
    };
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => {
            if n.fract() == 0.0 && n.abs() < 1e15 {
                out.push_str(&format!("{}", *n as i64));
            } else {
                out.push_str(&format!("{n}"));
            }
        }
        Value::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => {
                        out.push_str(&format!("\\u{:04x}", c as u32))
                    }
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Value::Arr(a) => {
            out.push('[');
            for (i, x) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, depth + 1);
                write_into(x, pretty, depth + 1, out);
            }
            if !a.is_empty() {
                pad(out, depth);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, depth + 1);
                write_into(&Value::Str(k.clone()), false, 0, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_into(x, pretty, depth + 1, out);
            }
            if !m.is_empty() {
                pad(out, depth);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse(" -3.5e2 ").unwrap(), Value::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{} extra").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"gcn","shapes":[[128,128],[10]],"n":34314,"ok":true}"#;
        let v = parse(src).unwrap();
        let out = write(&v, false);
        assert_eq!(parse(&out).unwrap(), v);
        let out_pretty = write(&v, true);
        assert_eq!(parse(&out_pretty).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }
}
