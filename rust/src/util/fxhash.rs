//! Minimal FxHash-style hasher for integer keys (the vendor set has no
//! fxhash/ahash). SipHash's per-insert cost dominated the gather-planning
//! hot loop (EXPERIMENTS.md §Perf); this multiply-rotate hasher is ~3x
//! faster for u32 vertex ids while keeping HashMap/HashSet semantics.

use std::hash::{BuildHasherDefault, Hasher};

const SEED64: u64 = 0x51_7c_c1_b7_27_22_0a_95;

#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuildHasher>;
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_semantics() {
        let mut s: FxHashSet<u32> = FxHashSet::default();
        assert!(s.insert(7));
        assert!(!s.insert(7));
        assert!(s.insert(8));
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn spreads_sequential_keys() {
        // sequential vertex ids must not collide into few buckets: check
        // the low bits of hashes differ
        use std::hash::BuildHasher;
        let bh = FxBuildHasher::default();
        let mut low_bits = std::collections::HashSet::new();
        for v in 0u32..64 {
            let mut h = bh.build_hasher();
            h.write_u32(v);
            low_bits.insert(h.finish() & 0x3f);
        }
        assert!(low_bits.len() > 32, "only {} distinct", low_bits.len());
    }
}
