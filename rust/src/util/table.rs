//! ASCII table printer for experiment reports (paper-style rows).

/// A simple left-aligned table with a header row.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Self {
        Self {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch: {cells:?}"
        );
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                out.push_str("| ");
                out.push_str(c);
                for _ in c.chars().count()..widths[i] {
                    out.push(' ');
                }
                out.push(' ');
            }
            out.push_str("|\n");
        };
        fmt_row(&self.headers, &widths, &mut out);
        for (i, w) in widths.iter().enumerate() {
            out.push_str(if i == 0 { "|" } else { "|" });
            for _ in 0..w + 2 {
                out.push('-');
            }
        }
        out.push_str("|\n");
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }

    /// Markdown rendering (same as render; GitHub-flavoured tables).
    pub fn markdown(&self) -> String {
        self.render()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Column headers (for structured report export).
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    /// Data rows (for structured report export).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }
}

/// Format seconds human-readably (ms below 1 s).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else if s < 100.0 {
        format!("{s:.2}s")
    } else {
        format!("{s:.0}s")
    }
}

/// Format a byte count (binary units).
pub fn fmt_bytes(b: u64) -> String {
    const U: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = b as f64;
    let mut u = 0;
    while x >= 1024.0 && u < U.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{x:.2}{}", U[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(["name", "value"]);
        t.row(["a", "1"]);
        t.row(["longer-name", "2.5x"]);
        let s = t.render();
        assert!(s.contains("| name        | value |"), "{s}");
        assert!(s.contains("| longer-name | 2.5x  |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(35 * 1024 * 1024 * 1024), "35.00GiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(fmt_secs(0.5e-3), "500.0µs");
        assert_eq!(fmt_secs(0.25), "250.00ms");
        assert_eq!(fmt_secs(2.0), "2.00s");
    }
}
