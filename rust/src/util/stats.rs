//! Small statistics helpers for metrics and the bench harness.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation — robust spread for bench reporting.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Geometric mean of positive values (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Max load / mean load — the balance metric for partitions & merging.
pub fn imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 0.0;
    }
    let m = mean(loads);
    if m == 0.0 {
        return 0.0;
    }
    loads.iter().cloned().fold(f64::MIN, f64::max) / m
}

/// Streaming quantile estimator — the P² (piecewise-parabolic)
/// algorithm of Jain & Chlamtac (CACM 1985).
///
/// Tracks one quantile `p` with five markers in O(1) space and O(1)
/// per observation, **allocation-free** after construction — which is
/// why the serving engine can feed it per-request latencies inside the
/// zero-alloc steady-state loop (`tests/alloc_budget.rs`). Accuracy
/// against exact sort-based quantiles on adversarial (bimodal,
/// heavy-tail) streams is locked by `tests/serve_parity.rs`.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    /// Observations seen. The first five land in `q` directly.
    n: u64,
    /// Marker heights (estimates of the 0, p/2, p, (1+p)/2, 1 quantiles).
    q: [f64; 5],
    /// Actual marker positions (1-based ranks into the stream).
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Per-observation increments of the desired positions.
    incr: [f64; 5],
}

impl P2Quantile {
    /// `p` is the quantile fraction in (0, 1), e.g. `0.99`.
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0, "quantile fraction out of (0,1): {p}");
        Self {
            p,
            n: 0,
            q: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            incr: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
        }
    }

    /// The tracked quantile fraction.
    pub fn p(&self) -> f64 {
        self.p
    }

    /// Observations fed so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Feed one observation. Allocation-free.
    pub fn observe(&mut self, x: f64) {
        self.n += 1;
        if self.n <= 5 {
            // bootstrap: insertion-sort the first five into the markers
            let k = (self.n - 1) as usize;
            self.q[k] = x;
            let mut i = k;
            while i > 0 && self.q[i - 1] > self.q[i] {
                self.q.swap(i - 1, i);
                i -= 1;
            }
            return;
        }
        // find the cell k with q[k] <= x < q[k+1], clamping outliers
        // into the end markers
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            while k < 3 && x >= self.q[k + 1] {
                k += 1;
            }
            k
        };
        for i in (k + 1)..5 {
            self.pos[i] += 1.0;
        }
        for i in 0..5 {
            self.desired[i] += self.incr[i];
        }
        // adjust the three interior markers toward their desired
        // positions: parabolic (P²) when the neighbor gap admits it,
        // linear otherwise
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let d = d.signum();
                let qp = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, d)
                };
                self.pos[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, pos) = (&self.q, &self.pos);
        q[i] + d / (pos[i + 1] - pos[i - 1])
            * ((pos[i] - pos[i - 1] + d) * (q[i + 1] - q[i])
                / (pos[i + 1] - pos[i])
                + (pos[i + 1] - pos[i] - d) * (q[i] - q[i - 1])
                    / (pos[i] - pos[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current quantile estimate (exact while n <= 5; 0.0 when empty).
    /// Allocation-free.
    pub fn value(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n <= 5 {
            // markers hold the sorted prefix: interpolate exactly
            let n = self.n as usize;
            let rank = self.p * (n - 1) as f64;
            let lo = rank.floor() as usize;
            let hi = rank.ceil() as usize;
            return if lo == hi {
                self.q[lo]
            } else {
                self.q[lo] + (self.q[hi] - self.q[lo]) * (rank - lo as f64)
            };
        }
        self.q[2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 100.0), 30.0);
        assert_eq!(percentile(&xs, 25.0), 15.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert_eq!(geomean(&xs), 4.0);
    }

    #[test]
    fn imbalance_metric() {
        assert_eq!(imbalance(&[1.0, 1.0, 1.0]), 1.0);
        assert_eq!(imbalance(&[2.0, 1.0, 0.0]), 2.0);
        assert_eq!(imbalance(&[]), 0.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
        assert_eq!(P2Quantile::new(0.5).value(), 0.0);
    }

    #[test]
    fn p2_is_exact_on_tiny_streams() {
        let mut est = P2Quantile::new(0.5);
        for x in [5.0, 1.0, 3.0] {
            est.observe(x);
        }
        assert_eq!(est.value(), 3.0);
        assert_eq!(est.count(), 3);
        est.observe(2.0);
        est.observe(4.0);
        assert_eq!(est.value(), 3.0, "exact median of 1..=5");
    }

    #[test]
    fn p2_tracks_uniform_quantiles_closely() {
        // a deterministic low-discrepancy uniform stream: P² is known
        // accurate here, so the check can be tight
        for p in [0.5, 0.95, 0.99] {
            let mut est = P2Quantile::new(p);
            let mut xs = Vec::new();
            let mut u = 0.5f64;
            for _ in 0..10_000 {
                u = (u + 0.754_877_666_246_692_9).fract(); // 2 - phi
                est.observe(u);
                xs.push(u);
            }
            let exact = percentile(&xs, p * 100.0);
            assert!(
                (est.value() - exact).abs() < 0.02,
                "p={p}: estimate {} vs exact {exact}",
                est.value()
            );
        }
    }

    #[test]
    fn p2_end_markers_track_extremes() {
        let mut est = P2Quantile::new(0.9);
        for i in 0..1000 {
            est.observe(f64::from(i));
        }
        let v = est.value();
        assert!(v > 850.0 && v < 950.0, "p90 of 0..1000 was {v}");
    }
}
