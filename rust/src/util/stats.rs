//! Small statistics helpers for metrics and the bench harness.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64)
        .sqrt()
}

/// Median (by sorting a copy).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Median absolute deviation — robust spread for bench reporting.
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

/// Geometric mean of positive values (used for speedup aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Max load / mean load — the balance metric for partitions & merging.
pub fn imbalance(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 0.0;
    }
    let m = mean(loads);
    if m == 0.0 {
        return 0.0;
    }
    loads.iter().cloned().fold(f64::MIN, f64::max) / m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
        assert_eq!(median(&xs), 2.5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 20.0);
        assert_eq!(percentile(&xs, 100.0), 30.0);
        assert_eq!(percentile(&xs, 25.0), 15.0);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [1.0, 1.1, 0.9, 1.0, 100.0];
        assert!(mad(&xs) < 0.2);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert_eq!(geomean(&xs), 4.0);
    }

    #[test]
    fn imbalance_metric() {
        assert_eq!(imbalance(&[1.0, 1.0, 1.0]), 1.0);
        assert_eq!(imbalance(&[2.0, 1.0, 0.0]), 2.0);
        assert_eq!(imbalance(&[]), 0.0);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
        assert_eq!(geomean(&[]), 0.0);
    }
}
