//! Tiny declarative CLI flag parser (offline replacement for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, and generates `--help` text. Used by `main.rs` and the examples.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Options the user actually typed (vs. spec defaults) — lets
    /// callers layer CLI values over a config file without the
    /// defaults stomping the file's settings.
    explicit: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| {
                s.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{s}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| {
                s.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got '{s}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.values.contains_key(name)
    }

    /// Was `--name` given on the command line (not just a default)?
    pub fn explicit(&self, name: &str) -> bool {
        self.explicit.iter().any(|n| n == name)
    }
}

pub struct Cli {
    pub name: &'static str,
    pub about: &'static str,
    pub specs: Vec<ArgSpec>,
}

impl Cli {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            specs: Vec::new(),
        }
    }

    pub fn opt(
        mut self,
        name: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: Some(default),
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(ArgSpec {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for spec in &self.specs {
            let d = spec
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  --{:<22} {}{}\n", spec.name, spec.help, d));
        }
        s
    }

    /// Parse an iterator of argument strings (exclude argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        &self,
        argv: I,
    ) -> Result<Args, String> {
        let mut args = Args::default();
        for spec in &self.specs {
            if let Some(d) = spec.default {
                args.values.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                return Err(self.help_text());
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| {
                        format!("unknown option --{name}\n\n{}", self.help_text())
                    })?;
                if spec.is_flag {
                    if let Some(v) = inline {
                        args.values.insert(name.clone(), v);
                    }
                    args.flags.push(name.clone());
                    args.explicit.push(name);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    args.values.insert(name.clone(), v);
                    args.explicit.push(name);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn parse_env(&self) -> Result<Args, String> {
        self.parse(std::env::args().skip(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("dataset", "arxiv-s", "dataset name")
            .opt("servers", "4", "server count")
            .flag("verbose", "chatty")
    }

    fn parse(toks: &[&str]) -> Args {
        cli().parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get("dataset"), Some("arxiv-s"));
        assert_eq!(a.get_usize("servers", 0), 4);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = parse(&["--dataset", "uk-s", "--servers=8", "--verbose", "go"]);
        assert_eq!(a.get("dataset"), Some("uk-s"));
        assert_eq!(a.get_usize("servers", 0), 8);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["go"]);
    }

    #[test]
    fn explicit_distinguishes_typed_from_default() {
        let a = parse(&["--servers", "8", "--verbose"]);
        assert!(a.explicit("servers"));
        assert!(a.explicit("verbose"));
        assert!(!a.explicit("dataset"), "default is not explicit");
        assert_eq!(a.get("dataset"), Some("arxiv-s"), "default still applies");
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cli()
            .parse(["--nope".to_string()])
            .is_err());
    }

    #[test]
    fn help_is_error_path() {
        let e = cli().parse(["--help".to_string()]).unwrap_err();
        assert!(e.contains("--dataset"));
    }
}
