//! Real (PJRT-executed) training: the numeric counterpart of the
//! simulated strategies. Used by the end-to-end example, Table 3
//! (accuracy), and runtime cost calibration.
//!
//! One logical model is trained (data-parallel replicas are numerically
//! identical after each allreduce, so a single parameter set is exact);
//! what differs between order policies is the *composition of each
//! iteration's mini-batch* — which is precisely the paper's accuracy
//! argument (§5.1, §7.9):
//!
//! * `Global`  — DGL and HopGNN: every iteration draws uniformly from the
//!   globally shuffled training set. (HopGNN redistributes *where* each
//!   micrograph is trained, never *which* roots form the batch, and
//!   gradient accumulation keeps the update identical — Table 3's "S".)
//! * `LocalityOpt` — each server draws only from its own partition's
//!   shard, cycling independently; shards are unequal so some vertices
//!   are oversampled per epoch — the biased sequence that costs accuracy.

pub mod accuracy;

use crate::graph::datasets::Dataset;
use crate::partition::Partition;
use crate::runtime::{Adam, BatchBuffers, Engine, ParamSet};
use crate::sampler::{sample_micrograph, Micrograph, SampleConfig};
use crate::util::error::Result;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OrderPolicy {
    /// Globally shuffled batches (DGL & HopGNN semantics).
    Global,
    /// Per-server local shards, independently cycled (LO semantics).
    LocalityOpt,
}

pub struct EpochStats {
    pub mean_loss: f64,
    pub steps: usize,
    pub train_accuracy: f64,
}

pub struct Trainer {
    pub engine: Engine,
    pub params: ParamSet,
    pub opt: Adam,
    buffers: BatchBuffers,
    sample_cfg: SampleConfig,
    rng: Rng,
}

impl Trainer {
    pub fn new(
        engine: Engine,
        sample_cfg: SampleConfig,
        lr: f32,
        seed: u64,
    ) -> Self {
        let params = ParamSet::init(&engine.spec, seed);
        let opt = Adam::new(&params, lr);
        let buffers = BatchBuffers::for_artifact(&engine.spec);
        Self {
            engine,
            params,
            opt,
            buffers,
            sample_cfg,
            rng: Rng::new(seed ^ 0x7A11),
        }
    }

    /// Train one epoch; `batch_size` roots per optimizer step.
    pub fn train_epoch(
        &mut self,
        dataset: &Dataset,
        partition: Option<&Partition>,
        policy: OrderPolicy,
        batch_size: usize,
    ) -> Result<EpochStats> {
        let batches = self.plan_batches(dataset, partition, policy,
                                        batch_size);
        let mut total_loss = 0.0;
        let mut total_correct = 0u64;
        let mut total_seen = 0u64;
        let mut grad_acc = self.params.zeros_like();

        for batch_roots in &batches {
            grad_acc.zero();
            let mut micros = 0usize;
            // HopGNN-style gradient accumulation: the batch is processed
            // in fixed-size executable calls; gradients accumulate and
            // the optimizer steps once per logical batch.
            let b = self.engine.spec.batch;
            let mut mgs: Vec<Micrograph> = Vec::with_capacity(b);
            let mut chunks: Vec<Vec<Micrograph>> = Vec::new();
            for &root in batch_roots {
                mgs.push(sample_micrograph(
                    &dataset.graph,
                    root,
                    &self.sample_cfg,
                    &mut self.rng,
                ));
                if mgs.len() == b {
                    chunks.push(std::mem::take(&mut mgs));
                }
            }
            if !mgs.is_empty() {
                // fill the ragged tail by repeating its head (padding
                // slots would otherwise inject f(0) gradients)
                let mut i = 0;
                while mgs.len() < b {
                    mgs.push(mgs[i % mgs.len().max(1)].clone());
                    i += 1;
                }
                chunks.push(mgs);
            }
            for chunk in &chunks {
                let packed = self.buffers.pack(chunk, dataset);
                debug_assert_eq!(packed, b);
                let out =
                    self.engine.train_step_b(&self.params, &self.buffers)?;
                total_loss += out.loss as f64 * b as f64;
                total_correct += out.correct as u64;
                total_seen += b as u64;
                grad_acc.add_from_slices(&out.grads);
                micros += b;
            }
            // average accumulated grads over executable calls (each call
            // already returns a batch-mean gradient)
            grad_acc.scale(1.0 / chunks.len().max(1) as f32);
            self.opt.step(&mut self.params, &grad_acc);
            let _ = micros;
        }

        Ok(EpochStats {
            mean_loss: if total_seen == 0 {
                0.0
            } else {
                total_loss / total_seen as f64
            },
            steps: batches.len(),
            train_accuracy: if total_seen == 0 {
                0.0
            } else {
                total_correct as f64 / total_seen as f64
            },
        })
    }

    /// Accuracy over a vertex set (validation / test).
    pub fn evaluate(&mut self, dataset: &Dataset, vertices: &[u32])
                    -> Result<f64> {
        let b = self.engine.spec.batch;
        let classes = self.engine.spec.classes;
        let mut correct = 0u64;
        let mut total = 0u64;
        let mut mgs: Vec<Micrograph> = Vec::with_capacity(b);
        let flush = |mgs: &mut Vec<Micrograph>,
                     this: &mut Self|
         -> Result<(u64, u64)> {
            if mgs.is_empty() {
                return Ok((0, 0));
            }
            let real = mgs.len();
            let mut i = 0;
            while mgs.len() < b {
                mgs.push(mgs[i % real].clone());
                i += 1;
            }
            this.buffers.pack(mgs, dataset);
            let logits = this.engine.predict_b(&this.params, &this.buffers)?;
            let mut c = 0u64;
            for (k, mg) in mgs.iter().take(real).enumerate() {
                let row = &logits[k * classes..(k + 1) * classes];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                if pred == dataset.labels[mg.root as usize] as usize {
                    c += 1;
                }
            }
            mgs.clear();
            Ok((c, real as u64))
        };
        for &v in vertices {
            mgs.push(sample_micrograph(
                &dataset.graph,
                v,
                &self.sample_cfg,
                &mut self.rng,
            ));
            if mgs.len() == b {
                let (c, t) = flush(&mut mgs, self)?;
                correct += c;
                total += t;
            }
        }
        let (c, t) = flush(&mut mgs, self)?;
        correct += c;
        total += t;
        Ok(if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        })
    }

    /// Compose the epoch's batches according to the order policy.
    fn plan_batches(
        &mut self,
        dataset: &Dataset,
        partition: Option<&Partition>,
        policy: OrderPolicy,
        batch_size: usize,
    ) -> Vec<Vec<u32>> {
        match policy {
            OrderPolicy::Global => {
                let mut roots = dataset.train_vertices.clone();
                self.rng.shuffle(&mut roots);
                roots
                    .chunks(batch_size)
                    .filter(|c| c.len() == batch_size)
                    .map(|c| c.to_vec())
                    .collect()
            }
            OrderPolicy::LocalityOpt => {
                let part = partition
                    .expect("LocalityOpt needs a partition");
                let n = part.num_parts;
                // per-server local shards, each shuffled locally
                let mut shards: Vec<Vec<u32>> = vec![Vec::new(); n];
                for &r in &dataset.train_vertices {
                    shards[part.home(r) as usize].push(r);
                }
                for s in shards.iter_mut() {
                    self.rng.shuffle(s);
                }
                // iterations: as many as the GLOBAL count; each server
                // contributes batch/n roots from its own shard, cycling
                // (small shards wrap -> oversampling bias)
                let iters = dataset.train_vertices.len() / batch_size;
                let per = batch_size / n;
                let mut cursors = vec![0usize; n];
                let mut out = Vec::with_capacity(iters);
                for _ in 0..iters {
                    let mut batch = Vec::with_capacity(per * n);
                    for s in 0..n {
                        if shards[s].is_empty() {
                            continue;
                        }
                        for _ in 0..per {
                            batch.push(shards[s][cursors[s] % shards[s].len()]);
                            cursors[s] += 1;
                        }
                    }
                    if batch.len() == per * n {
                        out.push(batch);
                    }
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_test_dataset;
    use crate::partition::{partition, PartitionAlgo};

    // plan_batches is pure scheduling: test it without an Engine by
    // exercising the policies through a standalone planner instance.
    fn plan(
        policy: OrderPolicy,
        batch: usize,
    ) -> (Vec<Vec<u32>>, Dataset) {
        let d = tiny_test_dataset(90);
        let p = partition(&d.graph, 4, PartitionAlgo::MetisLike, 1);
        let mut rng = Rng::new(7);
        // reimplement the tiny pure parts inline to avoid Engine deps
        let batches = match policy {
            OrderPolicy::Global => {
                let mut roots = d.train_vertices.clone();
                rng.shuffle(&mut roots);
                roots
                    .chunks(batch)
                    .filter(|c| c.len() == batch)
                    .map(|c| c.to_vec())
                    .collect()
            }
            OrderPolicy::LocalityOpt => {
                let mut shards: Vec<Vec<u32>> = vec![Vec::new(); 4];
                for &r in &d.train_vertices {
                    shards[p.home(r) as usize].push(r);
                }
                let iters = d.train_vertices.len() / batch;
                let per = batch / 4;
                let mut cursors = vec![0usize; 4];
                let mut out = Vec::new();
                for _ in 0..iters {
                    let mut b = Vec::new();
                    for s in 0..4 {
                        if shards[s].is_empty() {
                            continue;
                        }
                        for _ in 0..per {
                            b.push(shards[s][cursors[s] % shards[s].len()]);
                            cursors[s] += 1;
                        }
                    }
                    out.push(b);
                }
                out
            }
        };
        (batches, d)
    }

    #[test]
    fn global_batches_cover_without_repeats() {
        let (batches, d) = plan(OrderPolicy::Global, 20);
        let flat: Vec<u32> = batches.iter().flatten().copied().collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), flat.len(), "global batches must not repeat");
        assert_eq!(flat.len(), (d.train_vertices.len() / 20) * 20);
    }

    #[test]
    fn lo_batches_oversample_small_shards() {
        let (batches, _) = plan(OrderPolicy::LocalityOpt, 20);
        let flat: Vec<u32> = batches.iter().flatten().copied().collect();
        let mut sorted = flat.clone();
        sorted.sort_unstable();
        let before = sorted.len();
        sorted.dedup();
        // unequal shards + cycling => some vertices appear twice
        assert!(sorted.len() <= before, "dedup sanity");
    }
}
