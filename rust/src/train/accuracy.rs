//! Table 3: model accuracy under DGL / LO / HopGNN training orders.
//!
//! The paper's claim: HopGNN preserves accuracy exactly (its batches are
//! the same global-random batches as DGL's; gradient accumulation is
//! mathematically transparent), while the locality-optimized ordering
//! (LO) biases the sequence and drops accuracy.

use crate::graph::datasets::Dataset;
use crate::partition::Partition;
use crate::runtime::{Engine, Manifest};
use crate::sampler::SampleConfig;
use crate::train::{OrderPolicy, Trainer};
use crate::util::error::Result;

pub struct AccuracyRow {
    pub system: &'static str,
    pub val_accuracy: f64,
    pub final_loss: f64,
}

/// Train one configuration to (near-)convergence and report val accuracy.
pub fn train_and_eval(
    dataset: &Dataset,
    partition: Option<&Partition>,
    manifest: &Manifest,
    model: &str,
    hidden: usize,
    policy: OrderPolicy,
    epochs: usize,
    batch_size: usize,
    seed: u64,
) -> Result<AccuracyRow> {
    let spec = manifest
        .find(model, hidden, dataset.feat_dim)
        .ok_or_else(|| {
            crate::err!(
                "no artifact for {model} h{hidden} f{} — extend \
                 DEFAULT_VARIANTS in python/compile/aot.py",
                dataset.feat_dim
            )
        })?;
    let engine = Engine::load(spec)?;
    let sample_cfg = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: crate::sampler::SamplerKind::NodeWise,
    };
    let mut trainer = Trainer::new(engine, sample_cfg, 3e-3, seed);
    let mut final_loss = f64::NAN;
    for _ in 0..epochs {
        let stats =
            trainer.train_epoch(dataset, partition, policy, batch_size)?;
        final_loss = stats.mean_loss;
    }
    let val_accuracy = trainer.evaluate(dataset, &dataset.val_vertices)?;
    Ok(AccuracyRow {
        system: match policy {
            OrderPolicy::Global => "Global",
            OrderPolicy::LocalityOpt => "LO",
        },
        val_accuracy,
        final_loss,
    })
}
