//! Node-wise k-hop neighbor sampling (GraphSAGE style): every vertex on
//! the frontier samples up to `fanout` distinct neighbors for the next
//! hop. The workhorse sampler for all end-to-end experiments (the paper
//! uses fanout 10 throughout §7).

use super::{intern, Micrograph, SampleConfig, SampleScratch};
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

pub fn sample(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Micrograph {
    let mut scratch = SampleScratch::new();
    sample_into(graph, root, cfg, rng, &mut scratch);
    scratch.take_micrograph(root, cfg.layers)
}

/// Scratch-based implementation: identical draw order and output to the
/// historical allocating version (`sample` is now a thin wrapper).
pub fn sample_into(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    scratch.reset(root);
    let SampleScratch {
        map,
        vertices,
        depth: depths,
        edges,
        frontier,
        next_frontier,
        picks,
        ..
    } = scratch;
    frontier.push(0); // local indices
    edges.push((0, 0)); // root self-loop

    for depth in 0..cfg.layers as u8 {
        next_frontier.clear();
        for &dst_local in frontier.iter() {
            let dst_global = vertices[dst_local as usize];
            let neigh = graph.neighbors(dst_global);
            if neigh.is_empty() {
                continue;
            }
            let k = cfg.fanout.min(neigh.len());
            rng.sample_distinct_into(neigh.len(), k, picks);
            for &pi in picks.iter() {
                let src_global = neigh[pi];
                if let Some(src_local) =
                    intern(map, vertices, depths, src_global, depth + 1, cfg.vmax)
                {
                    edges.push((dst_local, src_local));
                    // newly discovered non-leaf vertex joins the next
                    // frontier and gets a self-loop (it participates in
                    // aggregations at shallower layers)
                    if src_local as usize == vertices.len() - 1
                        && (depth + 1) < cfg.layers as u8
                    {
                        next_frontier.push(src_local);
                        edges.push((src_local, src_local));
                    }
                }
            }
        }
        std::mem::swap(frontier, next_frontier);
        if frontier.is_empty() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};
    use crate::sampler::SamplerKind;

    fn graph() -> CsrGraph {
        community_graph(&CommunityGraphSpec {
            num_vertices: 1000,
            num_edges: 9000,
            num_communities: 10,
            seed: 31,
            ..Default::default()
        })
        .graph
    }

    fn cfg(layers: usize, fanout: usize, vmax: usize) -> SampleConfig {
        SampleConfig {
            layers,
            fanout,
            vmax,
            kind: SamplerKind::NodeWise,
        }
    }

    #[test]
    fn respects_fanout_bound() {
        let g = graph();
        let c = cfg(2, 3, 128);
        let mut rng = Rng::new(1);
        let mg = sample(&g, 5, &c, &mut rng);
        // count sampled (non-self-loop) out-edges per dst
        let mut counts = std::collections::HashMap::new();
        for &(d, s) in &mg.edges {
            if d != s {
                *counts.entry(d).or_insert(0usize) += 1;
            }
        }
        for (&d, &c2) in &counts {
            assert!(c2 <= 3, "vertex {d} sampled {c2} > fanout");
        }
    }

    #[test]
    fn vertex_count_bounded_by_fanout_series() {
        let g = graph();
        let c = cfg(2, 4, 10_000);
        let mut rng = Rng::new(2);
        let mg = sample(&g, 17, &c, &mut rng);
        // 1 + 4 + 16 = 21 max
        assert!(mg.num_vertices() <= 21, "{}", mg.num_vertices());
    }

    #[test]
    fn respects_vmax_cap() {
        let g = graph();
        let c = cfg(3, 10, 32);
        let mut rng = Rng::new(3);
        let mg = sample(&g, 42, &c, &mut rng);
        assert!(mg.num_vertices() <= 32);
        for &(d, s) in &mg.edges {
            assert!((d as usize) < 32 && (s as usize) < 32);
        }
    }

    #[test]
    fn depths_consistent_with_edges() {
        let g = graph();
        let c = cfg(3, 4, 256);
        let mut rng = Rng::new(4);
        let mg = sample(&g, 9, &c, &mut rng);
        for &(d, s) in &mg.edges {
            if d != s {
                assert!(
                    mg.depth[s as usize] <= mg.depth[d as usize] + 1,
                    "edge ({d},{s}) depth jump"
                );
            }
        }
        // only vertices with depth < layers have out-edges
        for &(d, s) in &mg.edges {
            if d != s {
                assert!((mg.depth[d as usize] as usize) < c.layers);
                let _ = s;
            }
        }
    }

    #[test]
    fn isolated_root_is_fine() {
        let g = CsrGraph::from_edges(4, &[(1, 2)]);
        let c = cfg(2, 4, 16);
        let mut rng = Rng::new(5);
        let mg = sample(&g, 0, &c, &mut rng);
        assert_eq!(mg.num_vertices(), 1);
        assert_eq!(mg.edges, vec![(0, 0)]);
    }

    #[test]
    fn every_vertex_has_self_loop() {
        let g = graph();
        let c = cfg(2, 5, 64);
        let mut rng = Rng::new(6);
        let mg = sample(&g, 123, &c, &mut rng);
        for i in 0..mg.num_vertices() as u32 {
            if (mg.depth[i as usize] as usize) < c.layers {
                assert!(
                    mg.edges.contains(&(i, i)),
                    "vertex {i} missing self-loop"
                );
            }
        }
    }
}
