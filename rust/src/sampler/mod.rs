//! k-hop neighborhood sampling and the **micrograph** abstraction (§4).
//!
//! A micrograph is the per-root-vertex computation graph: the result of
//! k-hop fanout sampling from a single mini-batch vertex. A *subgraph*
//! (DGL's unit) is the union of the micrographs of a whole mini-batch.
//! The paper's observation (Table 1) is that micrographs have far better
//! feature locality than subgraphs under locality-preserving partitioning,
//! and HopGNN exploits this by training each micrograph entirely on its
//! root's home server.

pub mod layerwise;
pub mod nodewise;

use crate::graph::CsrGraph;
use crate::partition::Partition;
use crate::util::rng::Rng;
use crate::util::fxhash::FxHashMap;

/// Per-root computation graph from k-hop sampling.
///
/// `vertices[0]` is always the root. `depth[i]` is the hop at which vertex
/// `i` was discovered (root = 0). `edges` holds `(dst_local, src_local)`
/// pairs; each vertex with `depth < layers` carries one sampled neighbor
/// set (plus a self-loop), reused at every model layer it participates in
/// (see `fill_dense_adj`).
#[derive(Clone, Debug)]
pub struct Micrograph {
    pub root: u32,
    pub vertices: Vec<u32>,
    pub depth: Vec<u8>,
    pub edges: Vec<(u32, u32)>,
    pub layers: usize,
}

impl Micrograph {
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// Fraction of non-root vertices co-located with the root — the
    /// R_micro metric of Table 1.
    pub fn locality(&self, partition: &Partition) -> f64 {
        if self.vertices.len() <= 1 {
            return 1.0;
        }
        let home = partition.home(self.root);
        let co = self.vertices[1..]
            .iter()
            .filter(|&&v| partition.home(v) == home)
            .count();
        co as f64 / (self.vertices.len() - 1) as f64
    }

    /// Vertices whose features live on `server`.
    pub fn vertices_on<'a>(
        &'a self,
        partition: &'a Partition,
        server: u32,
    ) -> impl Iterator<Item = u32> + 'a {
        self.vertices
            .iter()
            .copied()
            .filter(move |&v| partition.home(v) == server)
    }

    /// Fill a dense per-layer 0/1 adjacency tensor `[layers, vmax, vmax]`
    /// (row-major, already zeroed) — the exact ABI of the AOT artifacts:
    /// model layer `l` uses edges whose destination depth `<= layers-1-l`,
    /// so a vertex discovered at depth d has correct embeddings from layer
    /// 0 through layer `layers-1-d` — in particular the root at the final
    /// layer.
    pub fn fill_dense_adj(&self, vmax: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.layers * vmax * vmax);
        for &(dst, src) in &self.edges {
            let (d, s) = (dst as usize, src as usize);
            if d >= vmax || s >= vmax {
                continue; // truncated by padding cap
            }
            if self.depth[d] as usize >= self.layers {
                continue; // leaf: features only, no aggregation row
            }
            let max_layer = self.layers - 1 - self.depth[d] as usize;
            for l in 0..=max_layer {
                out[l * vmax * vmax + d * vmax + s] = 1.0;
            }
        }
    }
}

/// Sampling algorithm selector (Table 1 compares node-wise vs layer-wise).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    NodeWise,
    LayerWise,
}

impl SamplerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "nodewise" | "node" => Some(Self::NodeWise),
            "layerwise" | "layer" => Some(Self::LayerWise),
            _ => None,
        }
    }
}

/// Shared sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct SampleConfig {
    pub layers: usize,
    pub fanout: usize,
    /// Hard cap on vertices per micrograph (the AOT artifact's VMAX).
    pub vmax: usize,
    pub kind: SamplerKind,
}

pub fn sample_micrograph(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Micrograph {
    match cfg.kind {
        SamplerKind::NodeWise => nodewise::sample(graph, root, cfg, rng),
        SamplerKind::LayerWise => layerwise::sample(graph, root, cfg, rng),
    }
}

/// Union of a mini-batch's micrographs: the model-centric (DGL) unit.
pub struct Subgraph {
    /// Unique global vertex ids across all member micrographs.
    pub vertices: Vec<u32>,
    pub roots: Vec<u32>,
}

impl Subgraph {
    pub fn union_of(micrographs: &[Micrograph]) -> Self {
        let mut seen: FxHashMap<u32, ()> = FxHashMap::default();
        let mut vertices = Vec::new();
        let mut roots = Vec::with_capacity(micrographs.len());
        for mg in micrographs {
            roots.push(mg.root);
            for &v in &mg.vertices {
                if seen.insert(v, ()).is_none() {
                    vertices.push(v);
                }
            }
        }
        Self { vertices, roots }
    }

    /// Mean subgraph locality R_sub (Table 1): for each root, the fraction
    /// of the subgraph's non-root vertices co-located with that root.
    pub fn locality(&self, partition: &Partition) -> f64 {
        if self.roots.is_empty() || self.vertices.len() <= 1 {
            return 1.0;
        }
        let mut per_part = vec![0usize; partition.num_parts];
        for &v in &self.vertices {
            per_part[partition.home(v) as usize] += 1;
        }
        let mut acc = 0.0;
        for &r in &self.roots {
            let home = partition.home(r) as usize;
            // co-located vertices excluding the root itself
            acc += (per_part[home] - 1) as f64 / (self.vertices.len() - 1) as f64;
        }
        acc / self.roots.len() as f64
    }
}

/// Helper shared by both samplers: local-index interner with a vmax cap.
pub(crate) struct Interner {
    map: FxHashMap<u32, u32>,
    pub vertices: Vec<u32>,
    pub depth: Vec<u8>,
    cap: usize,
}

impl Interner {
    pub fn new(root: u32, cap: usize) -> Self {
        let mut map = FxHashMap::default();
        map.insert(root, 0);
        Self {
            map,
            vertices: vec![root],
            depth: vec![0],
            cap,
        }
    }

    /// Intern `v` at `depth`; returns local index, or None if the cap is
    /// reached and `v` is new.
    pub fn intern(&mut self, v: u32, depth: u8) -> Option<u32> {
        if let Some(&i) = self.map.get(&v) {
            return Some(i);
        }
        if self.vertices.len() >= self.cap {
            return None;
        }
        let i = self.vertices.len() as u32;
        self.map.insert(v, i);
        self.vertices.push(v);
        self.depth.push(depth);
        Some(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};
    use crate::partition::{partition, PartitionAlgo};
    use crate::util::prop;

    fn setup() -> (CsrGraph, Partition) {
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 2000,
            num_edges: 16_000,
            num_communities: 16,
            seed: 21,
            ..Default::default()
        })
        .graph;
        let p = partition(&g, 4, PartitionAlgo::MetisLike, 3);
        (g, p)
    }

    #[test]
    fn micrograph_root_is_vertex_zero() {
        let (g, _) = setup();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 4,
            vmax: 64,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(1);
        let mg = sample_micrograph(&g, 77, &cfg, &mut rng);
        assert_eq!(mg.vertices[0], 77);
        assert_eq!(mg.depth[0], 0);
    }

    #[test]
    fn micrograph_locality_beats_subgraph_locality() {
        // The paper's Table 1 claim, on our synthetic data.
        let (g, p) = setup();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 10,
            vmax: 128,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(2);
        let mut mgs = Vec::new();
        for i in 0..64 {
            mgs.push(sample_micrograph(&g, (i * 31) % 2000, &cfg, &mut rng));
        }
        let r_micro: f64 =
            mgs.iter().map(|m| m.locality(&p)).sum::<f64>() / mgs.len() as f64;
        let sub = Subgraph::union_of(&mgs);
        let r_sub = sub.locality(&p);
        assert!(
            r_micro > r_sub * 1.5,
            "R_micro {r_micro} should beat R_sub {r_sub}"
        );
    }

    #[test]
    fn dense_adj_fill_layer_semantics() {
        // hand-built micrograph: root 0 -(hop1)-> 1 -(hop2)-> 2, layers=2
        let mg = Micrograph {
            root: 10,
            vertices: vec![10, 11, 12],
            depth: vec![0, 1, 2],
            edges: vec![(0, 0), (0, 1), (1, 1), (1, 2)],
            layers: 2,
        };
        let vmax = 4;
        let mut adj = vec![0f32; 2 * vmax * vmax];
        mg.fill_dense_adj(vmax, &mut adj);
        let at = |l: usize, d: usize, s: usize| adj[l * 16 + d * 4 + s];
        // layer 0 (first aggregation): depth<=1 rows active
        assert_eq!(at(0, 0, 1), 1.0);
        assert_eq!(at(0, 1, 2), 1.0);
        // layer 1 (final): only depth<=0 rows active
        assert_eq!(at(1, 0, 1), 1.0);
        assert_eq!(at(1, 1, 2), 0.0, "deep row must be inactive at layer 1");
        // self loops
        assert_eq!(at(0, 0, 0), 1.0);
        assert_eq!(at(0, 1, 1), 1.0);
    }

    #[test]
    fn prop_subgraph_vertices_superset_of_micrographs() {
        let (g, _) = setup();
        prop::check(
            "subgraph-union",
            16,
            |r| (r.range(1, 20), r.next_u64()),
            |&(nroots, seed)| {
                let cfg = SampleConfig {
                    layers: 2,
                    fanout: 5,
                    vmax: 64,
                    kind: SamplerKind::NodeWise,
                };
                let mut rng = Rng::new(seed);
                let mgs: Vec<Micrograph> = (0..nroots)
                    .map(|_| {
                        sample_micrograph(
                            &g,
                            rng.below(2000) as u32,
                            &cfg,
                            &mut rng,
                        )
                    })
                    .collect();
                let sub = Subgraph::union_of(&mgs);
                // no duplicates
                let mut sorted = sub.vertices.clone();
                sorted.sort_unstable();
                let before = sorted.len();
                sorted.dedup();
                if sorted.len() != before {
                    return Err("subgraph has duplicate vertices".into());
                }
                // superset
                for mg in &mgs {
                    for v in &mg.vertices {
                        if !sub.vertices.contains(v) {
                            return Err(format!("vertex {v} missing"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn interner_caps() {
        let mut it = Interner::new(5, 3);
        assert_eq!(it.intern(5, 0), Some(0));
        assert_eq!(it.intern(6, 1), Some(1));
        assert_eq!(it.intern(7, 1), Some(2));
        assert_eq!(it.intern(8, 1), None); // cap
        assert_eq!(it.intern(6, 2), Some(1)); // existing still resolves
    }
}
