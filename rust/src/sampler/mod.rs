//! k-hop neighborhood sampling and the **micrograph** abstraction (§4).
//!
//! A micrograph is the per-root-vertex computation graph: the result of
//! k-hop fanout sampling from a single mini-batch vertex. A *subgraph*
//! (DGL's unit) is the union of the micrographs of a whole mini-batch.
//! The paper's observation (Table 1) is that micrographs have far better
//! feature locality than subgraphs under locality-preserving partitioning,
//! and HopGNN exploits this by training each micrograph entirely on its
//! root's home server.

pub mod layerwise;
pub mod nodewise;

use crate::graph::CsrGraph;
use crate::partition::Partition;
use crate::util::fxhash::FxHashSet;
use crate::util::rng::Rng;
use crate::util::stamp::StampedMap;

/// Per-root computation graph from k-hop sampling.
///
/// `vertices[0]` is always the root. `depth[i]` is the hop at which vertex
/// `i` was discovered (root = 0). `edges` holds `(dst_local, src_local)`
/// pairs; each vertex with `depth < layers` carries one sampled neighbor
/// set (plus a self-loop), reused at every model layer it participates in
/// (see `fill_dense_adj`).
#[derive(Clone, Debug)]
pub struct Micrograph {
    pub root: u32,
    pub vertices: Vec<u32>,
    pub depth: Vec<u8>,
    pub edges: Vec<(u32, u32)>,
    pub layers: usize,
}

impl Micrograph {
    pub fn num_vertices(&self) -> usize {
        self.vertices.len()
    }

    /// Fraction of non-root vertices co-located with the root — the
    /// R_micro metric of Table 1.
    pub fn locality(&self, partition: &Partition) -> f64 {
        if self.vertices.len() <= 1 {
            return 1.0;
        }
        let home = partition.home(self.root);
        let co = self.vertices[1..]
            .iter()
            .filter(|&&v| partition.home(v) == home)
            .count();
        co as f64 / (self.vertices.len() - 1) as f64
    }

    /// Vertices whose features live on `server`.
    pub fn vertices_on<'a>(
        &'a self,
        partition: &'a Partition,
        server: u32,
    ) -> impl Iterator<Item = u32> + 'a {
        self.vertices
            .iter()
            .copied()
            .filter(move |&v| partition.home(v) == server)
    }

    /// Fill a dense per-layer 0/1 adjacency tensor `[layers, vmax, vmax]`
    /// (row-major, already zeroed) — the exact ABI of the AOT artifacts:
    /// model layer `l` uses edges whose destination depth `<= layers-1-l`,
    /// so a vertex discovered at depth d has correct embeddings from layer
    /// 0 through layer `layers-1-d` — in particular the root at the final
    /// layer.
    pub fn fill_dense_adj(&self, vmax: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.layers * vmax * vmax);
        for &(dst, src) in &self.edges {
            let (d, s) = (dst as usize, src as usize);
            if d >= vmax || s >= vmax {
                continue; // truncated by padding cap
            }
            if self.depth[d] as usize >= self.layers {
                continue; // leaf: features only, no aggregation row
            }
            let max_layer = self.layers - 1 - self.depth[d] as usize;
            for l in 0..=max_layer {
                out[l * vmax * vmax + d * vmax + s] = 1.0;
            }
        }
    }
}

/// Sampling algorithm selector (Table 1 compares node-wise vs layer-wise).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    NodeWise,
    LayerWise,
}

impl SamplerKind {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "nodewise" | "node" => Some(Self::NodeWise),
            "layerwise" | "layer" => Some(Self::LayerWise),
            _ => None,
        }
    }
}

/// Shared sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct SampleConfig {
    pub layers: usize,
    pub fanout: usize,
    /// Hard cap on vertices per micrograph (the AOT artifact's VMAX).
    pub vmax: usize,
    pub kind: SamplerKind,
}

pub fn sample_micrograph(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Micrograph {
    match cfg.kind {
        SamplerKind::NodeWise => nodewise::sample(graph, root, cfg, rng),
        SamplerKind::LayerWise => layerwise::sample(graph, root, cfg, rng),
    }
}

/// Reusable sampler scratch state: the interner table plus every
/// working buffer either sampler needs, cleared in O(used) and reused
/// across all roots, iterations, and epochs.
///
/// The interner map is generation-stamped
/// ([`crate::util::stamp::StampedMap`]), so "clearing" it is a counter
/// bump and its storage is bounded by the set of vertices ever touched;
/// the vectors keep their high-water capacity. One `SampleScratch`
/// driven through [`sample_micrograph_into`] / [`sample_batch_into`]
/// therefore samples arbitrarily many micrographs with zero
/// steady-state heap allocation (asserted by `tests/alloc_budget.rs`),
/// where the legacy [`sample_micrograph`] path allocated a fresh
/// interner map and four vectors per root. Both paths share one
/// sampler implementation, so they are draw-for-draw and
/// vertex-for-vertex identical.
#[derive(Default)]
pub struct SampleScratch {
    /// global vertex id -> local index for the current micrograph
    pub(crate) map: StampedMap,
    /// interned global vertex ids (`vertices[0]` is the root)
    pub(crate) vertices: Vec<u32>,
    /// discovery hop per interned vertex
    pub(crate) depth: Vec<u8>,
    /// `(dst_local, src_local)` sampled edges incl. self-loops
    pub(crate) edges: Vec<(u32, u32)>,
    /// current / next BFS frontier (local indices)
    pub(crate) frontier: Vec<u32>,
    pub(crate) next_frontier: Vec<u32>,
    /// layer-wise candidate pool and chosen globals
    pub(crate) pool: Vec<u32>,
    pub(crate) chosen: Vec<u32>,
    /// `sample_distinct_into` output buffer
    pub(crate) picks: Vec<usize>,
}

impl SampleScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Prepare for a fresh micrograph rooted at `root`.
    pub(crate) fn reset(&mut self, root: u32) {
        self.map.reset();
        self.vertices.clear();
        self.depth.clear();
        self.edges.clear();
        self.frontier.clear();
        self.next_frontier.clear();
        self.map.insert(root, 0);
        self.vertices.push(root);
        self.depth.push(0);
    }

    /// Vertices of the most recently sampled micrograph.
    pub fn vertices(&self) -> &[u32] {
        &self.vertices
    }

    /// Sampled edge count (incl. self-loops) of the most recent
    /// micrograph.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// Move the buffers out as an owned [`Micrograph`] (the legacy
    /// single-shot path; leaves the scratch empty but warm).
    fn take_micrograph(&mut self, root: u32, layers: usize) -> Micrograph {
        Micrograph {
            root,
            vertices: std::mem::take(&mut self.vertices),
            depth: std::mem::take(&mut self.depth),
            edges: std::mem::take(&mut self.edges),
            layers,
        }
    }
}

/// Interner step shared by both samplers, operating on split scratch
/// fields: resolve `v` to its local index, interning it at `depth` if
/// new, or `None` once the `cap` (vmax) is reached.
#[inline]
pub(crate) fn intern(
    map: &mut StampedMap,
    vertices: &mut Vec<u32>,
    depths: &mut Vec<u8>,
    v: u32,
    depth: u8,
    cap: usize,
) -> Option<u32> {
    if let Some(i) = map.get(v) {
        return Some(i);
    }
    if vertices.len() >= cap {
        return None;
    }
    let i = vertices.len() as u32;
    map.insert(v, i);
    vertices.push(v);
    depths.push(depth);
    Some(i)
}

/// Sample one micrograph into `scratch` (no allocation once the scratch
/// is warm). The result is readable through the scratch accessors until
/// the next call.
pub fn sample_micrograph_into(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    match cfg.kind {
        SamplerKind::NodeWise => {
            nodewise::sample_into(graph, root, cfg, rng, scratch)
        }
        SamplerKind::LayerWise => {
            layerwise::sample_into(graph, root, cfg, rng, scratch)
        }
    }
}

/// Totals for a batch of micrographs sampled through
/// [`sample_batch_into`] — exactly the quantities the strategy
/// schedule builders consume (`Op::Sample` / `Op::Compute` operands).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SampleStats {
    /// Summed vertex count across the batch's micrographs.
    pub vertices: u64,
    /// Summed sampled-edge count (incl. self-loops).
    pub edges: u64,
    /// Summed count of non-leaf vertices (`depth < layers`).
    pub nonleaf: u64,
}

impl SampleStats {
    pub fn add(&mut self, other: SampleStats) {
        self.vertices += other.vertices;
        self.edges += other.edges;
        self.nonleaf += other.nonleaf;
    }
}

/// Sample a batch of roots through one scratch, appending each
/// micrograph's vertices (in draw order) to `verts` and returning the
/// batch totals. This is the strategies' hot path: the concatenated
/// vertex list is byte-identical to flattening the equivalent
/// `Vec<Micrograph>`, with zero steady-state allocation beyond growth
/// of the caller's `verts` buffer toward its high-water mark.
pub fn sample_batch_into(
    graph: &CsrGraph,
    roots: &[u32],
    cfg: &SampleConfig,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
    verts: &mut Vec<u32>,
) -> SampleStats {
    let mut stats = SampleStats::default();
    for &root in roots {
        sample_micrograph_into(graph, root, cfg, rng, scratch);
        verts.extend_from_slice(&scratch.vertices);
        stats.vertices += scratch.vertices.len() as u64;
        stats.edges += scratch.edges.len() as u64;
        stats.nonleaf += scratch
            .depth
            .iter()
            .filter(|&&d| (d as usize) < cfg.layers)
            .count() as u64;
    }
    stats
}

/// Union of a mini-batch's micrographs: the model-centric (DGL) unit.
pub struct Subgraph {
    /// Unique global vertex ids across all member micrographs.
    pub vertices: Vec<u32>,
    pub roots: Vec<u32>,
}

impl Subgraph {
    pub fn union_of(micrographs: &[Micrograph]) -> Self {
        let mut seen: FxHashSet<u32> = FxHashSet::default();
        let mut vertices = Vec::new();
        let mut roots = Vec::with_capacity(micrographs.len());
        for mg in micrographs {
            roots.push(mg.root);
            for &v in &mg.vertices {
                if seen.insert(v) {
                    vertices.push(v);
                }
            }
        }
        Self { vertices, roots }
    }

    /// Mean subgraph locality R_sub (Table 1): for each root, the fraction
    /// of the subgraph's non-root vertices co-located with that root.
    pub fn locality(&self, partition: &Partition) -> f64 {
        if self.roots.is_empty() || self.vertices.len() <= 1 {
            return 1.0;
        }
        let mut per_part = vec![0usize; partition.num_parts];
        for &v in &self.vertices {
            per_part[partition.home(v) as usize] += 1;
        }
        let mut acc = 0.0;
        for &r in &self.roots {
            let home = partition.home(r) as usize;
            // co-located vertices excluding the root itself
            acc += (per_part[home] - 1) as f64 / (self.vertices.len() - 1) as f64;
        }
        acc / self.roots.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};
    use crate::partition::{partition, PartitionAlgo};
    use crate::util::prop;

    fn setup() -> (CsrGraph, Partition) {
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 2000,
            num_edges: 16_000,
            num_communities: 16,
            seed: 21,
            ..Default::default()
        })
        .graph;
        let p = partition(&g, 4, PartitionAlgo::MetisLike, 3);
        (g, p)
    }

    #[test]
    fn micrograph_root_is_vertex_zero() {
        let (g, _) = setup();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 4,
            vmax: 64,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(1);
        let mg = sample_micrograph(&g, 77, &cfg, &mut rng);
        assert_eq!(mg.vertices[0], 77);
        assert_eq!(mg.depth[0], 0);
    }

    #[test]
    fn micrograph_locality_beats_subgraph_locality() {
        // The paper's Table 1 claim, on our synthetic data.
        let (g, p) = setup();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 10,
            vmax: 128,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(2);
        let mut mgs = Vec::new();
        for i in 0..64 {
            mgs.push(sample_micrograph(&g, (i * 31) % 2000, &cfg, &mut rng));
        }
        let r_micro: f64 =
            mgs.iter().map(|m| m.locality(&p)).sum::<f64>() / mgs.len() as f64;
        let sub = Subgraph::union_of(&mgs);
        let r_sub = sub.locality(&p);
        assert!(
            r_micro > r_sub * 1.5,
            "R_micro {r_micro} should beat R_sub {r_sub}"
        );
    }

    #[test]
    fn dense_adj_fill_layer_semantics() {
        // hand-built micrograph: root 0 -(hop1)-> 1 -(hop2)-> 2, layers=2
        let mg = Micrograph {
            root: 10,
            vertices: vec![10, 11, 12],
            depth: vec![0, 1, 2],
            edges: vec![(0, 0), (0, 1), (1, 1), (1, 2)],
            layers: 2,
        };
        let vmax = 4;
        let mut adj = vec![0f32; 2 * vmax * vmax];
        mg.fill_dense_adj(vmax, &mut adj);
        let at = |l: usize, d: usize, s: usize| adj[l * 16 + d * 4 + s];
        // layer 0 (first aggregation): depth<=1 rows active
        assert_eq!(at(0, 0, 1), 1.0);
        assert_eq!(at(0, 1, 2), 1.0);
        // layer 1 (final): only depth<=0 rows active
        assert_eq!(at(1, 0, 1), 1.0);
        assert_eq!(at(1, 1, 2), 0.0, "deep row must be inactive at layer 1");
        // self loops
        assert_eq!(at(0, 0, 0), 1.0);
        assert_eq!(at(0, 1, 1), 1.0);
    }

    #[test]
    fn prop_subgraph_vertices_superset_of_micrographs() {
        let (g, _) = setup();
        prop::check(
            "subgraph-union",
            16,
            |r| (r.range(1, 20), r.next_u64()),
            |&(nroots, seed)| {
                let cfg = SampleConfig {
                    layers: 2,
                    fanout: 5,
                    vmax: 64,
                    kind: SamplerKind::NodeWise,
                };
                let mut rng = Rng::new(seed);
                let mgs: Vec<Micrograph> = (0..nroots)
                    .map(|_| {
                        sample_micrograph(
                            &g,
                            rng.below(2000) as u32,
                            &cfg,
                            &mut rng,
                        )
                    })
                    .collect();
                let sub = Subgraph::union_of(&mgs);
                // no duplicates
                let mut sorted = sub.vertices.clone();
                sorted.sort_unstable();
                let before = sorted.len();
                sorted.dedup();
                if sorted.len() != before {
                    return Err("subgraph has duplicate vertices".into());
                }
                // superset
                for mg in &mgs {
                    for v in &mg.vertices {
                        if !sub.vertices.contains(v) {
                            return Err(format!("vertex {v} missing"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn interner_caps() {
        let mut s = SampleScratch::new();
        s.reset(5);
        let SampleScratch {
            map,
            vertices,
            depth,
            ..
        } = &mut s;
        assert_eq!(intern(map, vertices, depth, 5, 0, 3), Some(0));
        assert_eq!(intern(map, vertices, depth, 6, 1, 3), Some(1));
        assert_eq!(intern(map, vertices, depth, 7, 1, 3), Some(2));
        assert_eq!(intern(map, vertices, depth, 8, 1, 3), None); // cap
        // existing still resolves
        assert_eq!(intern(map, vertices, depth, 6, 2, 3), Some(1));
    }

    #[test]
    fn scratch_sampling_matches_legacy_bit_for_bit() {
        // One warm scratch reused across roots must reproduce the
        // allocating path exactly: same vertices/depth/edges, same RNG
        // trajectory.
        let (g, _) = setup();
        for kind in [SamplerKind::NodeWise, SamplerKind::LayerWise] {
            let cfg = SampleConfig {
                layers: 3,
                fanout: 6,
                vmax: 96,
                kind,
            };
            let mut ra = Rng::new(31);
            let mut rb = Rng::new(31);
            let mut scratch = SampleScratch::new();
            for i in 0..32u32 {
                let root = (i * 61) % 2000;
                let mg = sample_micrograph(&g, root, &cfg, &mut ra);
                sample_micrograph_into(&g, root, &cfg, &mut rb, &mut scratch);
                assert_eq!(mg.vertices, scratch.vertices, "{kind:?} root {root}");
                assert_eq!(mg.depth, scratch.depth, "{kind:?} root {root}");
                assert_eq!(mg.edges, scratch.edges, "{kind:?} root {root}");
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "{kind:?} stream diverged");
        }
    }

    #[test]
    fn sample_batch_into_matches_flattened_micrographs() {
        let (g, _) = setup();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 5,
            vmax: 64,
            kind: SamplerKind::NodeWise,
        };
        let roots: Vec<u32> = (0..24).map(|i| (i * 83) % 2000).collect();
        let mut ra = Rng::new(8);
        let mut rb = Rng::new(8);
        let mgs: Vec<Micrograph> = roots
            .iter()
            .map(|&r| sample_micrograph(&g, r, &cfg, &mut ra))
            .collect();
        let mut scratch = SampleScratch::new();
        let mut verts = vec![999u32; 3]; // stale content is caller-owned
        verts.clear();
        let stats =
            sample_batch_into(&g, &roots, &cfg, &mut rb, &mut scratch, &mut verts);
        let flat: Vec<u32> =
            mgs.iter().flat_map(|m| m.vertices.iter().copied()).collect();
        assert_eq!(verts, flat);
        assert_eq!(stats.vertices, flat.len() as u64);
        assert_eq!(
            stats.edges,
            mgs.iter().map(|m| m.edges.len() as u64).sum::<u64>()
        );
        let nonleaf: u64 = mgs
            .iter()
            .flat_map(|m| m.depth.iter())
            .filter(|&&d| (d as usize) < cfg.layers)
            .count() as u64;
        assert_eq!(stats.nonleaf, nonleaf);
    }
}
