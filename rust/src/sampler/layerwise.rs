//! Layer-wise (FastGCN-style) sampling: each hop draws a fixed budget of
//! vertices from the *union* of the frontier's neighborhoods, instead of
//! per-vertex fanouts. Destinations then connect to whichever sampled
//! vertices are their neighbors. Compared to node-wise sampling this
//! spreads the sample across the graph, which is exactly why Table 1
//! shows weaker micrograph locality for it at scale.

use super::{intern, Micrograph, SampleConfig, SampleScratch};
use crate::graph::CsrGraph;
use crate::util::rng::Rng;

pub fn sample(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
) -> Micrograph {
    let mut scratch = SampleScratch::new();
    sample_into(graph, root, cfg, rng, &mut scratch);
    scratch.take_micrograph(root, cfg.layers)
}

/// Scratch-based implementation: identical draw order and output to the
/// historical allocating version (`sample` is now a thin wrapper).
pub fn sample_into(
    graph: &CsrGraph,
    root: u32,
    cfg: &SampleConfig,
    rng: &mut Rng,
    scratch: &mut SampleScratch,
) {
    scratch.reset(root);
    let SampleScratch {
        map,
        vertices,
        depth: depths,
        edges,
        frontier,
        next_frontier,
        pool,
        chosen,
        picks,
    } = scratch;
    edges.push((0, 0));
    frontier.push(0);

    for depth in 0..cfg.layers as u8 {
        // candidate pool: union of all frontier neighborhoods
        pool.clear();
        for &dst_local in frontier.iter() {
            let dst_global = vertices[dst_local as usize];
            pool.extend_from_slice(graph.neighbors(dst_global));
        }
        pool.sort_unstable();
        pool.dedup();
        if pool.is_empty() {
            break;
        }
        // budget: same expected size as node-wise at this hop
        let budget = (cfg.fanout * frontier.len()).min(pool.len());
        rng.sample_distinct_into(pool.len(), budget, picks);
        chosen.clear();
        chosen.extend(picks.iter().map(|&i| pool[i]));

        next_frontier.clear();
        for &dst_local in frontier.iter() {
            let dst_global = vertices[dst_local as usize];
            let neigh = graph.neighbors(dst_global);
            for &src_global in chosen.iter() {
                // membership test via binary search (neighbors sorted)
                if neigh.binary_search(&src_global).is_ok() {
                    if let Some(src_local) = intern(
                        map,
                        vertices,
                        depths,
                        src_global,
                        depth + 1,
                        cfg.vmax,
                    ) {
                        edges.push((dst_local, src_local));
                        if src_local as usize == vertices.len() - 1
                            && (depth + 1) < cfg.layers as u8
                        {
                            next_frontier.push(src_local);
                            edges.push((src_local, src_local));
                        }
                    }
                }
            }
        }
        std::mem::swap(frontier, next_frontier);
        if frontier.is_empty() {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};
    use crate::sampler::SamplerKind;

    fn graph() -> CsrGraph {
        community_graph(&CommunityGraphSpec {
            num_vertices: 1000,
            num_edges: 9000,
            num_communities: 10,
            seed: 41,
            ..Default::default()
        })
        .graph
    }

    #[test]
    fn produces_connected_sample() {
        let g = graph();
        let cfg = SampleConfig {
            layers: 2,
            fanout: 4,
            vmax: 128,
            kind: SamplerKind::LayerWise,
        };
        let mut rng = Rng::new(1);
        let mg = sample(&g, 11, &cfg, &mut rng);
        assert_eq!(mg.vertices[0], 11);
        // all edges reference interned vertices
        for &(d, s) in &mg.edges {
            assert!((d as usize) < mg.num_vertices());
            assert!((s as usize) < mg.num_vertices());
        }
        // edges connect true graph neighbors (besides self-loops)
        for &(d, s) in &mg.edges {
            if d != s {
                let dg = mg.vertices[d as usize];
                let sg = mg.vertices[s as usize];
                assert!(g.neighbors(dg).contains(&sg), "({dg},{sg}) not an edge");
            }
        }
    }

    #[test]
    fn respects_vmax() {
        let g = graph();
        let cfg = SampleConfig {
            layers: 3,
            fanout: 10,
            vmax: 40,
            kind: SamplerKind::LayerWise,
        };
        let mut rng = Rng::new(2);
        let mg = sample(&g, 5, &cfg, &mut rng);
        assert!(mg.num_vertices() <= 40);
    }

    #[test]
    fn spreads_more_than_nodewise() {
        // layer-wise picks from the union pool, so across many samples it
        // should touch at least as many distinct vertices as node-wise
        let g = graph();
        let mut rng = Rng::new(3);
        let mut lw = std::collections::HashSet::new();
        let mut nw = std::collections::HashSet::new();
        for i in 0..50u32 {
            let c_lw = SampleConfig {
                layers: 2,
                fanout: 4,
                vmax: 256,
                kind: SamplerKind::LayerWise,
            };
            let c_nw = SampleConfig {
                kind: SamplerKind::NodeWise,
                ..c_lw
            };
            lw.extend(sample(&g, i * 7, &c_lw, &mut rng).vertices);
            nw.extend(
                crate::sampler::nodewise::sample(&g, i * 7, &c_nw, &mut rng)
                    .vertices,
            );
        }
        assert!(
            lw.len() as f64 > nw.len() as f64 * 0.6,
            "lw {} nw {}",
            lw.len(),
            nw.len()
        );
    }
}
