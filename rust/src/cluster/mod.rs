//! The simulated GPU cluster: per-server virtual clocks, the network
//! cost model with exact byte accounting, and the compute cost model.
//!
//! Substitution note (DESIGN.md §2): the paper's 4×A100 + 10 GbE testbed
//! is replaced by N simulated servers. Coordination logic (who fetches
//! what, when models move) is identical to a real deployment; compute and
//! network *times* come from calibrated cost models, while *byte counts*
//! are exact.

pub mod clock;
pub mod cost;
pub mod network;

pub use clock::Clocks;
pub use cost::{CostModel, ModelFamily, ModelShape};
pub use network::{NetStats, NetworkModel, TransferKind};
