//! The simulated GPU cluster: per-server virtual clocks, the
//! topology-aware fabric with exact byte accounting, and the compute
//! cost model.
//!
//! Substitution note (DESIGN.md §2): the paper's 4×A100 + 10 GbE testbed
//! is replaced by N simulated servers. Coordination logic (who fetches
//! what, when models move) is identical to a real deployment; compute and
//! network *times* come from calibrated cost models, while *byte and
//! message counts* are exact.
//!
//! Layering:
//!
//! * [`network`] — exact per-(src, dst)-link byte/message accounting
//!   ([`NetStats`], validated at the end of every driver session) and
//!   the base scalar rate ([`NetworkModel`]).
//! * [`fabric`] — the topology layer: a [`Fabric`] owns per-link
//!   latency/bandwidth matrices plus per-server compute multipliers,
//!   built from a named [`FabricSpec`] (`uniform`, `rack:<k>`,
//!   `hetero-mix`, `straggler:<s>`). The `uniform` fabric is
//!   bit-identical to the legacy scalar model.
//! * [`cost`] — analytic FLOP counts per GNN layer and the per-server
//!   compute constants ([`CostModel`]); the fabric's compute multiplier
//!   scales these per server in the epoch driver.
//! * [`clock`] — per-server virtual clocks and barriers ([`Clocks`]).

pub mod clock;
pub mod cost;
pub mod fabric;
pub mod network;

pub use clock::Clocks;
pub use cost::{CostModel, ModelFamily, ModelShape};
pub use fabric::{Fabric, FabricSpec};
pub use network::{NetStats, NetworkModel, TransferKind};
