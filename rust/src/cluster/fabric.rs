//! Topology-aware cluster fabric: per-(src, dst)-link latency/bandwidth
//! plus per-server compute-speed multipliers.
//!
//! The paper's testbed is one uniform 10 GbE switch, but HopGNN's core
//! claims — merging that rebalances per-worker load (§5.3), feature-
//! centric transfers beating push-pull — matter *most* on non-uniform
//! clusters: oversubscribed racks, mixed-generation NICs, straggler
//! GPUs. The scalar [`NetworkModel`] cannot express any of those, so the
//! simulator routes every transfer through a [`Fabric`] instead: a full
//! link matrix (`t = latency[src][dst] + bytes / bandwidth[src][dst]`)
//! and a per-server compute multiplier that scales `Op::Compute` time in
//! the epoch driver.
//!
//! Named topologies ([`FabricSpec`], parseable from `--fabric` and the
//! `fabric =` config key):
//!
//! * `uniform` — every link is the base [`NetworkModel`], every server
//!   computes at full speed. **Bit-identical** to the legacy scalar
//!   model (locked by `tests/fabric_parity.rs`): the per-link lookup
//!   performs exactly the same float operations on exactly the same
//!   values.
//! * `rack:<k>` — two-tier oversubscribed topology with `k` racks
//!   (contiguous server ranges). Intra-rack links run at the base rate;
//!   cross-rack links lose [`RACK_OVERSUBSCRIPTION`]× bandwidth and pay
//!   [`RACK_CROSS_LATENCY_FACTOR`]× latency (the extra spine hop).
//! * `hetero-mix` — mixed-generation NICs: the upper half of the
//!   servers has [`SLOW_NIC_FACTOR`]× slower NICs, and a link runs at
//!   the slower endpoint's rate.
//! * `straggler:<s>` — one degraded server: every link touching `s`
//!   loses [`STRAGGLER_LINK_FACTOR`]× bandwidth and doubles latency,
//!   and `s` computes at `1/`[`STRAGGLER_COMPUTE_FACTOR`] speed.
//!
//! All topologies are symmetric (`time(a→b) == time(b→a)`) and strictly
//! positive off the diagonal — property-tested in
//! `tests/fabric_parity.rs`.

use super::network::NetworkModel;
use crate::util::specs;

/// Cross-rack links of a `rack:<k>` fabric run at `base bandwidth / 4`
/// (a classic 4:1 oversubscribed spine).
pub const RACK_OVERSUBSCRIPTION: f64 = 4.0;
/// Cross-rack latency multiplier (the extra switch hop).
pub const RACK_CROSS_LATENCY_FACTOR: f64 = 2.0;
/// Slow-NIC bandwidth divisor for the `hetero-mix` fabric's slow half.
pub const SLOW_NIC_FACTOR: f64 = 4.0;
/// Bandwidth divisor for every link touching a `straggler:<s>` server.
pub const STRAGGLER_LINK_FACTOR: f64 = 4.0;
/// Latency multiplier for every link touching a `straggler:<s>` server.
pub const STRAGGLER_LATENCY_FACTOR: f64 = 2.0;
/// Compute slowdown of a `straggler:<s>` server (speed = 1/this).
pub const STRAGGLER_COMPUTE_FACTOR: f64 = 2.0;

/// Named fabric topology — the config-level description, materialized
/// into a [`Fabric`] once the server count is known.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricSpec {
    /// Every link identical to the base scalar model (legacy behavior).
    Uniform,
    /// Two-tier topology: `racks` racks, oversubscribed spine between.
    Rack { racks: usize },
    /// Fast/slow NIC split: the upper half of the servers is slow.
    HeteroMix,
    /// One slow server: degraded links and half-speed compute.
    Straggler { server: usize },
}

impl FabricSpec {
    /// Parse `uniform`, `rack:<k>`, `hetero-mix`, or `straggler:<s>`.
    pub fn from_str(s: &str) -> Option<Self> {
        Self::parse(s).ok()
    }

    /// [`Self::from_str`] with the shared [`specs`] error style, so
    /// `--fabric` rejections read like `--tiers` and `synth:` ones.
    pub fn parse(s: &str) -> Result<Self, String> {
        if let Some(k) = s.strip_prefix("rack:") {
            let racks =
                specs::parse_count(&format!("fabric spec '{s}'"), k)?;
            if racks < 1 {
                return Err(format!(
                    "fabric spec '{s}': rack count must be >= 1"
                ));
            }
            return Ok(Self::Rack { racks });
        }
        if let Some(sv) = s.strip_prefix("straggler:") {
            let server =
                specs::parse_count(&format!("fabric spec '{s}'"), sv)?;
            return Ok(Self::Straggler { server });
        }
        match s {
            "uniform" => Ok(Self::Uniform),
            "hetero-mix" | "hetero" => Ok(Self::HeteroMix),
            _ => Err(specs::unknown_spec(
                "fabric",
                s,
                &["uniform", "rack:<k>", "hetero-mix", "straggler:<s>"],
            )),
        }
    }

    /// Canonical spelling (round-trips through [`Self::from_str`]).
    pub fn name(&self) -> String {
        match self {
            Self::Uniform => "uniform".to_string(),
            Self::Rack { racks } => format!("rack:{racks}"),
            Self::HeteroMix => "hetero-mix".to_string(),
            Self::Straggler { server } => format!("straggler:{server}"),
        }
    }

    /// Config-level validation for values that only make sense once the
    /// server count is known (CLI/config front ends call this to reject
    /// bad input gracefully; [`Self::build`] asserts the same bound).
    pub fn validate(&self, num_servers: usize) -> Result<(), String> {
        if let Self::Straggler { server } = self {
            if *server >= num_servers {
                return Err(format!(
                    "straggler server {server} out of range (servers: \
                     {num_servers})"
                ));
            }
        }
        Ok(())
    }

    /// Materialize the topology for `num_servers` servers over the base
    /// scalar model.
    pub fn build(&self, num_servers: usize, base: NetworkModel) -> Fabric {
        match *self {
            Self::Uniform => Fabric::uniform(num_servers, base),
            Self::Rack { racks } => Fabric::rack(num_servers, base, racks),
            Self::HeteroMix => Fabric::hetero_mix(num_servers, base),
            Self::Straggler { server } => {
                Fabric::straggler(num_servers, base, server)
            }
        }
    }
}

/// Which rack hosts `server` under a `rack:<k>` fabric: contiguous
/// ranges, as evenly sized as integer division allows. Widened
/// arithmetic keeps absurd user-supplied rack counts from overflowing.
pub fn rack_of(server: usize, num_servers: usize, racks: usize) -> usize {
    (server as u128 * racks as u128 / num_servers as u128) as usize
}

/// The materialized cluster fabric: full per-link cost matrices plus
/// per-server compute-speed multipliers. All transfer times in the
/// simulator derive from [`Self::transfer_time`]; all compute times are
/// divided by [`Self::compute_speed`] in the epoch driver's lane
/// executor.
#[derive(Clone, Debug)]
pub struct Fabric {
    num_servers: usize,
    /// latency[src * n + dst], seconds.
    latency: Vec<f64>,
    /// bandwidth[src * n + dst], bytes/second.
    bandwidth: Vec<f64>,
    /// Per-server compute-speed multiplier (1.0 = baseline).
    compute: Vec<f64>,
    spec: FabricSpec,
}

impl Fabric {
    fn filled(num_servers: usize, base: NetworkModel, spec: FabricSpec) -> Self {
        let nn = num_servers * num_servers;
        Self {
            num_servers,
            latency: vec![base.latency; nn],
            bandwidth: vec![base.bandwidth; nn],
            compute: vec![1.0; num_servers],
            spec,
        }
    }

    fn set_link(&mut self, src: usize, dst: usize, lat: f64, bw: f64) {
        let i = src * self.num_servers + dst;
        self.latency[i] = lat;
        self.bandwidth[i] = bw;
    }

    /// Every link = the base scalar model (bit-identical to it).
    pub fn uniform(num_servers: usize, base: NetworkModel) -> Self {
        Self::filled(num_servers, base, FabricSpec::Uniform)
    }

    /// Two-tier oversubscribed topology with `racks` racks.
    pub fn rack(num_servers: usize, base: NetworkModel, racks: usize) -> Self {
        assert!(racks >= 1, "rack fabric needs at least one rack");
        let mut f =
            Self::filled(num_servers, base, FabricSpec::Rack { racks });
        for src in 0..num_servers {
            for dst in 0..num_servers {
                if src == dst {
                    continue;
                }
                let cross = rack_of(src, num_servers, racks)
                    != rack_of(dst, num_servers, racks);
                if cross {
                    f.set_link(
                        src,
                        dst,
                        base.latency * RACK_CROSS_LATENCY_FACTOR,
                        base.bandwidth / RACK_OVERSUBSCRIPTION,
                    );
                }
            }
        }
        f
    }

    /// Mixed-generation NICs: the upper half of the servers runs
    /// [`SLOW_NIC_FACTOR`]× slower; a link runs at its slower endpoint.
    pub fn hetero_mix(num_servers: usize, base: NetworkModel) -> Self {
        let nic = |s: usize| -> f64 {
            // slow half: s >= ceil(n/2)
            if s >= num_servers - num_servers / 2 {
                SLOW_NIC_FACTOR
            } else {
                1.0
            }
        };
        let mut f = Self::filled(num_servers, base, FabricSpec::HeteroMix);
        for src in 0..num_servers {
            for dst in 0..num_servers {
                if src == dst {
                    continue;
                }
                let factor = nic(src).max(nic(dst));
                if factor > 1.0 {
                    f.set_link(
                        src,
                        dst,
                        base.latency,
                        base.bandwidth / factor,
                    );
                }
            }
        }
        f
    }

    /// One degraded server: slow links on everything touching it, and
    /// half-speed compute.
    pub fn straggler(
        num_servers: usize,
        base: NetworkModel,
        server: usize,
    ) -> Self {
        assert!(
            server < num_servers,
            "straggler server {server} out of range (servers: {num_servers})"
        );
        let mut f = Self::filled(
            num_servers,
            base,
            FabricSpec::Straggler { server },
        );
        for peer in 0..num_servers {
            if peer == server {
                continue;
            }
            let lat = base.latency * STRAGGLER_LATENCY_FACTOR;
            let bw = base.bandwidth / STRAGGLER_LINK_FACTOR;
            f.set_link(server, peer, lat, bw);
            f.set_link(peer, server, lat, bw);
        }
        f.compute[server] = 1.0 / STRAGGLER_COMPUTE_FACTOR;
        f
    }

    pub fn num_servers(&self) -> usize {
        self.num_servers
    }

    pub fn spec(&self) -> FabricSpec {
        self.spec
    }

    pub fn name(&self) -> String {
        self.spec.name()
    }

    pub fn is_uniform(&self) -> bool {
        matches!(self.spec, FabricSpec::Uniform)
    }

    /// Linear per-link time model:
    /// `t = latency[src][dst] + bytes / bandwidth[src][dst]`.
    #[inline]
    pub fn transfer_time(&self, src: usize, dst: usize, bytes: u64) -> f64 {
        let i = src * self.num_servers + dst;
        self.latency[i] + bytes as f64 / self.bandwidth[i]
    }

    pub fn link_latency(&self, src: usize, dst: usize) -> f64 {
        self.latency[src * self.num_servers + dst]
    }

    pub fn link_bandwidth(&self, src: usize, dst: usize) -> f64 {
        self.bandwidth[src * self.num_servers + dst]
    }

    /// Compute-speed multiplier of `server` (1.0 = baseline; the epoch
    /// driver divides every compute op's seconds by this).
    #[inline]
    pub fn compute_speed(&self, server: usize) -> f64 {
        self.compute[server]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing_roundtrips() {
        for s in ["uniform", "rack:2", "rack:3", "hetero-mix", "straggler:0"]
        {
            let spec = FabricSpec::from_str(s).unwrap();
            assert_eq!(spec.name(), s, "canonical spelling must roundtrip");
        }
        assert_eq!(
            FabricSpec::from_str("hetero"),
            Some(FabricSpec::HeteroMix)
        );
        assert_eq!(FabricSpec::from_str("rack:0"), None);
        assert_eq!(FabricSpec::from_str("rack:x"), None);
        assert_eq!(FabricSpec::from_str("straggler:"), None);
        assert_eq!(FabricSpec::from_str("mesh"), None);
    }

    #[test]
    fn validate_rejects_out_of_range_straggler() {
        let spec = FabricSpec::Straggler { server: 9 };
        assert!(spec.validate(4).is_err());
        assert!(spec.validate(10).is_ok());
        for spec in [
            FabricSpec::Uniform,
            FabricSpec::Rack { racks: 7 },
            FabricSpec::HeteroMix,
        ] {
            assert!(spec.validate(2).is_ok());
        }
    }

    #[test]
    fn uniform_matches_scalar_model_bitwise() {
        let base = NetworkModel::default();
        let f = Fabric::uniform(4, base);
        for bytes in [0u64, 1, 1 << 10, 1 << 20, 1 << 30] {
            for src in 0..4 {
                for dst in 0..4 {
                    assert_eq!(
                        f.transfer_time(src, dst, bytes).to_bits(),
                        base.transfer_time(bytes).to_bits()
                    );
                }
            }
        }
        for s in 0..4 {
            assert_eq!(f.compute_speed(s), 1.0);
        }
        assert!(f.is_uniform());
    }

    #[test]
    fn rack_fabric_oversubscribes_cross_rack_only() {
        let base = NetworkModel::default();
        let f = Fabric::rack(4, base, 2);
        // servers {0,1} in rack 0, {2,3} in rack 1
        assert_eq!(f.link_bandwidth(0, 1), base.bandwidth);
        assert_eq!(f.link_latency(0, 1), base.latency);
        assert_eq!(
            f.link_bandwidth(0, 2),
            base.bandwidth / RACK_OVERSUBSCRIPTION
        );
        assert_eq!(
            f.link_latency(1, 3),
            base.latency * RACK_CROSS_LATENCY_FACTOR
        );
        // rack:1 degenerates to uniform (every link intra-rack)
        let one = Fabric::rack(4, base, 1);
        for src in 0..4 {
            for dst in 0..4 {
                assert_eq!(
                    one.transfer_time(src, dst, 1 << 20).to_bits(),
                    base.transfer_time(1 << 20).to_bits()
                );
            }
        }
    }

    #[test]
    fn hetero_mix_slows_the_upper_half() {
        let base = NetworkModel::default();
        let f = Fabric::hetero_mix(4, base);
        // fast-fast link at base rate; any slow endpoint degrades it
        assert_eq!(f.link_bandwidth(0, 1), base.bandwidth);
        assert_eq!(
            f.link_bandwidth(0, 2),
            base.bandwidth / SLOW_NIC_FACTOR
        );
        assert_eq!(
            f.link_bandwidth(2, 3),
            base.bandwidth / SLOW_NIC_FACTOR
        );
        for s in 0..4 {
            assert_eq!(f.compute_speed(s), 1.0, "hetero-mix is NIC-only");
        }
    }

    #[test]
    fn straggler_degrades_exactly_one_server() {
        let base = NetworkModel::default();
        let f = Fabric::straggler(4, base, 1);
        assert_eq!(
            f.compute_speed(1),
            1.0 / STRAGGLER_COMPUTE_FACTOR
        );
        for s in [0usize, 2, 3] {
            assert_eq!(f.compute_speed(s), 1.0);
        }
        assert_eq!(
            f.link_bandwidth(0, 1),
            base.bandwidth / STRAGGLER_LINK_FACTOR
        );
        assert_eq!(
            f.link_bandwidth(1, 2),
            base.bandwidth / STRAGGLER_LINK_FACTOR
        );
        assert_eq!(f.link_bandwidth(0, 2), base.bandwidth);
        assert_eq!(
            f.link_latency(3, 1),
            base.latency * STRAGGLER_LATENCY_FACTOR
        );
    }

    #[test]
    fn rack_assignment_is_contiguous_and_total() {
        for n in 1..9 {
            for racks in 1..5 {
                let mut prev = 0usize;
                for s in 0..n {
                    let r = rack_of(s, n, racks);
                    assert!(r >= prev, "rack ids must be non-decreasing");
                    assert!(r < racks.max(n), "rack id out of range");
                    prev = r;
                }
            }
        }
    }
}
