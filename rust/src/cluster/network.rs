//! Network byte accounting + the base scalar link model.
//!
//! The paper's testbed is 4 GPU servers on 10 Gb/s Ethernet; every win
//! HopGNN reports is ultimately a byte-count win (features vs model vs
//! intermediate state). This module accounts **bytes and messages
//! exactly** per transfer kind and per (src, dst) link. Transfer *times*
//! come from the topology-aware [`super::fabric::Fabric`] — a per-link
//! `t = latency + bytes / bandwidth` matrix; the scalar [`NetworkModel`]
//! here is the base rate a fabric is built from (and exactly what a
//! `uniform` fabric reproduces, bit for bit).

use super::fabric::Fabric;

/// What is being moved — the categories the paper's figures break out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferKind {
    /// Raw vertex features (the model-centric bottleneck, Fig 4).
    Feature,
    /// Model parameters (HopGNN migration; P³'s initial scatter).
    ModelParams,
    /// Accumulated gradients travelling with a migrating model.
    Gradient,
    /// Partial aggregations / saved activations (Naive-FC, Fig 6-7).
    Intermediate,
    /// Hidden-layer embeddings (P³'s push-pull).
    Hidden,
    /// Control messages (root redistribution etc.).
    Control,
}

pub const NUM_KINDS: usize = 6;

impl TransferKind {
    pub fn index(self) -> usize {
        match self {
            TransferKind::Feature => 0,
            TransferKind::ModelParams => 1,
            TransferKind::Gradient => 2,
            TransferKind::Intermediate => 3,
            TransferKind::Hidden => 4,
            TransferKind::Control => 5,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransferKind::Feature => "feature",
            TransferKind::ModelParams => "model",
            TransferKind::Gradient => "gradient",
            TransferKind::Intermediate => "intermediate",
            TransferKind::Hidden => "hidden",
            TransferKind::Control => "control",
        }
    }
}

/// Base scalar link model: `t = latency + bytes / bandwidth`. A
/// `uniform` fabric applies this rate to every link; the non-uniform
/// topologies derive their per-link matrices from it.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency in seconds (RPC + kernel + switch).
    pub latency: f64,
    /// Effective bandwidth, bytes/second.
    pub bandwidth: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        // 10 GbE: 1.25 GB/s line rate, ~1.0 GB/s effective after
        // TCP/gRPC overheads (the paper's own stack is Golang+gRPC).
        Self {
            latency: 50e-6,
            bandwidth: 1.0e9,
        }
    }
}

impl NetworkModel {
    #[inline]
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }
}

/// Byte + message accounting across the simulated cluster.
#[derive(Clone, Debug)]
pub struct NetStats {
    num_servers: usize,
    /// bytes[kind]
    pub bytes_by_kind: [u64; NUM_KINDS],
    /// messages[kind]
    pub msgs_by_kind: [u64; NUM_KINDS],
    /// per-link bytes: link[src * n + dst]
    pub link_bytes: Vec<u64>,
    /// per-link message counts: link[src * n + dst]
    pub link_msgs: Vec<u64>,
    /// bytes sent per source server (row sums of `link_bytes`).
    pub sent_bytes: Vec<u64>,
    /// bytes received per destination server (column sums).
    pub recv_bytes: Vec<u64>,
}

impl NetStats {
    pub fn new(num_servers: usize) -> Self {
        Self {
            num_servers,
            bytes_by_kind: [0; NUM_KINDS],
            msgs_by_kind: [0; NUM_KINDS],
            link_bytes: vec![0; num_servers * num_servers],
            link_msgs: vec![0; num_servers * num_servers],
            sent_bytes: vec![0; num_servers],
            recv_bytes: vec![0; num_servers],
        }
    }

    /// Record a transfer and return its modeled duration on the
    /// (src, dst) link of `fabric`.
    pub fn record(
        &mut self,
        fabric: &Fabric,
        src: usize,
        dst: usize,
        bytes: u64,
        kind: TransferKind,
    ) -> f64 {
        debug_assert!(src < self.num_servers && dst < self.num_servers);
        if src == dst {
            return 0.0; // local: no network cost, not counted
        }
        self.bytes_by_kind[kind.index()] += bytes;
        self.msgs_by_kind[kind.index()] += 1;
        self.link_bytes[src * self.num_servers + dst] += bytes;
        self.link_msgs[src * self.num_servers + dst] += 1;
        self.sent_bytes[src] += bytes;
        self.recv_bytes[dst] += bytes;
        fabric.transfer_time(src, dst, bytes)
    }

    /// Zero every counter, keeping the per-link buffers (the epoch
    /// driver's lane scratch resets instead of reallocating per lane
    /// set). A reset `NetStats` is indistinguishable from a fresh
    /// `new(num_servers)`.
    pub fn reset(&mut self) {
        self.bytes_by_kind = [0; NUM_KINDS];
        self.msgs_by_kind = [0; NUM_KINDS];
        self.link_bytes.fill(0);
        self.link_msgs.fill(0);
        self.sent_bytes.fill(0);
        self.recv_bytes.fill(0);
    }

    pub fn total_bytes(&self) -> u64 {
        self.bytes_by_kind.iter().sum()
    }

    pub fn total_msgs(&self) -> u64 {
        self.msgs_by_kind.iter().sum()
    }

    pub fn bytes(&self, kind: TransferKind) -> u64 {
        self.bytes_by_kind[kind.index()]
    }

    /// Fold another accounting delta into this one (lane-safe
    /// reduction: per-server lane executors record into local NetStats,
    /// merged in deterministic server order). All counters are exact
    /// integer sums, so merge order never changes totals.
    pub fn merge(&mut self, other: &NetStats) {
        debug_assert_eq!(self.num_servers, other.num_servers);
        for k in 0..NUM_KINDS {
            self.bytes_by_kind[k] += other.bytes_by_kind[k];
            self.msgs_by_kind[k] += other.msgs_by_kind[k];
        }
        for (dst, src) in self.link_bytes.iter_mut().zip(&other.link_bytes)
        {
            *dst += src;
        }
        for (dst, src) in self.link_msgs.iter_mut().zip(&other.link_msgs) {
            *dst += src;
        }
        for (dst, src) in self.sent_bytes.iter_mut().zip(&other.sent_bytes) {
            *dst += src;
        }
        for (dst, src) in self.recv_bytes.iter_mut().zip(&other.recv_bytes) {
            *dst += src;
        }
    }

    /// Conservation invariant, checked at the end of every
    /// `EpochDriver` session: per-kind byte totals == per-link byte
    /// totals, per-kind message counts == per-link message counts, and
    /// per-server byte conservation — each server's sent bytes equal
    /// its `link_bytes` row sum, its received bytes the column sum, and
    /// the cluster's total sent equals total received (transfers are
    /// recorded atomically, so in-flight bytes are structurally zero at
    /// session close; a nonzero residual means a counter was corrupted).
    pub fn validate(&self) -> Result<(), String> {
        let by_link: u64 = self.link_bytes.iter().sum();
        let by_kind: u64 = self.total_bytes();
        if by_link != by_kind {
            return Err(format!(
                "byte accounting mismatch: links {by_link} != kinds {by_kind}"
            ));
        }
        let msgs_link: u64 = self.link_msgs.iter().sum();
        let msgs_kind: u64 = self.total_msgs();
        if msgs_link != msgs_kind {
            return Err(format!(
                "message accounting mismatch: links {msgs_link} != kinds \
                 {msgs_kind}"
            ));
        }
        let n = self.num_servers;
        for s in 0..n {
            let row: u64 = self.link_bytes[s * n..(s + 1) * n].iter().sum();
            if row != self.sent_bytes[s] {
                return Err(format!(
                    "server {s} sent-byte mismatch: links {row} != sent {}",
                    self.sent_bytes[s]
                ));
            }
            let col: u64 = (0..n).map(|d| self.link_bytes[d * n + s]).sum();
            if col != self.recv_bytes[s] {
                return Err(format!(
                    "server {s} recv-byte mismatch: links {col} != received \
                     {}",
                    self.recv_bytes[s]
                ));
            }
        }
        let sent: u64 = self.sent_bytes.iter().sum();
        let recv: u64 = self.recv_bytes.iter().sum();
        if sent != recv {
            return Err(format!(
                "cluster byte conservation: sent {sent} != received {recv} \
                 (bytes in flight at session close)"
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(n: usize) -> Fabric {
        Fabric::uniform(n, NetworkModel::default())
    }

    #[test]
    fn linear_time_model() {
        let net = NetworkModel {
            latency: 1e-4,
            bandwidth: 1e9,
        };
        assert!((net.transfer_time(0) - 1e-4).abs() < 1e-12);
        assert!((net.transfer_time(1_000_000_000) - 1.0001).abs() < 1e-9);
    }

    #[test]
    fn local_transfers_are_free_and_uncounted() {
        let f = uniform(4);
        let mut s = NetStats::new(4);
        let t = s.record(&f, 2, 2, 1 << 20, TransferKind::Feature);
        assert_eq!(t, 0.0);
        assert_eq!(s.total_bytes(), 0);
        assert_eq!(s.total_msgs(), 0);
    }

    #[test]
    fn merge_is_exact_sum() {
        let f = uniform(2);
        let mut a = NetStats::new(2);
        let mut b = NetStats::new(2);
        a.record(&f, 0, 1, 100, TransferKind::Feature);
        b.record(&f, 1, 0, 40, TransferKind::Gradient);
        b.record(&f, 0, 1, 5, TransferKind::Feature);
        a.merge(&b);
        assert_eq!(a.bytes(TransferKind::Feature), 105);
        assert_eq!(a.bytes(TransferKind::Gradient), 40);
        assert_eq!(a.msgs_by_kind[TransferKind::Feature.index()], 2);
        assert_eq!(a.link_msgs[1], 2); // 0 -> 1 twice
        assert_eq!(a.link_msgs[2], 1); // 1 -> 0 once
        a.validate().unwrap();
    }

    #[test]
    fn accounting_by_kind_and_link() {
        let f = uniform(3);
        let mut s = NetStats::new(3);
        s.record(&f, 0, 1, 100, TransferKind::Feature);
        s.record(&f, 0, 1, 50, TransferKind::Feature);
        s.record(&f, 1, 2, 7, TransferKind::ModelParams);
        assert_eq!(s.bytes(TransferKind::Feature), 150);
        assert_eq!(s.bytes(TransferKind::ModelParams), 7);
        assert_eq!(s.msgs_by_kind[TransferKind::Feature.index()], 2);
        assert_eq!(s.link_bytes[1], 150);
        assert_eq!(s.link_msgs[1], 2);
        assert_eq!(s.link_msgs[5], 1);
        s.validate().unwrap();
    }

    #[test]
    fn validate_catches_message_drift() {
        let f = uniform(2);
        let mut s = NetStats::new(2);
        s.record(&f, 0, 1, 64, TransferKind::Control);
        s.link_msgs[1] += 1; // corrupt the per-link message count
        assert!(s.validate().is_err());
    }

    #[test]
    fn validate_enforces_per_server_byte_conservation() {
        let f = uniform(3);
        let mut s = NetStats::new(3);
        s.record(&f, 0, 1, 100, TransferKind::Feature);
        s.record(&f, 1, 2, 60, TransferKind::Feature);
        s.record(&f, 2, 0, 15, TransferKind::Gradient);
        assert_eq!(s.sent_bytes, vec![100, 60, 15]);
        assert_eq!(s.recv_bytes, vec![15, 100, 60]);
        s.validate().unwrap();
        // a lost sent record breaks the per-server row sum...
        let mut bad = s.clone();
        bad.sent_bytes[0] -= 1;
        let e = bad.validate().unwrap_err();
        assert!(e.contains("sent-byte mismatch"), "{e}");
        // ...as does a lost receive record on the column sum
        let mut bad = s.clone();
        bad.recv_bytes[2] += 1;
        let e = bad.validate().unwrap_err();
        assert!(e.contains("recv-byte mismatch"), "{e}");
        // merge preserves the invariant
        let mut merged = NetStats::new(3);
        merged.merge(&s);
        merged.merge(&s);
        assert_eq!(merged.sent_bytes, vec![200, 120, 30]);
        merged.validate().unwrap();
    }

    #[test]
    fn record_charges_the_fabric_link() {
        // a straggler link must be priced per-link, not at the base rate
        let base = NetworkModel::default();
        let f = Fabric::straggler(3, base, 0);
        let mut s = NetStats::new(3);
        let slow = s.record(&f, 0, 1, 1 << 20, TransferKind::Feature);
        let fast = s.record(&f, 1, 2, 1 << 20, TransferKind::Feature);
        assert!(slow > fast, "straggler link {slow} !> fast link {fast}");
        assert_eq!(
            fast.to_bits(),
            base.transfer_time(1 << 20).to_bits(),
            "untouched links stay at the base rate"
        );
        s.validate().unwrap();
    }
}
