//! Per-server virtual clocks.
//!
//! Each simulated GPU server advances its own clock through gather /
//! compute / migration phases; synchronization points (gradient allreduce,
//! HopGNN's per-time-step model migration barrier) set every participant
//! to the maximum — that *is* the synchronization overhead the paper's
//! merging technique (§5.3) trades against locality.

#[derive(Clone, Debug)]
pub struct Clocks {
    t: Vec<f64>,
    /// accumulated busy (compute) time per server — the GPU-utilization
    /// proxy for Fig 20.
    busy: Vec<f64>,
}

impl Clocks {
    pub fn new(num_servers: usize) -> Self {
        Self {
            t: vec![0.0; num_servers],
            busy: vec![0.0; num_servers],
        }
    }

    pub fn num_servers(&self) -> usize {
        self.t.len()
    }

    #[inline]
    pub fn now(&self, server: usize) -> f64 {
        self.t[server]
    }

    /// Advance `server` by `dt` (idle/transfer time).
    #[inline]
    pub fn advance(&mut self, server: usize, dt: f64) {
        debug_assert!(dt >= 0.0, "negative time {dt}");
        self.t[server] += dt;
    }

    /// Advance `server` by `dt` of *compute* (counted busy).
    #[inline]
    pub fn advance_busy(&mut self, server: usize, dt: f64) {
        self.advance(server, dt);
        self.busy[server] += dt;
    }

    /// Lane-safe accounting: overwrite `server`'s clock with the final
    /// time computed by a concurrent lane executor. The lane starts
    /// from `now(server)` and only accumulates, so `t` never rewinds.
    #[inline]
    pub fn set(&mut self, server: usize, t: f64) {
        debug_assert!(
            t >= self.t[server],
            "lane clock rewind: {t} < {}",
            self.t[server]
        );
        self.t[server] = t;
    }

    /// Lane-safe accounting: fold a lane's accumulated busy (compute)
    /// seconds into `server`'s busy counter.
    #[inline]
    pub fn add_busy(&mut self, server: usize, dt: f64) {
        debug_assert!(dt >= 0.0, "negative busy {dt}");
        self.busy[server] += dt;
    }

    /// Barrier across all servers: everyone waits for the slowest.
    pub fn barrier(&mut self) -> f64 {
        let max = self.max();
        for t in self.t.iter_mut() {
            *t = max;
        }
        max
    }

    /// Barrier across a subset.
    pub fn barrier_among(&mut self, servers: &[usize]) -> f64 {
        let max = servers
            .iter()
            .map(|&s| self.t[s])
            .fold(f64::MIN, f64::max);
        for &s in servers {
            self.t[s] = max;
        }
        max
    }

    pub fn max(&self) -> f64 {
        self.t.iter().cloned().fold(0.0, f64::max)
    }

    pub fn busy_fraction(&self) -> f64 {
        let total = self.max() * self.t.len() as f64;
        if total == 0.0 {
            return 0.0;
        }
        self.busy.iter().sum::<f64>() / total
    }

    pub fn busy_time(&self, server: usize) -> f64 {
        self.busy[server]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn advance_and_barrier() {
        let mut c = Clocks::new(3);
        c.advance(0, 1.0);
        c.advance(1, 3.0);
        c.advance_busy(2, 2.0);
        assert_eq!(c.max(), 3.0);
        let t = c.barrier();
        assert_eq!(t, 3.0);
        for s in 0..3 {
            assert_eq!(c.now(s), 3.0);
        }
    }

    #[test]
    fn busy_fraction_counts_only_compute() {
        let mut c = Clocks::new(2);
        c.advance_busy(0, 1.0); // busy
        c.advance(0, 1.0); // idle
        c.barrier(); // server 1 idles 2.0
        // total wall = 2.0 * 2 servers = 4.0; busy = 1.0
        assert!((c.busy_fraction() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn subset_barrier_leaves_others() {
        let mut c = Clocks::new(3);
        c.advance(0, 5.0);
        c.barrier_among(&[0, 1]);
        assert_eq!(c.now(1), 5.0);
        assert_eq!(c.now(2), 0.0);
    }

    #[test]
    fn lane_set_and_add_busy() {
        let mut c = Clocks::new(2);
        c.advance(0, 1.0);
        // a lane resumed from now(0)=1.0 and accumulated to 3.5 with
        // 1.5s of compute
        c.set(0, 3.5);
        c.add_busy(0, 1.5);
        assert_eq!(c.now(0), 3.5);
        assert_eq!(c.busy_time(0), 1.5);
        assert_eq!(c.max(), 3.5);
    }

    #[test]
    fn monotonic_clocks() {
        let mut c = Clocks::new(2);
        let mut prev = 0.0;
        for i in 0..50 {
            c.advance(0, (i % 3) as f64 * 0.1);
            assert!(c.now(0) >= prev);
            prev = c.now(0);
        }
    }
}
