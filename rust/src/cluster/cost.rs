//! Compute cost model for the simulated GPU servers.
//!
//! Epoch-time *shape* reproduction needs relative costs, not absolute
//! A100 numbers: compute time is derived from an analytic FLOP count per
//! GNN layer, divided by an effective throughput that the runtime can
//! calibrate from a real PJRT execution (`calibrate`). Kernel-launch and
//! synchronization constants are what micrograph merging (§5.3) trades
//! against locality, so they are explicit knobs.

/// Which GNN family — aggregation cost differs (GAT's attention is the
/// expensive one, Fig 11's GCN-vs-GAT speedup difference comes from this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelFamily {
    Gcn,
    Sage,
    Gat,
    DeepGcn,
    Film,
}

impl ModelFamily {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "gcn" => Some(Self::Gcn),
            "sage" => Some(Self::Sage),
            "gat" => Some(Self::Gat),
            "deepgcn" => Some(Self::DeepGcn),
            "film" => Some(Self::Film),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Gcn => "gcn",
            Self::Sage => "sage",
            Self::Gat => "gat",
            Self::DeepGcn => "deepgcn",
            Self::Film => "film",
        }
    }

    /// Default layer count used in the paper (§7.1).
    pub fn default_layers(&self) -> usize {
        match self {
            Self::DeepGcn => 7,
            Self::Film => 10,
            _ => 3,
        }
    }
}

/// Static description of one training workload's model shape.
#[derive(Clone, Copy, Debug)]
pub struct ModelShape {
    pub family: ModelFamily,
    pub layers: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
}

impl ModelShape {
    /// Scalar parameter count (mirrors python param_spec; used for the
    /// alpha ratio of Fig 5 and migration byte accounting).
    pub fn param_count(&self) -> usize {
        let mut total = 0usize;
        for l in 0..self.layers {
            let fi = if l == 0 { self.feat_dim } else { self.hidden };
            let deep = matches!(self.family, ModelFamily::DeepGcn | ModelFamily::Film);
            let fo = if l == self.layers - 1 && !deep {
                self.classes
            } else {
                self.hidden
            };
            match self.family {
                ModelFamily::Sage => total += 2 * fi * fo + fo,
                ModelFamily::Film => total += 3 * fi * fo + fo,
                ModelFamily::Gat => total += fi * fo + fo + 2 * fo,
                _ => total += fi * fo + fo,
            }
        }
        if matches!(self.family, ModelFamily::DeepGcn | ModelFamily::Film) {
            total += self.hidden * self.classes + self.classes;
        }
        total
    }

    pub fn param_bytes(&self) -> u64 {
        (self.param_count() * 4) as u64
    }

    /// Forward+backward FLOPs for a sampled block with `vertices`
    /// vertices and `edges` edges (all layers). Backward ≈ 2× forward.
    pub fn train_flops(&self, vertices: u64, edges: u64) -> f64 {
        let mut fwd = 0.0;
        for l in 0..self.layers {
            let fi = if l == 0 { self.feat_dim } else { self.hidden } as f64;
            let fo = if l == self.layers - 1 {
                self.classes
            } else {
                self.hidden
            } as f64;
            let v = vertices as f64;
            let e = edges as f64;
            // aggregation: 2 flops per edge per input dim
            let agg = 2.0 * e * fi;
            // transform: dense matmul
            let xform = 2.0 * v * fi * fo;
            let extra = match self.family {
                ModelFamily::Gat => 4.0 * e * fo + 6.0 * e, // scores+softmax
                ModelFamily::Sage => 2.0 * v * fi * fo,     // concat doubles fan-in
                ModelFamily::Film => 4.0 * v * fi * fo,     // gamma/beta heads
                _ => 0.0,
            };
            fwd += agg + xform + extra;
        }
        3.0 * fwd // fwd + ~2x bwd
    }
}

/// Cluster compute-cost constants.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Effective GNN training throughput per GPU, FLOP/s. Real A100 peak
    /// is 19.5 TF32-TFLOPs but GNN training achieves a few percent
    /// (Fig 20 shows <20% utilization); 1.5e12 reflects that.
    pub flops_per_sec: f64,
    /// Fixed overhead per executable launch (kernel switch, Fig 17's
    /// motivation for merging).
    pub t_launch: f64,
    /// Fixed overhead per cross-server synchronization barrier.
    pub t_sync: f64,
    /// Sampling cost per sampled vertex (CPU-side, amortized).
    pub sample_per_vertex: f64,
    /// Host-side per-vertex feature staging cost (memcpy into tensors).
    pub stage_per_byte: f64,
    /// P³-only: CPU cost per layer-1 row for splitting/merging the N-way
    /// partial-activation tensors in its push-pull phase. The HopGNN
    /// paper's P³ reimplementation (like ours, built from the OSDI text)
    /// is bottlenecked here, which is why their Fig 11 shows P³ behind
    /// HopGNN even at hidden=16 where P³'s byte counts are tiny.
    pub mp_row_overhead: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        // Calibrated to the paper's measured fractions (Fig 4: gather
        // 44-83% of DGL epoch; Fig 20: GPU busy ~13%; sample+compute ~11%
        // combined): an A100 runs the dense padded-micrograph kernels at
        // a few TFLOP/s effective, and DGL's 48-core sampler pipelines at
        // tens of ns per sampled vertex.
        Self {
            flops_per_sec: 4.0e12,
            t_launch: 15e-6,
            t_sync: 0.2e-3,
            sample_per_vertex: 0.02e-6,
            stage_per_byte: 1.0 / 16.0e9, // pinned-memory H2D staging
            mp_row_overhead: 0.5e-6,
        }
    }
}

impl CostModel {
    /// Time to train one block (batched micrographs or a subgraph).
    pub fn train_time(
        &self,
        shape: &ModelShape,
        vertices: u64,
        edges: u64,
    ) -> f64 {
        shape.train_flops(vertices, edges) / self.flops_per_sec
            + self.launch_overhead(shape)
    }

    /// Launch overhead for one executable invocation: ~4 kernels per
    /// layer (normalize, aggregate, transform, activation) fwd + bwd.
    pub fn launch_overhead(&self, shape: &ModelShape) -> f64 {
        self.t_launch * (shape.layers * 8) as f64
    }

    pub fn sample_time(&self, sampled_vertices: u64) -> f64 {
        self.sample_per_vertex * sampled_vertices as f64
    }

    pub fn stage_time(&self, bytes: u64) -> f64 {
        self.stage_per_byte * bytes as f64
    }

    /// Calibrate effective FLOP/s from a measured real execution of a
    /// known block (done once at startup when PJRT artifacts are loaded).
    pub fn calibrate(
        &mut self,
        shape: &ModelShape,
        vertices: u64,
        edges: u64,
        measured_secs: f64,
    ) {
        if measured_secs > 0.0 {
            self.flops_per_sec = shape.train_flops(vertices, edges)
                / measured_secs;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(family: ModelFamily, layers: usize, hidden: usize) -> ModelShape {
        ModelShape {
            family,
            layers,
            feat_dim: 128,
            hidden,
            classes: 10,
        }
    }

    #[test]
    fn param_count_matches_python_abi() {
        // python: gcn l3 h128 f128 c10 -> 34314 (aot.py output)
        assert_eq!(shape(ModelFamily::Gcn, 3, 128).param_count(), 34_314);
        // sage doubles fan-in: 68362
        assert_eq!(shape(ModelFamily::Sage, 3, 128).param_count(), 68_362);
        // gat adds attention vectors: 34846
        assert_eq!(shape(ModelFamily::Gat, 3, 128).param_count(), 34_846);
        // deepgcn l7 h64: 33866
        let d = ModelShape {
            family: ModelFamily::DeepGcn,
            layers: 7,
            feat_dim: 128,
            hidden: 64,
            classes: 10,
        };
        assert_eq!(d.param_count(), 33_866);
        // film l10 h64: 136458
        let f = ModelShape {
            family: ModelFamily::Film,
            layers: 10,
            feat_dim: 128,
            hidden: 64,
            classes: 10,
        };
        assert_eq!(f.param_count(), 136_458);
    }

    #[test]
    fn gat_costs_more_than_gcn() {
        let g = shape(ModelFamily::Gcn, 3, 128);
        let a = shape(ModelFamily::Gat, 3, 128);
        assert!(a.train_flops(1000, 8000) > g.train_flops(1000, 8000));
    }

    #[test]
    fn flops_scale_with_size() {
        let s = shape(ModelFamily::Gcn, 3, 128);
        assert!(s.train_flops(2000, 16000) > 1.9 * s.train_flops(1000, 8000));
    }

    #[test]
    fn calibration_inverts_train_time() {
        let mut cm = CostModel::default();
        let s = shape(ModelFamily::Gcn, 3, 128);
        cm.calibrate(&s, 1024, 8192, 0.010);
        let t = s.train_flops(1024, 8192) / cm.flops_per_sec;
        assert!((t - 0.010).abs() < 1e-9);
    }

    #[test]
    fn launch_overhead_scales_with_depth() {
        let cm = CostModel::default();
        let shallow = shape(ModelFamily::Gcn, 3, 128);
        let deep = shape(ModelFamily::DeepGcn, 7, 64);
        assert!(cm.launch_overhead(&deep) > 2.0 * cm.launch_overhead(&shallow));
    }
}
