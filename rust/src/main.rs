//! `hopgnn` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   reproduce  regenerate paper tables/figures (DESIGN.md §5)
//!   bench      run experiments by id, writing markdown + JSON reports
//!              (the CI smoke entry point)
//!   sim        run one (dataset, model, strategy) simulation
//!   train      real PJRT training run (loss curve + accuracy)
//!   partition  partition a dataset and report cut/balance/locality
//!   calibrate  measure real PJRT step time, report effective FLOP/s
//!   info       list datasets, artifacts, experiments

use hopgnn::bench::servebench::{
    cell_label, run_serve_grid, serve_table, workload_axis,
};
use hopgnn::bench::sweep::{Axis, SweepSpec};
use hopgnn::bench::{
    resolve_experiment_ids, run_experiment, Report, Scale, ALL_EXPERIMENTS,
};
use hopgnn::cluster::{FabricSpec, ModelFamily};
use hopgnn::config::RunConfig;
use hopgnn::coordinator::{run_strategy, StrategySpec};
use hopgnn::featstore::cache::CachePolicy;
use hopgnn::featstore::tier::TierSpec;
use hopgnn::graph::datasets::{load, ALL_SPECS};
use hopgnn::partition::{partition, PartitionAlgo};
use hopgnn::runtime::{Engine, Manifest};
use hopgnn::sampler::{sample_micrograph, SampleConfig, SamplerKind};
use hopgnn::serve::{serve, ServeOpts, WorkloadSpec};
use hopgnn::train::{OrderPolicy, Trainer};
use hopgnn::util::cli::Cli;
use hopgnn::util::pool::set_thread_budget;
use hopgnn::util::rng::Rng;
use hopgnn::util::table::{fmt_bytes, fmt_secs, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            std::process::exit(2);
        }
    };
    let code = match cmd {
        "reproduce" => cmd_reproduce(rest),
        "bench" => cmd_bench(rest),
        "sim" => cmd_sim(rest),
        "train" => cmd_train(rest),
        "partition" => cmd_partition(rest),
        "calibrate" => cmd_calibrate(rest),
        "info" => cmd_info(rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            0
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            2
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "hopgnn — feature-centric distributed GNN training (HopGNN reproduction)\n\n\
     Usage: hopgnn <command> [options]\n\n\
     Commands:\n  \
       reproduce   regenerate paper tables/figures (--exp <id|all>, --quick)\n  \
       bench       run experiments by id (positional), md + JSON reports;\n  \
                   'bench sweep' runs a declarative strategy/config grid\n  \
       sim         simulate one strategy (--dataset, --model, --strategy, ...);\n  \
                   'sim serve' streams an inference workload instead\n  \
       train       real PJRT training (--dataset-size, --model, --epochs)\n  \
       partition   partition quality report (--dataset, --algo, --servers)\n  \
       calibrate   measure PJRT step time and effective FLOP/s\n  \
       info        list datasets, artifacts, experiment ids\n\n\
     Run `hopgnn <command> --help` for per-command options."
        .to_string()
}

fn cmd_reproduce(args: Vec<String>) -> i32 {
    let cli = Cli::new("hopgnn reproduce", "regenerate paper tables/figures")
        .opt("exp", "all", "experiment id (fig04..fig23, table1, table3) or 'all'")
        .opt("out", "reports", "output directory for markdown reports")
        .opt("jobs", "1", "total thread budget: sweep cells x epoch \
              lanes (0 = all cores)")
        .flag("quick", "reduced scale (CI-sized)");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    set_thread_budget(a.get_usize("jobs", 1));
    let scale = if a.has("quick") {
        Scale::quick()
    } else {
        Scale::full()
    };
    let ids: Vec<&str> = match a.get("exp") {
        Some("all") | None => ALL_EXPERIMENTS.to_vec(),
        Some(id) => vec![id],
    };
    let out = a.get_or("out", "reports");
    let mut failed = 0;
    for id in ids {
        let t0 = std::time::Instant::now();
        match run_experiment(id, scale) {
            Ok(report) => {
                println!("{}", report.render());
                if let Err(e) = report.save(&out) {
                    eprintln!("warning: could not save {id}: {e}");
                }
                eprintln!("[{id} done in {}]\n", fmt_secs(t0.elapsed().as_secs_f64()));
            }
            Err(e) => {
                eprintln!("experiment {id} failed: {e}");
                failed += 1;
            }
        }
    }
    failed
}

/// `hopgnn bench [--quick] [--out DIR] <experiment id>...` — the CI
/// smoke entry point: run the named experiments (default: all) and
/// write both the markdown report and its JSON twin, which the smoke
/// workflow uploads as its artifact. Ids are validated and deduped
/// *before* anything runs, so a typo can no longer abort a batch
/// mid-run after earlier experiments already spent minutes.
///
/// `hopgnn bench sweep ...` instead runs one declarative grid through
/// the sweep engine — see `cmd_bench_sweep`.
fn cmd_bench(args: Vec<String>) -> i32 {
    if args.first().map(String::as_str) == Some("sweep") {
        return cmd_bench_sweep(args[1..].to_vec());
    }
    let cli = Cli::new(
        "hopgnn bench",
        "run experiments by id, writing markdown + JSON reports \
         ('bench sweep' runs a declarative grid instead)",
    )
    .opt("out", "reports", "output directory for md/json reports")
    .opt("jobs", "1", "total thread budget: sweep cells x epoch lanes \
          (0 = all cores)")
    .flag("quick", "reduced scale (CI-sized)");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    set_thread_budget(a.get_usize("jobs", 1));
    let scale = if a.has("quick") {
        Scale::quick()
    } else {
        Scale::full()
    };
    let requested: Vec<String> = if a.positional.is_empty() {
        ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect()
    } else {
        a.positional.clone()
    };
    // fail fast: every id checked (and duplicates dropped) up front
    let ids = match resolve_experiment_ids(&requested) {
        Ok(ids) => ids,
        Err(e) => {
            eprintln!("{e}");
            if requested.iter().any(|id| id == "sweep") {
                eprintln!(
                    "note: 'sweep' is a subcommand, not an experiment \
                     id — spell it `hopgnn bench sweep [flags]` with \
                     'sweep' directly after 'bench'"
                );
            }
            return 2;
        }
    };
    let out = a.get_or("out", "reports");
    let mut failed = 0;
    for id in &ids {
        let t0 = std::time::Instant::now();
        match run_experiment(id, scale) {
            Ok(report) => {
                println!("{}", report.render());
                if let Err(e) = report.save(&out) {
                    eprintln!("warning: could not save {id}.md: {e}");
                    failed += 1;
                }
                if let Err(e) = report.save_json(&out) {
                    eprintln!("warning: could not save {id}.json: {e}");
                    failed += 1;
                }
                eprintln!(
                    "[{id} done in {}]\n",
                    fmt_secs(t0.elapsed().as_secs_f64())
                );
            }
            Err(e) => {
                eprintln!("experiment {id} failed: {e}");
                failed += 1;
            }
        }
    }
    failed
}

/// `hopgnn bench sweep [--quick] [--out DIR] --strategies <specs>
/// [--datasets ...] [--fabrics ...] [--cache ...] [--cache-mb ...]
/// [--tiers ...] [--overlap off|on|both] [--set k=v,...]` — build a
/// `SweepSpec`
/// from the flags, run the full cartesian grid through the engine, and
/// write a `sweep` report (md + JSON) with one row per cell.
/// Parse a comma-separated CLI list, trimming items and prefixing
/// errors with the flag name (shared by every `bench sweep` axis flag).
fn parse_list<T>(
    raw: &str,
    what: &str,
    parse: impl Fn(&str) -> Result<T, String>,
) -> Result<Vec<T>, String> {
    raw.split(',')
        .map(|item| parse(item.trim()).map_err(|e| format!("{what}: {e}")))
        .collect()
}

fn cmd_bench_sweep(args: Vec<String>) -> i32 {
    let cli = Cli::new(
        "hopgnn bench sweep",
        "run a declarative strategy x config sweep grid",
    )
    .opt(
        "strategies",
        "dgl,hopgnn",
        "comma-separated strategy specs (grammar or legacy aliases)",
    )
    .opt("datasets", "", "comma-separated dataset axis")
    .opt(
        "fabrics",
        "",
        "comma-separated fabric axis (uniform|rack:<k>|hetero-mix|straggler:<s>)",
    )
    .opt("cache", "", "comma-separated cache-policy axis")
    .opt("cache-mb", "", "comma-separated capacity axis (MiB)")
    .opt(
        "tiers",
        "",
        "comma-separated tier-stack axis (e.g. remote,dram:64m:lru+remote)",
    )
    .opt("overlap", "", "overlap axis: off|on|both")
    .opt(
        "workload",
        "",
        "semicolon-separated workload axis (kind:rate=..[,dur=..,seed=..]; \
         params use commas, so items split on ';'); when set, every cell \
         streams its workload through the serving engine instead of the \
         epoch runner",
    )
    .opt(
        "set",
        "",
        "base config patches 'key=val[,key=val...]'; 'strategy=<spec>' \
         pins the single strategy (instead of --strategies)",
    )
    .opt("out", "reports", "output directory for the md/json report")
    .opt("jobs", "1", "total thread budget: grid cells x epoch lanes \
          (0 = all cores)")
    .flag("quick", "reduced scale (CI-sized)");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let scale = if a.has("quick") {
        Scale::quick()
    } else {
        Scale::full()
    };
    let mut base = RunConfig {
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        ..Default::default()
    };
    base.vmax = RunConfig::full_sim_vmax(base.layers, base.fanout);
    for patch in a.get_or("set", "").split(',') {
        let patch = patch.trim();
        if patch.is_empty() {
            continue;
        }
        let Some((k, v)) = patch.split_once('=') else {
            eprintln!("--set expects key=val pairs, got '{patch}'");
            return 2;
        };
        if let Err(e) = base.set(k.trim(), v.trim()) {
            eprintln!("--set {patch}: {e}");
            return 2;
        }
    }

    // `--set strategy=<spec>` pins the single strategy; mixing it with
    // an explicit `--strategies` axis would be ambiguous
    let mut specs: Vec<StrategySpec> = Vec::new();
    if let Some(s) = base.strategy.take() {
        if a.explicit("strategies") {
            eprintln!(
                "--set strategy= conflicts with --strategies; pick one"
            );
            return 2;
        }
        specs.push(s);
    } else {
        match parse_list(
            &a.get_or("strategies", "dgl,hopgnn"),
            "--strategies",
            |s| s.parse::<StrategySpec>(),
        ) {
            Ok(list) => specs = list,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    let mut sweep = SweepSpec::new(base, specs[0]);
    let mut shape: Vec<String> = Vec::new();
    let datasets = a.get_or("datasets", "");
    if !datasets.is_empty() {
        let list: Vec<&str> = datasets.split(',').map(str::trim).collect();
        shape.push(format!("{} datasets", list.len()));
        sweep = sweep.axis(Axis::key("dataset", &list));
    }
    let fabrics = a.get_or("fabrics", "");
    if !fabrics.is_empty() {
        let list = match parse_list(&fabrics, "--fabrics", |f| {
            FabricSpec::from_str(f)
                .ok_or_else(|| format!("unknown fabric '{f}'"))
        }) {
            Ok(list) => list,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        shape.push(format!("{} fabrics", list.len()));
        sweep = sweep.axis(Axis::fabrics(&list));
    }
    let cache = a.get_or("cache", "");
    if !cache.is_empty() {
        let list = match parse_list(&cache, "--cache", |p| {
            CachePolicy::from_str(p)
                .ok_or_else(|| format!("unknown cache policy '{p}'"))
        }) {
            Ok(list) => list,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        shape.push(format!("{} cache policies", list.len()));
        sweep = sweep.axis(Axis::cache_policies(&list));
    }
    let cache_mb = a.get_or("cache-mb", "");
    if !cache_mb.is_empty() {
        let list = match parse_list(&cache_mb, "--cache-mb", |mb| {
            mb.parse::<usize>()
                .map_err(|_| format!("bad capacity '{mb}'"))
        }) {
            Ok(list) => list,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        shape.push(format!("{} capacities", list.len()));
        sweep = sweep.axis(Axis::cache_capacities_mb(&list));
    }
    let tiers = a.get_or("tiers", "");
    if !tiers.is_empty() {
        let list = match parse_list(&tiers, "--tiers", TierSpec::parse) {
            Ok(list) => list,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        shape.push(format!("{} tier stacks", list.len()));
        sweep = sweep.axis(Axis::tiers(&list));
    }
    shape.push(format!("{} strategies", specs.len()));
    sweep = sweep.axis(Axis::strategies(&specs));
    match a.get_or("overlap", "").as_str() {
        "" => {}
        "off" => sweep = sweep.axis(Axis::overlap(&[false])),
        "on" => sweep = sweep.axis(Axis::overlap(&[true])),
        "both" => {
            shape.push("2 overlap modes".to_string());
            sweep = sweep.axis(Axis::overlap(&[false, true]));
        }
        other => {
            eprintln!("--overlap expects off|on|both, got '{other}'");
            return 2;
        }
    }

    set_thread_budget(a.get_usize("jobs", 1));
    sweep = sweep.jobs(a.get_usize("jobs", 1));
    let t0 = std::time::Instant::now();

    // a workload axis re-routes the whole grid through the serving
    // engine: same declarative axes and --jobs budget split, but each
    // cell streams requests (`sim serve` semantics) instead of running
    // training epochs
    let workloads_raw = a.get_or("workload", "");
    if !workloads_raw.is_empty() {
        let mut workloads: Vec<WorkloadSpec> = Vec::new();
        for item in workloads_raw.split(';') {
            match WorkloadSpec::parse(item.trim()) {
                Ok(w) => workloads.push(w),
                Err(e) => {
                    eprintln!("--workload: {e}");
                    return 2;
                }
            }
        }
        shape.push(format!("{} workloads", workloads.len()));
        sweep = sweep.axis(workload_axis(&workloads));
        let (expanded, reports) =
            match run_serve_grid(&sweep, &ServeOpts::default()) {
                Ok(r) => r,
                Err(e) => {
                    eprintln!("serve sweep failed validation: {e}");
                    return 2;
                }
            };
        let mut failed = 0;
        for ((index, _, _), rep) in expanded.iter().zip(&reports) {
            if let Err(e) = rep.metrics.validate() {
                eprintln!(
                    "serve cell {}: {e}",
                    cell_label(&sweep.axes, index)
                );
                failed += 1;
            }
        }
        let mut report =
            Report::new("serve_sweep", "declarative serving sweep grid");
        report.section(
            format!("{} cells ({})", expanded.len(), shape.join(" x ")),
            serve_table(&sweep.axes, &expanded, &reports),
        );
        report.note(
            "each cell streams its workload through the serving engine \
             (`sim serve` semantics): latency = queue + gather + \
             compute, p50/p95/p99 are streaming P2 estimates, qps is \
             served requests over the stream makespan",
        );
        println!("{}", report.render());
        eprintln!(
            "[serve sweep: {} cells in {}]",
            expanded.len(),
            fmt_secs(t0.elapsed().as_secs_f64())
        );
        let out = a.get_or("out", "reports");
        if let Err(e) = report.save(&out) {
            eprintln!("warning: could not save serve_sweep.md: {e}");
            failed += 1;
        }
        if let Err(e) = report.save_json(&out) {
            eprintln!("warning: could not save serve_sweep.json: {e}");
            failed += 1;
        }
        return failed;
    }

    let grid = match sweep.run() {
        Ok(g) => g,
        Err(e) => {
            eprintln!("sweep failed validation: {e}");
            return 2;
        }
    };
    let mut report = Report::new("sweep", "declarative sweep grid");
    report.section(
        format!("{} cells ({})", grid.cells.len(), shape.join(" x ")),
        grid.table(),
    );
    report.note(
        "declared via `bench sweep`: each axis is expanded into a \
         cartesian grid and executed through the memoized runner; see \
         bench::sweep for the library API",
    );
    println!("{}", report.render());
    eprintln!(
        "[sweep: {} cells in {}]",
        grid.cells.len(),
        fmt_secs(t0.elapsed().as_secs_f64())
    );
    let out = a.get_or("out", "reports");
    let mut failed = 0;
    if let Err(e) = report.save(&out) {
        eprintln!("warning: could not save sweep.md: {e}");
        failed += 1;
    }
    if let Err(e) = report.save_json(&out) {
        eprintln!("warning: could not save sweep.json: {e}");
        failed += 1;
    }
    failed
}

fn cmd_sim(args: Vec<String>) -> i32 {
    // positional subcommands route before flag parsing; an unknown one
    // fails fast with the valid list instead of being silently ignored
    if let Some(first) = args.first() {
        if !first.starts_with('-') {
            if first == "serve" {
                return cmd_sim_serve(args[1..].to_vec());
            }
            eprintln!(
                "unknown sim subcommand '{first}'; known subcommands: \
                 serve (or pass flags directly for a training simulation)"
            );
            return 2;
        }
    }
    let cli = Cli::new("hopgnn sim", "simulate one training strategy")
        .opt("dataset", "products-s",
             "dataset (arxiv-s|products-s|uk-s|in-s|it-s|synth:v=..,e=..)")
        .opt("model", "gcn", "gcn|sage|gat|deepgcn|film")
        .opt("strategy", "hopgnn",
             "strategy spec (e.g. hopgnn+fa-pg) or legacy alias \
              (dgl|p3|naive|hopgnn|+mg|+pg|rd|fa|lo|ns|dgl-fb)")
        .opt("servers", "4", "number of simulated GPU servers")
        .opt("fabric", "uniform",
             "cluster topology (uniform|rack:<k>|hetero-mix|straggler:<s>)")
        .opt("batch", "1024", "global mini-batch size")
        .opt("hidden", "128", "hidden dimension")
        .opt("fanout", "10", "neighbor sampling fanout")
        .opt("epochs", "3", "epochs to simulate")
        .opt("partition", "metis", "metis|heuristic|hash")
        .opt("config", "", "key=value config file (overrides other flags)")
        .opt("seed", "42", "random seed")
        .opt("cache", "none",
             "feature-cache policy (none|lru|degree|schedule)")
        .opt("cache-mb", "64", "feature-cache capacity per server, MiB")
        .opt("tiers", "",
             "feature tier stack kind:cap[:policy]+..+remote \
              (overrides --cache/--cache-mb)")
        .flag("cache-persist", "keep feature caches warm across epochs")
        .opt("jobs", "0",
             "thread budget for parallel op lanes (0 = all cores)")
        .flag("overlap", "hide async gathers behind compute (pipelining)")
        .flag("sequential", "disable parallel per-server op lanes");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    set_thread_budget(a.get_usize("jobs", 0));
    let from_file = a.get("config").is_some_and(|s| !s.is_empty());
    let mut cfg = if from_file {
        match RunConfig::from_kv_file(a.get("config").unwrap()) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        }
    } else {
        RunConfig::default()
    };
    // with a config file, CLI *defaults* must not stomp the file's
    // settings — only options the user actually typed override it
    for key in ["dataset", "model", "servers", "hidden", "fanout", "epochs",
                "partition", "seed", "cache", "fabric"] {
        if from_file && !a.explicit(key) {
            continue;
        }
        if let Some(v) = a.get(key) {
            if let Err(e) = cfg.set(key, v) {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if !from_file || a.explicit("cache-mb") {
        if let Some(v) = a.get("cache-mb") {
            if let Err(e) = cfg.set("cache_mb", v) {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    // --tiers defaults to "" (unset), so only a typed spec reaches the
    // config; it then shadows the legacy --cache/--cache-mb pair
    let tiers = a.get_or("tiers", "");
    if !tiers.is_empty() && (!from_file || a.explicit("tiers")) {
        if let Err(e) = cfg.set("tiers", &tiers) {
            eprintln!("{e}");
            return 2;
        }
    }
    if !from_file || a.explicit("batch") {
        cfg.batch_size = a.get_usize("batch", cfg.batch_size);
    }
    if a.has("cache-persist") {
        cfg.cache_persist = true;
    }
    if let Err(e) = cfg.fabric.validate(cfg.num_servers) {
        eprintln!("{e}");
        return 2;
    }
    if a.has("overlap") {
        cfg.overlap = true;
    }
    if a.has("sequential") {
        cfg.parallel_lanes = false;
    }
    // simulation default: full micrograph (the 128 default is the PJRT
    // artifact pad, not a sampling semantic)
    cfg.vmax = RunConfig::full_sim_vmax(cfg.layers, cfg.fanout);
    // a config file's `strategy =` key pins the spec unless the user
    // typed --strategy explicitly
    let file_spec = if from_file && !a.explicit("strategy") {
        cfg.strategy
    } else {
        None
    };
    let spec = match file_spec {
        Some(s) => s,
        None => {
            match a.get_or("strategy", "hopgnn").parse::<StrategySpec>() {
                Ok(s) => s,
                Err(e) => {
                    eprintln!("{e}");
                    return 2;
                }
            }
        }
    };
    let d = load(&cfg.dataset);
    println!(
        "dataset {}: {} vertices, {} edges, feat {}, Vol_F {}",
        d.name,
        d.graph.num_vertices(),
        d.graph.num_edges(),
        d.feat_dim,
        fmt_bytes(d.feature_volume_bytes())
    );
    if cfg.fabric != hopgnn::cluster::FabricSpec::Uniform {
        println!(
            "fabric {}: per-link costs + per-server compute multipliers \
             (base: {:.0} MB/s, {:.0} us)",
            cfg.fabric.name(),
            cfg.net.bandwidth / 1e6,
            cfg.net.latency * 1e6
        );
    }
    let m = run_strategy(&d, &cfg, spec);
    println!("strategy {} ({spec}): {}", spec.name(), m.summary());
    println!("{}", m.breakdown_table().render());
    if cfg.cache_enabled() {
        println!(
            "tiers {} (per server): {:.1}% hit rate, {} saved, {} evicted",
            cfg.effective_tiers().name(),
            m.cache_hit_rate() * 100.0,
            fmt_bytes(m.cache_hit_bytes),
            fmt_bytes(m.cache_evict_bytes),
        );
    }
    0
}

/// `hopgnn sim serve` — stream an inference workload through one
/// simulated cluster and report the latency decomposition, tail
/// quantiles, and sustained QPS (the single-run face of the serving
/// subsystem; `bench serve` runs the full grid).
fn cmd_sim_serve(args: Vec<String>) -> i32 {
    let cli = Cli::new(
        "hopgnn sim serve",
        "stream an inference workload through the simulator",
    )
    .opt("dataset", "products-s",
         "dataset (arxiv-s|products-s|uk-s|in-s|it-s|synth:v=..,e=..)")
    .opt("workload", "poisson:rate=500,dur=1",
         "arrival process kind:rate=..[,dur=..,seed=..] \
          (poisson | bursty:..,mult=..,dwell=.. | \
          diurnal:..,period=..,depth=..)")
    .opt("strategy", "dgl",
         "strategy base whose placement the fleet inherited from \
          training (p3 forces hash partitioning)")
    .opt("model", "gcn", "gcn|sage|gat|deepgcn|film")
    .opt("servers", "4", "number of simulated GPU servers")
    .opt("fabric", "uniform",
         "cluster topology (uniform|rack:<k>|hetero-mix|straggler:<s>)")
    .opt("fanout", "10", "neighbor sampling fanout")
    .opt("partition", "metis", "metis|heuristic|hash")
    .opt("tiers", "dram:64m:lru+remote",
         "feature tier stack kind:cap[:policy]+..+remote")
    .opt("seed", "42", "random seed")
    .opt("window-us", "2000",
         "micro-batch coalescing window in microseconds (0 = serve \
          each batch as soon as the lane is free)")
    .opt("queue-cap", "1024",
         "bounded admission queue per server lane (overflow drops \
          fail the run)")
    .opt("max-batch", "32", "max requests coalesced into one gather")
    .opt("jobs", "0", "thread budget for parallel serve lanes \
          (0 = all cores)");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    set_thread_budget(a.get_usize("jobs", 0));
    let mut cfg = RunConfig::default();
    for key in ["dataset", "model", "servers", "fanout", "partition",
                "seed", "fabric", "tiers", "workload"] {
        if let Some(v) = a.get(key) {
            if let Err(e) = cfg.set(key, v) {
                eprintln!("{e}");
                return 2;
            }
        }
    }
    if let Err(e) = cfg.fabric.validate(cfg.num_servers) {
        eprintln!("{e}");
        return 2;
    }
    cfg.vmax = RunConfig::full_sim_vmax(cfg.layers, cfg.fanout);
    let spec = match a.get_or("strategy", "dgl").parse::<StrategySpec>() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let wl = cfg.workload.expect("--workload has a default");
    let opts = ServeOpts {
        window: a.get_f64("window-us", 2000.0) * 1e-6,
        queue_cap: a.get_usize("queue-cap", 1024),
        max_batch: a.get_usize("max-batch", 32),
    };
    let d = load(&cfg.dataset);
    println!(
        "dataset {}: {} vertices, {} edges, feat {}, Vol_F {}",
        d.name,
        d.graph.num_vertices(),
        d.graph.num_edges(),
        d.feat_dim,
        fmt_bytes(d.feature_volume_bytes())
    );
    println!(
        "workload {} (~{} arrivals expected)",
        wl.name(),
        wl.expected_arrivals().round() as u64
    );
    let env = hopgnn::coordinator::SimEnv::new(&d, cfg);
    let rep = serve(&env, &wl, &opts);
    println!("serve {} ({spec}): {}", spec.name(), rep.metrics.summary());
    println!("{}", rep.metrics.latency_table().render());
    if env.cfg.cache_enabled() {
        println!(
            "tiers {} (per server): {:.1}% hit rate, {} served from \
             warm tiers, {} evicted",
            env.cfg.effective_tiers().name(),
            rep.metrics.transport.cache_hit_rate() * 100.0,
            fmt_bytes(rep.metrics.transport.cache_hit_bytes),
            fmt_bytes(rep.metrics.transport.cache_evict_bytes),
        );
    }
    if let Err(e) = rep.metrics.validate() {
        eprintln!("{e}");
        return 1;
    }
    0
}

fn cmd_train(args: Vec<String>) -> i32 {
    let cli = Cli::new("hopgnn train", "real PJRT training run")
        .opt("model", "gcn", "gcn|sage|gat (needs a matching artifact)")
        .opt("hidden", "128", "hidden dim (must match an artifact)")
        .opt("vertices", "8000", "synthetic dataset size")
        .opt("epochs", "5", "training epochs")
        .opt("batch", "64", "roots per optimizer step")
        .opt("lr", "0.003", "Adam learning rate")
        .opt("order", "global", "global|lo (batch-composition policy)")
        .opt("seed", "7", "seed");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let model = a.get_or("model", "gcn");
    let hidden = a.get_usize("hidden", 128);
    let spec = match manifest.find(&model, hidden, 128) {
        Some(s) => s,
        None => {
            eprintln!("no artifact for {model} h{hidden} f128; run `make artifacts`");
            return 1;
        }
    };
    let n = a.get_usize("vertices", 8000);
    let d = hopgnn::graph::datasets::load_spec(
        &hopgnn::graph::datasets::DatasetSpec {
            name: "train-cli",
            num_vertices: n,
            num_edges: n * 7,
            feat_dim: 128,
            classes: 10,
            num_communities: (n / 100).max(4),
            train_fraction: 0.4,
            seed: a.get_usize("seed", 7) as u64,
        },
    );
    let engine = match Engine::load(spec) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("engine: {e:#}");
            return 1;
        }
    };
    println!("platform: {}, artifact: {}", engine.platform(), spec.name);
    let cfgs = SampleConfig {
        layers: spec.layers,
        fanout: 10,
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let lr = a.get_f64("lr", 3e-3) as f32;
    let mut trainer = Trainer::new(engine, cfgs, lr, a.get_usize("seed", 7) as u64);
    let policy = if a.get_or("order", "global") == "lo" {
        OrderPolicy::LocalityOpt
    } else {
        OrderPolicy::Global
    };
    let part = partition(&d.graph, 4, PartitionAlgo::MetisLike, 3);
    let epochs = a.get_usize("epochs", 5);
    let batch = a.get_usize("batch", 64);
    for e in 0..epochs {
        let t0 = std::time::Instant::now();
        match trainer.train_epoch(&d, Some(&part), policy, batch) {
            Ok(stats) => println!(
                "epoch {e}: loss {:.4}  train-acc {:.1}%  ({} steps, {})",
                stats.mean_loss,
                stats.train_accuracy * 100.0,
                stats.steps,
                fmt_secs(t0.elapsed().as_secs_f64())
            ),
            Err(err) => {
                eprintln!("epoch {e} failed: {err:#}");
                return 1;
            }
        }
    }
    match trainer.evaluate(&d, &d.val_vertices) {
        Ok(acc) => println!("validation accuracy: {:.2}%", acc * 100.0),
        Err(e) => eprintln!("eval failed: {e:#}"),
    }
    0
}

fn cmd_partition(args: Vec<String>) -> i32 {
    let cli = Cli::new("hopgnn partition", "partition quality report")
        .opt("dataset", "arxiv-s", "dataset name")
        .opt("algo", "metis", "metis|heuristic|hash")
        .opt("servers", "4", "number of parts")
        .opt("seed", "7", "seed");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let d = load(&a.get_or("dataset", "arxiv-s"));
    let algo = PartitionAlgo::from_str(&a.get_or("algo", "metis")).unwrap();
    let k = a.get_usize("servers", 4);
    let t0 = std::time::Instant::now();
    let p = partition(&d.graph, k, algo, a.get_usize("seed", 7) as u64);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "partitioned {} ({} vertices, {} edges) into {k} parts with {} in {}",
        d.name,
        d.graph.num_vertices(),
        d.graph.num_edges(),
        algo.name(),
        fmt_secs(dt)
    );
    println!("edge cut:  {:.1}%", p.edge_cut_fraction(&d.graph) * 100.0);
    println!("balance:   {:.3} (max/mean)", p.balance());
    // micrograph locality sample
    let cfg = SampleConfig {
        layers: 2,
        fanout: 10,
        vmax: 256,
        kind: SamplerKind::NodeWise,
    };
    let mut rng = Rng::new(1);
    let mut acc = 0.0;
    for _ in 0..128 {
        let root = d.train_vertices[rng.below(d.train_vertices.len())];
        acc += sample_micrograph(&d.graph, root, &cfg, &mut rng).locality(&p);
    }
    println!("R_micro:   {:.1}% (128 samples, 2L fanout 10)", acc / 128.0 * 100.0);
    0
}

fn cmd_calibrate(args: Vec<String>) -> i32 {
    let cli = Cli::new("hopgnn calibrate",
                       "measure PJRT step time / effective FLOPs")
        .opt("artifact", "", "artifact name (default: all)");
    let a = match cli.parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let manifest = match Manifest::load_default() {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let filter = a.get_or("artifact", "");
    let mut t = Table::new([
        "artifact", "params", "step time", "eff FLOP/s",
    ]);
    for spec in &manifest.artifacts {
        if !filter.is_empty() && spec.name != filter {
            continue;
        }
        match calibrate_one(spec) {
            Ok((secs, flops)) => t.row([
                spec.name.clone(),
                spec.param_count.to_string(),
                fmt_secs(secs),
                format!("{:.2e}", flops),
            ]),
            Err(e) => {
                eprintln!("{}: {e:#}", spec.name);
            }
        }
    }
    println!("{}", t.render());
    0
}

fn calibrate_one(spec: &hopgnn::runtime::ArtifactSpec)
                 -> hopgnn::util::error::Result<(f64, f64)> {
    use hopgnn::cluster::ModelShape;
    use hopgnn::runtime::{BatchBuffers, ParamSet};
    let d = hopgnn::graph::datasets::load_spec(
        &hopgnn::graph::datasets::DatasetSpec {
            name: "calib",
            num_vertices: 2000,
            num_edges: 14000,
            feat_dim: spec.feat_dim,
            classes: spec.classes,
            num_communities: 25,
            train_fraction: 0.5,
            seed: 99,
        },
    );
    let mut engine = Engine::load(spec)?;
    let params = ParamSet::init(spec, 1);
    let cfg = SampleConfig {
        layers: spec.layers,
        fanout: if spec.layers > 3 { 2 } else { 10 },
        vmax: spec.vmax,
        kind: SamplerKind::NodeWise,
    };
    let mut rng = Rng::new(5);
    let mgs: Vec<_> = (0..spec.batch)
        .map(|i| sample_micrograph(&d.graph, (i * 31) as u32, &cfg, &mut rng))
        .collect();
    let mut buf = BatchBuffers::for_artifact(spec);
    buf.pack(&mgs, &d);
    engine.train_step_b(&params, &buf)?; // warmup
    let mut best = f64::MAX;
    for _ in 0..5 {
        engine.train_step_b(&params, &buf)?;
        best = best.min(engine.last_step_secs);
    }
    let v: u64 = mgs.iter().map(|m| m.num_vertices() as u64).sum();
    let e: u64 = mgs.iter().map(|m| m.edges.len() as u64).sum();
    let family = ModelFamily::from_str(&spec.model).unwrap();
    let shape = ModelShape {
        family,
        layers: spec.layers,
        feat_dim: spec.feat_dim,
        hidden: spec.hidden,
        classes: spec.classes,
    };
    Ok((best, shape.train_flops(v, e) / best))
}

fn cmd_info(_args: Vec<String>) -> i32 {
    println!("datasets (synthetic stand-ins for the paper's Table 2):");
    let mut t = Table::new(["name", "#V", "#E target", "dim", "classes"]);
    for s in &ALL_SPECS {
        t.row([
            s.name.to_string(),
            s.num_vertices.to_string(),
            s.num_edges.to_string(),
            s.feat_dim.to_string(),
            s.classes.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("models: gcn, sage, gat (3L), deepgcn (7L), film (10L)");
    println!(
        "strategies (composable specs): base dgl|p3|naive|hopgnn|lo|ns|\
         dgl-fb with modifiers +/-mg, +/-pg, +ml/+rd/+fa/-merge \
         (e.g. hopgnn+fa-pg); legacy aliases +mg, +pg, rd, fa, ... \
         still parse"
    );
    println!("fabrics: uniform, rack:<k>, hetero-mix, straggler:<s>");
    println!(
        "tiers: kind:cap[:policy]+..+remote over hbm|dram|ssd|remote \
         (e.g. hbm:2g+dram:16g+remote, dram:64m:lru+remote, remote)"
    );
    println!(
        "workloads: poisson:rate=<r>[,dur=..,seed=..], \
         bursty:rate=..,mult=..,dwell=.., diurnal:rate=..,period=..,\
         depth=.. (sim serve / bench sweep --workload)"
    );
    println!("experiments: {}", ALL_EXPERIMENTS.join(", "));
    match Manifest::load_default() {
        Ok(m) => {
            println!("\nartifacts ({}):", m.dir.display());
            for a_ in &m.artifacts {
                println!(
                    "  {} ({} params, batch {}, vmax {})",
                    a_.name, a_.param_count, a_.batch, a_.vmax
                );
            }
        }
        Err(e) => println!("\nartifacts: {e}"),
    }
    let _ = ModelFamily::Gcn;
    0
}
