//! Overall-performance experiments: Fig 11 (shallow models), Fig 12 (deep
//! models), Fig 19 (large graph), Fig 21 (full-batch vs NeutronStar).

use super::{Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use crate::coordinator::neutronstar::{FullBatchMode, NeutronStar};
use super::memo;
use crate::coordinator::{SimEnv, Strategy, StrategySpec};
use crate::metrics::EpochMetrics;
use crate::util::table::{fmt_secs, Table};

fn cfg_for(
    scale: Scale,
    ds: &str,
    model: ModelFamily,
    hidden: usize,
) -> RunConfig {
    let deep = model.default_layers() > 3;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        hidden,
        fanout: if deep { 2 } else { 10 },
        vmax: RunConfig::full_sim_vmax(
            model.default_layers(),
            if deep { 2 } else { 10 },
        ),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        ..Default::default()
    }
}

const HEADLINE: [StrategySpec; 4] = [
    StrategySpec::dgl(),
    StrategySpec::p3(),
    StrategySpec::naive(),
    StrategySpec::hopgnn(),
];

fn faceoff_row(
    t: &mut Table,
    ds: &str,
    label: String,
    cfg: &RunConfig,
) -> (f64, f64) {
    let ms: Vec<EpochMetrics> = HEADLINE
        .iter()
        .map(|&k| memo::run(cfg, k))
        .collect();
    let hop = ms[3].epoch_time;
    let vs_dgl = ms[0].epoch_time / hop;
    let vs_p3 = ms[1].epoch_time / hop;
    t.row([
        ds.to_string(),
        label,
        fmt_secs(ms[0].epoch_time),
        fmt_secs(ms[1].epoch_time),
        fmt_secs(ms[2].epoch_time),
        fmt_secs(hop),
        format!("{vs_dgl:.2}x"),
        format!("{vs_p3:.2}x"),
    ]);
    (vs_dgl, vs_p3)
}

/// Fig 11: shallow models x hidden {16,128} x datasets.
pub fn fig11_shallow(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig11",
        "epoch time, shallow models (paper: HopGNN 1.3-3.1x over DGL, 1.2-4.2x over P3)",
    );
    let mut t = Table::new([
        "dataset", "model", "DGL", "P3", "Naive", "HopGNN", "vs DGL",
        "vs P3",
    ]);
    let datasets = if scale.quick {
        vec!["arxiv-s", "products-s"]
    } else {
        vec!["arxiv-s", "products-s", "uk-s", "in-s"]
    };
    let models = [ModelFamily::Gcn, ModelFamily::Sage, ModelFamily::Gat];
    let hiddens = if scale.quick {
        vec![16usize, 128]
    } else {
        vec![16, 128]
    };
    let mut best_dgl: f64 = 0.0;
    let mut best_p3: f64 = 0.0;
    for ds in &datasets {
        for &model in &models {
            for &h in &hiddens {
                let cfg = cfg_for(scale, ds, model, h);
                let (a, b) = faceoff_row(
                    &mut t,
                    ds,
                    format!("{}({h})", model.name()),
                    &cfg,
                );
                best_dgl = best_dgl.max(a);
                best_p3 = best_p3.max(b);
            }
        }
    }
    r.section("average epoch time (HopGNN steady state)", t);
    r.note(format!(
        "max speedup observed: {best_dgl:.2}x vs DGL, {best_p3:.2}x vs P3 \
         (paper: 3.1x / 4.2x)"
    ));
    r
}

/// Fig 12: deep models (DeepGCN 7L, GNN-FiLM 10L).
pub fn fig12_deep(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig12",
        "epoch time, deep models (paper: HopGNN wins grow with depth; P3 degrades)",
    );
    let mut t = Table::new([
        "dataset", "model", "DGL", "P3", "Naive", "HopGNN", "vs DGL",
        "vs P3",
    ]);
    let datasets = if scale.quick {
        vec!["arxiv-s"]
    } else {
        vec!["uk-s", "in-s"]
    };
    for ds in &datasets {
        for model in [ModelFamily::DeepGcn, ModelFamily::Film] {
            for h in [16usize, 128] {
                let cfg = cfg_for(scale, ds, model, h);
                faceoff_row(&mut t, ds, format!("{}({h})", model.name()),
                            &cfg);
            }
        }
    }
    r.section("average epoch time", t);
    r.note("paper Fig 12: P3's hidden-embedding exchange grows with layer-1 width × hidden; HopGNN unaffected");
    r
}

/// Fig 19: the large graph (it-s): subset of tests.
pub fn fig19_large_graph(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig19",
        "large-graph performance (paper: 1.91x vs DGL, 1.48x vs P3; hit rate 24.4%->92.3%)",
    );
    let ds = if scale.quick { "uk-s" } else { "it-s" };
    let _ = memo::dataset(ds); // warm the cache
    let mut t = Table::new(["model", "system", "epoch", "hit rate%"]);
    for model in [ModelFamily::Gcn, ModelFamily::Gat] {
        let mut cfg = cfg_for(scale, ds, model, 128);
        if scale.quick {
            cfg.max_iterations = Some(2);
        }
        for kind in [StrategySpec::dgl(), StrategySpec::p3(), StrategySpec::hopgnn()]
        {
            let m = memo::run(&cfg, kind);
            t.row([
                model.name().to_string(),
                kind.name(),
                fmt_secs(m.epoch_time),
                format!("{:.1}", (1.0 - m.miss_rate()) * 100.0),
            ]);
        }
    }
    r.section(format!("epoch time on {ds}"), t);
    r.note("paper Fig 19: local hit rate rises from 24.4% (DGL) to 92.3% (HopGNN)");
    r
}

/// Fig 21: full-batch comparison with NeutronStar (sampling disabled).
pub fn fig21_fullbatch(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig21",
        "full-batch training (paper: HopGNN 1.05-1.82x over NeutronStar)",
    );
    let mut t = Table::new(["dataset", "model", "system", "epoch", "bytes"]);
    let datasets = if scale.quick {
        vec!["arxiv-s"]
    } else {
        vec!["arxiv-s", "products-s", "uk-s"]
    };
    for ds in &datasets {
        let d = memo::dataset(ds);
        for model in [ModelFamily::Gcn, ModelFamily::Gat] {
            let cfg = cfg_for(scale, ds, model, 128);
            for mode in [
                FullBatchMode::DglFb,
                FullBatchMode::Hybrid,
                FullBatchMode::HopFb,
            ] {
                let mut env = SimEnv::new(&d, cfg.clone());
                let mut s = NeutronStar::with_mode(mode);
                let m = s.run_epoch(&mut env);
                t.row([
                    ds.to_string(),
                    model.name().to_string(),
                    s.name().to_string(),
                    fmt_secs(m.epoch_time),
                    crate::util::table::fmt_bytes(m.total_bytes()),
                ]);
            }
        }
    }
    r.section("full-batch epoch time", t);
    r.note("paper Fig 21 ordering: DGL-FB > NeutronStar > HopGNN");
    r
}
