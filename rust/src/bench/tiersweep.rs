//! Tier sweep: what does the multi-tier feature store buy — per stack
//! structure, per placement policy, per strategy, per fabric topology?
//!
//! Runs the same fixed-schedule strategies as `cachesweep` (their
//! gather streams are stack-invariant, so hit rates are comparable
//! column-to-column) over a ladder of [`TierSpec`] stacks: the
//! remote-only parity baseline, the legacy single `dram` cache, a
//! two-level `hbm+dram` hierarchy under both LRU promotion and static
//! degree pinning, and a `dram+ssd` stack that spills onto priced
//! flash — each across `uniform` and `rack:2` fabrics, because the
//! slower the fabric, the more a fast-tier hit is worth.
//!
//! Declared as a fabric × strategy × stack grid on the sweep engine
//! ([`super::sweep`]); the `remote` column is the configuration
//! `tests/tier_parity.rs` locks bit-identical to the uncached driver.

use super::cachesweep::SWEEP_STRATEGIES;
use super::sweep::{Axis, SweepSpec};
use super::{memo, Report, Scale};
use crate::cluster::{FabricSpec, ModelFamily, TransferKind};
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::featstore::tier::{TierKind, TierSpec};
use crate::metrics::EpochMetrics;
use crate::util::table::{fmt_bytes, fmt_secs, Table};

/// Fabric topologies the stacks are priced under.
pub const SWEEP_FABRICS: [FabricSpec; 2] =
    [FabricSpec::Uniform, FabricSpec::Rack { racks: 2 }];

/// The stack ladder: structure × policy folded into spec strings
/// (sweep axes patch the whole `tiers` key, so each cell is one
/// complete stack).
pub fn stack_specs(scale: Scale) -> Vec<TierSpec> {
    let raw: &[&str] = if scale.quick {
        &[
            "remote",
            "dram:8m:lru+remote",
            "hbm:2m:lru+dram:8m:lru+remote",
            "hbm:2m:degree+dram:8m:degree+remote",
            "dram:2m:lru+ssd:8m:lru+remote",
        ]
    } else {
        &[
            "remote",
            "dram:64m:lru+remote",
            "hbm:16m:lru+dram:64m:lru+remote",
            "hbm:16m:degree+dram:64m:degree+remote",
            "dram:16m:lru+ssd:64m:lru+remote",
        ]
    };
    raw.iter()
        .map(|s| TierSpec::parse(s).expect("static tier specs parse"))
        .collect()
}

fn cfg_for(scale: Scale, ds: &str) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        overlap: true,
        ..Default::default()
    }
}

/// One sweep cell: (fabric, stack, strategy) -> averaged epoch.
pub fn sweep_cell(
    scale: Scale,
    ds: &str,
    fabric: FabricSpec,
    tiers: &TierSpec,
    spec: StrategySpec,
) -> EpochMetrics {
    let mut cfg = cfg_for(scale, ds);
    cfg.fabric = fabric;
    cfg.tiers = Some(tiers.clone());
    memo::run(&cfg, spec)
}

/// `hits_at`-style per-kind cache-tier counts as a compact
/// `hbm/dram/ssd` cell.
fn fmt_cache_tier_hits(m: &EpochMetrics) -> String {
    format!(
        "{}/{}/{}",
        m.tier_hits[TierKind::Hbm.index()],
        m.tier_hits[TierKind::Dram.index()],
        m.tier_hits[TierKind::Ssd.index()],
    )
}

/// The `tiersweep` experiment: per-tier hit split, movement bytes, and
/// epoch time per (fabric, strategy, stack).
pub fn tiersweep(scale: Scale) -> Report {
    let mut r = Report::new(
        "tiersweep",
        "multi-tier feature store: hit split and epoch time per stack",
    );
    let ds = if scale.quick { "arxiv-s" } else { "products-s" };
    let stacks = stack_specs(scale);
    let grid = SweepSpec::new(cfg_for(scale, ds), StrategySpec::dgl())
        .axis(Axis::fabrics(&SWEEP_FABRICS))
        .axis(Axis::strategies(&SWEEP_STRATEGIES))
        .axis(Axis::tiers(&stacks))
        .run()
        .expect("tiersweep grid is statically valid");
    for (fi, fabric) in SWEEP_FABRICS.iter().enumerate() {
        let mut t = Table::new([
            "system",
            "tiers",
            "hit rate",
            "hbm/dram/ssd hits",
            "promoted",
            "evicted",
            "feat moved",
            "epoch",
        ]);
        for (ki, spec) in SWEEP_STRATEGIES.iter().enumerate() {
            for (ti, stack) in stacks.iter().enumerate() {
                let m = grid.metrics(&[fi, ki, ti]);
                let promoted: u64 = m.tier_promote_bytes.iter().sum();
                t.row([
                    spec.name(),
                    stack.name(),
                    format!("{:.1}%", m.cache_hit_rate() * 100.0),
                    fmt_cache_tier_hits(m),
                    fmt_bytes(promoted),
                    fmt_bytes(m.cache_evict_bytes),
                    fmt_bytes(m.bytes(TransferKind::Feature)),
                    fmt_secs(m.epoch_time),
                ]);
            }
        }
        r.section(
            format!(
                "fabric {} (GCN on {ds}, 4 servers, overlap on)",
                fabric.name()
            ),
            t,
        );
    }
    r.note(
        "hit rate counts every cache-tier hit over remote feature \
         requests; the hbm/dram/ssd split shows *where* the hits \
         landed (hbm hits are free, dram hits pay staging, ssd hits \
         pay staging + the flash read)",
    );
    r.note(
        "the 'remote' stack is the parity configuration (no cache \
         tiers) locked bit-identical to the uncached driver by \
         tests/tier_parity.rs; the dram-only stack is the legacy \
         --cache/--cache-mb pair under the tier grammar",
    );
    r.note(
        "promoted = bytes moved up the stack by LRU placement on a \
         lower-tier hit; static degree stacks pin disjoint ranking \
         slices and never promote",
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale {
            epochs: 2,
            max_iterations: Some(2),
            batch: 128,
            quick: true,
        }
    }

    #[test]
    fn report_renders_every_stack_and_fabric() {
        let r = tiersweep(tiny_scale());
        let s = r.render();
        for stack in stack_specs(tiny_scale()) {
            assert!(s.contains(&stack.name()), "{s}");
        }
        for fabric in SWEEP_FABRICS {
            assert!(s.contains(&fabric.name()), "{s}");
        }
        assert!(s.contains("hbm/dram/ssd hits"), "{s}");
    }

    #[test]
    fn remote_only_stack_serves_nothing() {
        let scale = tiny_scale();
        let m = sweep_cell(
            scale,
            "arxiv-s",
            FabricSpec::Uniform,
            &TierSpec::remote_only(),
            StrategySpec::dgl(),
        );
        assert_eq!(m.cache_hits, 0);
        assert_eq!(m.cache_hit_bytes, 0);
        for kind in [TierKind::Hbm, TierKind::Dram, TierKind::Ssd] {
            assert_eq!(m.tier_hits[kind.index()], 0, "{}", kind.name());
        }
        // everything lands on the remote backstop
        assert_eq!(
            m.tier_hit_bytes[TierKind::Remote.index()],
            m.cache_miss_bytes
        );
    }

    #[test]
    fn requested_bytes_are_stack_invariant() {
        // byte conservation: the gather stream is fixed per strategy,
        // so hit + miss bytes cannot depend on the stack
        let scale = tiny_scale();
        let spec = StrategySpec::dgl();
        let stacks = stack_specs(scale);
        let base = sweep_cell(
            scale,
            "arxiv-s",
            FabricSpec::Uniform,
            &stacks[0],
            spec,
        );
        let requested = base.cache_hit_bytes + base.cache_miss_bytes;
        for stack in &stacks[1..] {
            let m = sweep_cell(
                scale,
                "arxiv-s",
                FabricSpec::Uniform,
                stack,
                spec,
            );
            assert_eq!(
                m.cache_hit_bytes + m.cache_miss_bytes,
                requested,
                "{}: requested bytes must be stack-invariant",
                stack.name()
            );
            // only misses touch the fabric
            assert_eq!(m.cache_miss_bytes, m.bytes(TransferKind::Feature));
            // per-tier hit bytes partition the request volume
            let tier_sum: u64 = m.tier_hit_bytes.iter().sum();
            assert_eq!(tier_sum, requested, "{}", stack.name());
            assert!(m.cache_hits > 0, "{}: cached stack must hit", stack.name());
        }
    }
}
