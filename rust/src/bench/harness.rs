//! Micro-benchmark timing harness (offline replacement for criterion):
//! warmup + timed iterations, reporting median ± MAD.

use crate::util::stats::{mad, median};
use crate::util::table::fmt_secs;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_secs: f64,
    pub mad_secs: f64,
    pub iters: usize,
}

impl BenchResult {
    pub fn summary(&self) -> String {
        format!(
            "{:<40} {:>12} ± {:<10} ({} iters)",
            self.name,
            fmt_secs(self.median_secs),
            fmt_secs(self.mad_secs),
            self.iters
        )
    }

    /// throughput in ops/sec given `n` items per iteration
    pub fn per_sec(&self, n: usize) -> f64 {
        n as f64 / self.median_secs
    }
}

/// Time `f` with automatic iteration-count calibration: aims for
/// ~`target_secs` of total measurement after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, target_secs: f64, mut f: F) -> BenchResult {
    // warmup + calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        median_secs: median(&samples),
        mad_secs: mad(&samples),
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut x = 0u64;
        let r = bench("spin", 0.02, || {
            for i in 0..10_000u64 {
                x = x.wrapping_add(i * i);
            }
        });
        assert!(r.median_secs > 0.0);
        assert!(r.iters >= 3);
        assert!(r.summary().contains("spin"));
        assert!(x != 42); // keep the side effect alive
    }
}
