//! `scale` — simulator throughput across graph sizes: how many
//! simulated seconds of distributed training does one wall-clock second
//! of host CPU buy, per strategy, as the graph grows?
//!
//! This is the harness's own speedometer, not a paper figure. Each cell
//! is a (synth dataset, strategy) point run through the sweep engine;
//! the headline column is **sim-s/wall-s** =
//! `epoch_time × epochs / wall_secs`, computed from
//! [`SweepCell::wall_secs`](super::sweep::SweepCell::wall_secs) — the
//! one intentionally non-deterministic field in a sweep. The `synth:`
//! datasets exercise the memory-bounded chunk-streamed generator
//! (`graph::generator::community_graph_chunked`), so the full run
//! doubles as an end-to-end check of that path at sizes the named
//! suite never reaches.

use super::sweep::{Axis, SweepSpec};
use super::{Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::util::table::{fmt_secs, Table};

/// Strategy pair: the paper's baseline and headline systems.
pub const SCALE_STRATEGIES: [StrategySpec; 2] =
    [StrategySpec::dgl(), StrategySpec::hopgnn()];

/// Graph-size ladder (`synth:` specs, smallest first). Quick stays
/// test-suite sized; full climbs to it-s scale and beyond.
pub fn size_ladder(scale: Scale) -> Vec<&'static str> {
    if scale.quick {
        vec![
            "synth:v=2000,e=8000,d=32,c=4,seed=21",
            "synth:v=4000,e=16000,d=32,c=4,seed=21",
        ]
    } else {
        vec![
            "synth:v=6e4,e=4.2e5,seed=21",
            "synth:v=2.5e5,e=2e6,seed=21",
            "synth:v=5e5,e=5e6,seed=21",
        ]
    }
}

fn base_cfg(scale: Scale) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        overlap: true,
        ..Default::default()
    }
}

/// The `scale` experiment: simulated-seconds-per-wall-second over a
/// graph-size × strategy grid.
pub fn scalebench(scale: Scale) -> Report {
    let mut r = Report::new(
        "scale",
        "simulator throughput vs graph size (sim-s per wall-s)",
    );
    let sizes = size_ladder(scale);
    let grid = SweepSpec::new(base_cfg(scale), StrategySpec::dgl())
        .axis(Axis::key("dataset", &sizes))
        .axis(Axis::strategies(&SCALE_STRATEGIES))
        .run()
        .expect("scale grid is statically valid");
    let mut t = Table::new([
        "dataset",
        "strategy",
        "sim epoch",
        "cell wall",
        "sim-s/wall-s",
    ]);
    for cell in &grid.cells {
        let epochs = cell.cfg.epochs as f64;
        let sim_secs = cell.metrics.epoch_time * epochs;
        t.row([
            cell.cfg.dataset.clone(),
            cell.strategy.name(),
            fmt_secs(cell.metrics.epoch_time),
            fmt_secs(cell.wall_secs),
            format!("{:.1}", sim_secs / cell.wall_secs.max(1e-9)),
        ]);
    }
    r.section(
        format!(
            "{} sizes x {} strategies (GCN, 4 servers, overlap on)",
            sizes.len(),
            SCALE_STRATEGIES.len()
        ),
        t,
    );
    r.note(
        "sim-s/wall-s = simulated epoch time x epochs / host wall-clock \
         for the cell; wall-clock includes the one-time dataset \
         generation + partition for whichever cell first touches each \
         graph, so the second strategy on a dataset reads higher",
    );
    r.note(
        "datasets are synth: specs built by the chunk-streamed generator \
         (graph::generator), so this experiment also end-to-ends the \
         memory-bounded path; wall columns are machine-dependent and \
         excluded from parity locks",
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale {
            epochs: 2,
            max_iterations: Some(2),
            batch: 128,
            quick: true,
        }
    }

    #[test]
    fn report_renders_every_size_and_strategy() {
        let r = scalebench(tiny_scale());
        let s = r.render();
        for ds in size_ladder(tiny_scale()) {
            assert!(s.contains(ds), "{s}");
        }
        for spec in SCALE_STRATEGIES {
            assert!(s.contains(&spec.name()), "{s}");
        }
        assert!(s.contains("sim-s/wall-s"), "{s}");
    }

    #[test]
    fn wall_secs_is_populated() {
        let grid = SweepSpec::new(base_cfg(tiny_scale()), StrategySpec::dgl())
            .axis(Axis::key("dataset", &size_ladder(tiny_scale())[..1]))
            .axis(Axis::strategies(&SCALE_STRATEGIES))
            .run()
            .unwrap();
        for cell in &grid.cells {
            assert!(cell.wall_secs > 0.0);
            assert!(cell.metrics.epoch_time > 0.0);
        }
    }
}
