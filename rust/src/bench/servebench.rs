//! The `serve` experiment: online inference serving over the sweep
//! engine's grid — what tail latency and sustained QPS does a request
//! stream see per (workload × tier stack × fabric × strategy base)?
//!
//! The grid is *declared* on [`super::sweep::SweepSpec`] (same axes,
//! same fail-fast expansion/validation, same `--jobs` budget split as
//! the training sweeps) but *executed* through the serving engine
//! ([`crate::serve::engine`]) instead of the epoch runner: each cell
//! generates its workload's request schedule and serves it through
//! per-server lanes with warm tier stacks. The strategy axis pins the
//! partitioner the serving fleet inherited from training (P³ forces
//! hash partitioning, everything else keeps the config's partitioner)
//! — locality at serve time is a property of how the graph was placed.
//!
//! Every cell's report must pass [`crate::serve::ServeMetrics::validate`]:
//! a cell that drops requests at the admission queue fails the whole
//! experiment rather than reporting a truncated (and flattering)
//! latency distribution.

use super::sweep::{Axis, AxisValue, ExpandedCell, SweepSpec};
use super::tiersweep::SWEEP_FABRICS;
use super::{memo, Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use crate::coordinator::{SimEnv, StrategySpec};
use crate::featstore::tier::TierSpec;
use crate::serve::{serve, ServeOpts, ServeReport, WorkloadSpec};
use crate::util::pool;
use crate::util::table::{fmt_secs, Table};

/// Strategy bases the serving fleet can inherit its placement from:
/// the DGL baseline keeps the config's locality-aware partitioner;
/// P³ forces hash partitioning, so the same request stream pays more
/// remote gathers.
pub const SERVE_STRATEGIES: [StrategySpec; 2] =
    [StrategySpec::dgl(), StrategySpec::p3()];

/// The workload ladder: steady Poisson at two rates, an MMPP burst
/// train, and a diurnal sinusoid (quick mode trims rates and duration
/// for CI).
pub fn workload_specs(scale: Scale) -> Vec<WorkloadSpec> {
    let raw: &[&str] = if scale.quick {
        &[
            "poisson:rate=200,dur=0.2",
            "bursty:rate=200,mult=8,dwell=0.02,dur=0.2",
        ]
    } else {
        &[
            "poisson:rate=500,dur=1",
            "poisson:rate=2000,dur=1",
            "bursty:rate=500,mult=8,dwell=0.05,dur=1",
            "diurnal:rate=500,period=0.5,depth=0.8,dur=1",
        ]
    };
    raw.iter()
        .map(|s| WorkloadSpec::parse(s).expect("static workload specs parse"))
        .collect()
}

/// Tier-stack ladder for serving: the remote-only baseline, the plain
/// DRAM cache, and (full scale) a two-level degree-pinned hierarchy.
pub fn serve_stacks(scale: Scale) -> Vec<TierSpec> {
    let raw: &[&str] = if scale.quick {
        &["remote", "dram:8m:lru+remote"]
    } else {
        &[
            "remote",
            "dram:64m:lru+remote",
            "hbm:16m:degree+dram:64m:degree+remote",
        ]
    };
    raw.iter()
        .map(|s| TierSpec::parse(s).expect("static tier specs parse"))
        .collect()
}

/// Workload axis: one cell per spec, patched through the `workload`
/// config key (so a bad spec fails the sweep at expansion, like every
/// other axis).
pub fn workload_axis(specs: &[WorkloadSpec]) -> Axis {
    Axis::patches(
        "workload",
        specs
            .iter()
            .map(|w| (w.name(), vec![("workload".to_string(), w.name())]))
            .collect(),
    )
}

fn cfg_for(scale: Scale, ds: &str) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        ..Default::default()
    }
}

/// Serve one expanded cell: memoized dataset + partition (the strategy
/// base's preferred partitioner wins, as in [`memo::run`]), then the
/// full generate-and-serve pipeline on the cell's workload.
pub fn serve_cell(
    cfg: &RunConfig,
    strategy: StrategySpec,
    opts: &ServeOpts,
) -> ServeReport {
    let d = memo::dataset(&cfg.dataset);
    let mut cfg = cfg.clone();
    if let Some(pa) = strategy.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let part = memo::partition_for(
        d,
        cfg.num_servers,
        cfg.partition_algo,
        cfg.seed ^ 0x9A27,
    );
    let wl = cfg
        .workload
        .expect("serve cell has a workload (validated at expansion)");
    let env = SimEnv::with_partition(d, cfg, part);
    serve(&env, &wl, opts)
}

/// Expand a serve sweep and execute every cell through the serving
/// engine. Reports come back in the sweep's row-major grid order.
///
/// Same `--jobs` discipline as [`SweepSpec::run`]: the budget splits
/// between cell runners and each cell's serve lanes
/// ([`pool::LaneAllowanceGuard`]), so `--jobs 1` and `--jobs N` grids
/// are bit-identical (`tests/serve_parity.rs`).
pub fn run_serve_grid(
    spec: &SweepSpec,
    opts: &ServeOpts,
) -> Result<(Vec<ExpandedCell>, Vec<ServeReport>), String> {
    let expanded = spec.expand()?;
    for (index, _, cfg) in &expanded {
        if cfg.workload.is_none() {
            return Err(format!(
                "serve sweep cell {} has no workload — set `workload =` \
                 in the base config or add a workload axis \
                 (--workload poisson:rate=500,...)",
                cell_label(&spec.axes, index)
            ));
        }
    }
    let budget =
        pool::resolve_jobs(spec.jobs.unwrap_or_else(pool::thread_budget));
    let runners = budget.min(expanded.len()).max(1);
    let lane_share = budget / runners;
    let reports = pool::run_indexed(expanded.len(), runners, |i| {
        let _lanes = pool::LaneAllowanceGuard::set(lane_share);
        let (_, strategy, cfg) = &expanded[i];
        serve_cell(cfg, *strategy, opts)
    });
    Ok((expanded, reports))
}

/// Human label for one grid cell (axis labels joined in axis order).
pub fn cell_label(axes: &[Axis], index: &[usize]) -> String {
    index
        .iter()
        .enumerate()
        .map(|(d, &i)| axes[d].label(i))
        .collect::<Vec<_>>()
        .join(" x ")
}

/// One row per cell: axis labels plus the serving headline — tail
/// quantiles, sustained QPS, coalescing, cache contribution, drops.
/// Shared by the `serve` experiment and the `bench sweep --workload`
/// CLI path.
pub fn serve_table(
    axes: &[Axis],
    expanded: &[ExpandedCell],
    reports: &[ServeReport],
) -> Table {
    let has_strategy_axis = axes
        .iter()
        .any(|a| matches!(a.values.first(), Some(AxisValue::Strategy(_))));
    let mut headers: Vec<String> = Vec::new();
    if !has_strategy_axis {
        headers.push("strategy".to_string());
    }
    headers.extend(axes.iter().map(|a| a.name.clone()));
    for h in [
        "served", "p50", "p95", "p99", "mean", "qps", "req/batch",
        "hit rate", "dropped",
    ] {
        headers.push(h.to_string());
    }
    let mut t = Table::new(headers);
    for ((index, strategy, _), rep) in expanded.iter().zip(reports) {
        let m = &rep.metrics;
        let mut row: Vec<String> = Vec::new();
        if !has_strategy_axis {
            row.push(strategy.name());
        }
        for (d, &i) in index.iter().enumerate() {
            row.push(axes[d].label(i));
        }
        row.push(format!("{}/{}", m.served, m.offered));
        row.push(fmt_secs(m.p50()));
        row.push(fmt_secs(m.p95()));
        row.push(fmt_secs(m.p99()));
        row.push(fmt_secs(m.mean_latency()));
        row.push(format!("{:.0}", m.qps()));
        row.push(format!("{:.1}", m.mean_batch()));
        row.push(format!(
            "{:.1}%",
            m.transport.cache_hit_rate() * 100.0
        ));
        row.push(m.dropped.to_string());
        t.row(row);
    }
    t
}

/// The `serve` experiment: tail latency + QPS per (workload × stack ×
/// fabric × strategy base) cell, plus the decomposition of where a
/// request's time goes on the richest stack.
pub fn servebench(scale: Scale) -> Result<Report, String> {
    let ds = if scale.quick { "arxiv-s" } else { "products-s" };
    let stacks = serve_stacks(scale);
    let workloads = workload_specs(scale);
    let opts = ServeOpts::default();
    let spec = SweepSpec::new(cfg_for(scale, ds), StrategySpec::dgl())
        .axis(Axis::fabrics(&SWEEP_FABRICS))
        .axis(Axis::strategies(&SERVE_STRATEGIES))
        .axis(Axis::tiers(&stacks))
        .axis(workload_axis(&workloads));
    let (expanded, reports) = run_serve_grid(&spec, &opts)?;
    // a dropped request is a truncated latency distribution, not a
    // result — fail the experiment with the offending cell named
    for ((index, _, _), rep) in expanded.iter().zip(&reports) {
        rep.metrics.validate().map_err(|e| {
            format!("serve cell {}: {e}", cell_label(&spec.axes, index))
        })?;
    }
    let mut r = Report::new(
        "serve",
        "online serving: tail latency and sustained QPS per cell",
    );
    r.section(
        format!("latency / throughput grid (GCN on {ds}, 4 servers)"),
        serve_table(&spec.axes, &expanded, &reports),
    );
    // decomposition on the representative cell: uniform fabric, DGL
    // placement, richest stack, first workload
    let rep_index = vec![0, 0, stacks.len() - 1, 0];
    let rep_flat = (stacks.len() - 1) * workloads.len();
    r.section(
        format!(
            "latency decomposition — {}",
            cell_label(&spec.axes, &rep_index)
        ),
        reports[rep_flat].metrics.latency_table(),
    );
    r.note(
        "latency = queue (admission wait + micro-batch window) + \
         gather (sampling + tier walk + priced feature transfers) + \
         compute (forward-only on the home server's speed multiplier); \
         p50/p95/p99 are streaming P2 estimates over request totals",
    );
    r.note(
        "qps is sustained throughput: served requests over the stream \
         makespan, not the offered arrival rate — an overloaded cell \
         would fall behind its workload before it ever drops",
    );
    r.note(
        "the strategy axis pins the partitioner the fleet inherited \
         from training (P3 = hash): worse placement shows up directly \
         as gather-heavy tails on the remote-only stack",
    );
    r.note(
        "tier stacks persist across the run (early requests warm the \
         cache the tail is served from); every cell passed \
         ServeMetrics::validate — zero requests dropped or unaccounted",
    );
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale {
            epochs: 2,
            max_iterations: Some(2),
            batch: 128,
            quick: true,
        }
    }

    fn tiny_spec(workload: &str, tiers: &str) -> SweepSpec {
        let mut cfg = cfg_for(tiny_scale(), "arxiv-s");
        cfg.workload =
            Some(WorkloadSpec::parse(workload).expect("workload parses"));
        cfg.tiers = Some(TierSpec::parse(tiers).expect("tiers parse"));
        SweepSpec::new(cfg, StrategySpec::dgl())
    }

    #[test]
    fn report_renders_every_axis_value() {
        let r = servebench(tiny_scale()).expect("quick serve bench runs");
        let s = r.render();
        for wl in workload_specs(tiny_scale()) {
            assert!(s.contains(&wl.name()), "{s}");
        }
        for stack in serve_stacks(tiny_scale()) {
            assert!(s.contains(&stack.name()), "{s}");
        }
        for fabric in SWEEP_FABRICS {
            assert!(s.contains(&fabric.name()), "{s}");
        }
        assert!(s.contains("p99"), "{s}");
        assert!(s.contains("qps"), "{s}");
        assert!(s.contains("latency decomposition"), "{s}");
    }

    #[test]
    fn grid_cell_matches_direct_serve() {
        let spec = tiny_spec("poisson:rate=300,dur=0.1,seed=5", "dram:8m:lru+remote");
        let (expanded, reports) =
            run_serve_grid(&spec, &ServeOpts::default()).unwrap();
        assert_eq!(expanded.len(), 1);
        let direct = serve_cell(
            &expanded[0].2,
            expanded[0].1,
            &ServeOpts::default(),
        );
        assert_eq!(reports[0].metrics.digest(), direct.metrics.digest());
        assert!(reports[0].metrics.served > 0);
    }

    #[test]
    fn jobs_budget_does_not_change_the_grid() {
        let spec = |jobs: usize| {
            tiny_spec("bursty:rate=400,mult=4,dwell=0.02,dur=0.1", "remote")
                .axis(Axis::strategies(&SERVE_STRATEGIES))
                .jobs(jobs)
        };
        let (_, a) = run_serve_grid(&spec(1), &ServeOpts::default()).unwrap();
        let (_, b) = run_serve_grid(&spec(4), &ServeOpts::default()).unwrap();
        assert_eq!(a.len(), b.len());
        for (ra, rb) in a.iter().zip(&b) {
            assert_eq!(ra.metrics.digest(), rb.metrics.digest());
        }
    }

    #[test]
    fn cells_without_a_workload_fail_fast() {
        let mut cfg = cfg_for(tiny_scale(), "arxiv-s");
        cfg.tiers = Some(TierSpec::remote_only());
        let spec = SweepSpec::new(cfg, StrategySpec::dgl());
        let e = run_serve_grid(&spec, &ServeOpts::default()).unwrap_err();
        assert!(e.contains("workload"), "{e}");
    }

    #[test]
    fn placement_changes_what_serving_pays() {
        // same stream, hash vs locality partition: byte movement differs
        let spec = tiny_spec("poisson:rate=300,dur=0.1,seed=9", "remote")
            .axis(Axis::strategies(&SERVE_STRATEGIES));
        let (_, reports) =
            run_serve_grid(&spec, &ServeOpts::default()).unwrap();
        assert_eq!(reports.len(), 2);
        assert_ne!(
            reports[0].metrics.transport.total_bytes(),
            reports[1].metrics.transport.total_bytes(),
            "hash placement must price differently from locality placement"
        );
    }
}
