//! Overlap sweep: what does gather/compute pipelining buy each system?
//!
//! Runs every communication-bound strategy with the driver's overlap
//! mode off and on (same seeds, byte-identical traffic) and reports the
//! epoch-time delta plus how much transfer time was hidden behind
//! compute. P³'s push-pull and HopGNN's pre-gather are the interesting
//! rows: P³ is a pipelining design and HopGNN's §5.2 pre-gather becomes
//! a true prefetch; DGL models a prefetching dataloader. Naive-FC is
//! the control — its serial walk cannot overlap anything.
//!
//! Declared as a strategy × overlap grid on the sweep engine
//! ([`super::sweep`]); the table is the grid read row-major.

use super::sweep::{Axis, SweepSpec};
use super::{Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::util::table::{fmt_secs, Table};

fn cfg_for(scale: Scale, ds: &str) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        ..Default::default()
    }
}

/// The `overlap` experiment: serial vs overlapped epoch time per
/// strategy.
pub fn overlap_sweep(scale: Scale) -> Report {
    let mut r = Report::new(
        "overlap",
        "gather/compute overlap: epoch time with pipelining off vs on",
    );
    let ds = if scale.quick { "arxiv-s" } else { "products-s" };
    let specs = [
        StrategySpec::dgl(),
        StrategySpec::p3(),
        StrategySpec::naive(),
        StrategySpec::hopgnn_mg(),
        StrategySpec::hopgnn_mg_pg(),
        StrategySpec::hopgnn(),
    ];
    let grid = SweepSpec::new(cfg_for(scale, ds), StrategySpec::hopgnn())
        .axis(Axis::strategies(&specs))
        .axis(Axis::overlap(&[false, true]))
        .run()
        .expect("overlap grid is statically valid");
    let mut t = Table::new([
        "system", "serial", "overlapped", "speedup", "hidden/epoch",
    ]);
    for (i, spec) in specs.iter().enumerate() {
        let serial = grid.metrics(&[i, 0]);
        let over = grid.metrics(&[i, 1]);
        // overlap never changes what a given schedule moves — but the
        // merge controller adapts its schedule on measured epoch times,
        // so the adapting strategies may legitimately take different
        // merge trajectories (and byte totals) across >2 epochs. Hard
        // byte parity is asserted only for fixed-schedule strategies.
        if !spec.adapts_across_epochs() {
            assert_eq!(
                serial.total_bytes(),
                over.total_bytes(),
                "{}: overlap changed byte accounting",
                spec.name()
            );
        }
        t.row([
            spec.name(),
            fmt_secs(serial.epoch_time),
            fmt_secs(over.epoch_time),
            format!("{:.2}x", serial.epoch_time / over.epoch_time),
            fmt_secs(over.time_overlap_hidden),
        ]);
    }
    r.section(format!("GCN on {ds}, 4 servers"), t);
    r.note(
        "overlap defers async-flagged transfers into a per-server pending \
         stream drained by compute and barrier idle time; bytes moved are \
         identical in both modes (asserted per row)",
    );
    r.note(
        "Naive-FC is the control: its migration walk is serial, so its \
         two columns must match",
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlap_report_renders() {
        let r = overlap_sweep(Scale::quick());
        let s = r.render();
        assert!(s.contains("overlapped"), "{s}");
        assert!(s.contains("HopGNN"), "{s}");
    }
}
