//! Table 3: model accuracy under the three training orders, trained for
//! real through the PJRT artifacts (the only experiment whose result is
//! numerics, not coordination). HopGNN's batches are the same global-
//! random batches as DGL's (gradient accumulation is transparent), so it
//! runs the same Global order with a different sampling seed; LO runs the
//! biased per-partition order.

use super::{Report, Scale};
use crate::graph::datasets::{load_spec, DatasetSpec};
use crate::partition::{partition, PartitionAlgo};
use crate::runtime::Manifest;
use crate::train::accuracy::train_and_eval;
use crate::train::OrderPolicy;
use crate::util::table::Table;

/// Scaled-down arxiv analogue matching the f128 artifacts.
fn arxiv_numeric(quick: bool) -> DatasetSpec {
    DatasetSpec {
        name: "arxiv-numeric",
        num_vertices: if quick { 2_000 } else { 8_000 },
        num_edges: if quick { 14_000 } else { 56_000 },
        feat_dim: 128,
        classes: 10,
        num_communities: if quick { 25 } else { 80 },
        train_fraction: 0.4,
        seed: 1101,
    }
}

pub fn table3_accuracy(scale: Scale) -> Result<Report, String> {
    let manifest = Manifest::load_default().map_err(|e| e.to_string())?;
    let spec = arxiv_numeric(scale.quick);
    let d = load_spec(&spec);
    let p = partition(&d.graph, 4, PartitionAlgo::MetisLike, 3);
    let epochs = if scale.quick { 2 } else { 6 };
    let batch = 64;

    let mut r = Report::new(
        "table3",
        "model accuracy: DGL vs LO vs HopGNN (paper: HopGNN == DGL, LO drops)",
    );
    let mut t = Table::new([
        "model", "DGL acc%", "LO acc%", "LO drop", "HopGNN acc%",
        "HopGNN drop",
    ]);
    let models = if scale.quick {
        vec!["gcn"]
    } else {
        vec!["gcn", "sage", "gat"]
    };
    for model in models {
        let dgl = train_and_eval(
            &d,
            None,
            &manifest,
            model,
            128,
            OrderPolicy::Global,
            epochs,
            batch,
            7,
        )
        .map_err(|e| e.to_string())?;
        let lo = train_and_eval(
            &d,
            Some(&p),
            &manifest,
            model,
            128,
            OrderPolicy::LocalityOpt,
            epochs,
            batch,
            7,
        )
        .map_err(|e| e.to_string())?;
        // HopGNN: same global order, different sampling seed (migration
        // changes *where* training happens, never which roots are drawn)
        let hop = train_and_eval(
            &d,
            None,
            &manifest,
            model,
            128,
            OrderPolicy::Global,
            epochs,
            batch,
            8,
        )
        .map_err(|e| e.to_string())?;
        let fmt_drop = |base: f64, x: f64| {
            let drop = (base - x) * 100.0;
            if drop.abs() < 0.1 {
                "S".to_string()
            } else {
                format!("{drop:.2}")
            }
        };
        t.row([
            model.to_string(),
            format!("{:.2}", dgl.val_accuracy * 100.0),
            format!("{:.2}", lo.val_accuracy * 100.0),
            fmt_drop(dgl.val_accuracy, lo.val_accuracy),
            format!("{:.2}", hop.val_accuracy * 100.0),
            fmt_drop(dgl.val_accuracy, hop.val_accuracy),
        ]);
    }
    r.section(
        format!(
            "validation accuracy after {epochs} epochs (real PJRT training, \
             {} vertices)",
            d.graph.num_vertices()
        ),
        t,
    );
    r.note("\"S\" = same within 0.1% (the paper's notation)");
    r.note("LO's bias: per-partition shards cycle independently, oversampling small shards and correlating batches with communities");
    Ok(r)
}
