//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (§3 + §7). Each experiment id (DESIGN.md §5) maps to one
//! function returning a [`Report`]; `hopgnn reproduce --exp <id|all>`
//! prints it and writes `reports/<id>.md`.

pub mod ablation;
pub mod cachesweep;
pub mod harness;
pub mod hetero;
pub mod memo;
pub mod motivation;
pub mod overall;
pub mod overlap;
pub mod scalebench;
pub mod sensitivity;
pub mod servebench;
pub mod sweep;
pub mod table3;
pub mod tiersweep;

use crate::util::json::{self, Value};
use crate::util::table::Table;
use std::collections::BTreeMap;
use std::path::Path;

/// A rendered experiment: one or more captioned tables + notes.
pub struct Report {
    pub id: &'static str,
    pub title: String,
    pub sections: Vec<(String, Table)>,
    pub notes: Vec<String>,
}

impl Report {
    pub fn new(id: &'static str, title: impl Into<String>) -> Self {
        Self {
            id,
            title: title.into(),
            sections: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn section(&mut self, caption: impl Into<String>, table: Table) {
        self.sections.push((caption.into(), table));
    }

    pub fn note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    pub fn render(&self) -> String {
        let mut s = format!("# {} — {}\n\n", self.id, self.title);
        for (caption, table) in &self.sections {
            s.push_str(&format!("## {caption}\n\n"));
            s.push_str(&table.render());
            s.push('\n');
        }
        if !self.notes.is_empty() {
            s.push_str("## Notes\n\n");
            for n in &self.notes {
                s.push_str(&format!("- {n}\n"));
            }
        }
        s
    }

    pub fn save(&self, dir: impl AsRef<Path>) -> std::io::Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.md", self.id)), self.render())
    }

    /// Structured form of the report (id / title / sections with header
    /// + rows / notes) for machine consumers — the CI smoke job uploads
    /// these as its workflow artifact.
    pub fn to_json(&self) -> Value {
        let mut obj = BTreeMap::new();
        obj.insert("id".to_string(), Value::Str(self.id.to_string()));
        obj.insert("title".to_string(), Value::Str(self.title.clone()));
        let sections: Vec<Value> = self
            .sections
            .iter()
            .map(|(caption, table)| {
                let mut s = BTreeMap::new();
                s.insert("caption".to_string(), Value::Str(caption.clone()));
                s.insert(
                    "headers".to_string(),
                    Value::Arr(
                        table
                            .headers()
                            .iter()
                            .map(|h| Value::Str(h.clone()))
                            .collect(),
                    ),
                );
                s.insert(
                    "rows".to_string(),
                    Value::Arr(
                        table
                            .rows()
                            .iter()
                            .map(|row| {
                                Value::Arr(
                                    row.iter()
                                        .map(|c| Value::Str(c.clone()))
                                        .collect(),
                                )
                            })
                            .collect(),
                    ),
                );
                Value::Obj(s)
            })
            .collect();
        obj.insert("sections".to_string(), Value::Arr(sections));
        obj.insert(
            "notes".to_string(),
            Value::Arr(
                self.notes.iter().map(|n| Value::Str(n.clone())).collect(),
            ),
        );
        Value::Obj(obj)
    }

    pub fn save_json(&self, dir: impl AsRef<Path>) -> std::io::Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join(format!("{}.json", self.id)),
            json::write(&self.to_json(), true),
        )
    }
}

/// Experiment scale knobs (--quick shrinks everything for CI).
#[derive(Clone, Copy, Debug)]
pub struct Scale {
    pub epochs: usize,
    pub max_iterations: Option<usize>,
    pub batch: usize,
    pub quick: bool,
}

impl Scale {
    pub fn full() -> Self {
        Self {
            epochs: 5,
            // epoch time is reported over a fixed iteration budget —
            // ratios between strategies are iteration-count invariant
            max_iterations: Some(8),
            batch: 1024,
            quick: false,
        }
    }

    pub fn quick() -> Self {
        Self {
            epochs: 3,
            max_iterations: Some(3),
            batch: 512,
            quick: true,
        }
    }
}

pub const ALL_EXPERIMENTS: &[&str] = &[
    "fig04", "fig05", "fig07", "table1", "fig11", "fig12", "fig13",
    "fig14", "fig15", "fig16", "fig17", "fig18", "fig19", "fig20",
    "fig21", "fig22", "fig23", "table3", "overlap", "cachesweep",
    "tiersweep", "hetero", "scale", "serve",
];

/// Fail-fast id resolution for the `bench` CLI: validate *and dedupe*
/// every requested experiment id up front, so an unknown id aborts
/// before any experiment has spent time running. Order is preserved
/// (first occurrence wins); all unknown ids are reported together.
pub fn resolve_experiment_ids(
    ids: &[String],
) -> Result<Vec<&'static str>, String> {
    let mut resolved: Vec<&'static str> = Vec::new();
    let mut unknown: Vec<String> = Vec::new();
    for id in ids {
        match ALL_EXPERIMENTS.iter().find(|&&k| k == id.as_str()) {
            Some(&k) => {
                if !resolved.contains(&k) {
                    resolved.push(k);
                }
            }
            None => {
                if !unknown.contains(id) {
                    unknown.push(id.clone());
                }
            }
        }
    }
    if !unknown.is_empty() {
        return Err(format!(
            "unknown experiment id{} '{}'; known ids: {}",
            if unknown.len() > 1 { "s" } else { "" },
            unknown.join("', '"),
            ALL_EXPERIMENTS.join(", ")
        ));
    }
    Ok(resolved)
}

/// Dispatch one experiment by id.
pub fn run_experiment(id: &str, scale: Scale) -> Result<Report, String> {
    match id {
        "fig04" => Ok(motivation::fig04_breakdown(scale)),
        "fig05" => Ok(motivation::fig05_alpha(scale)),
        "fig07" => Ok(motivation::fig07_naive_vs_mc(scale)),
        "table1" => Ok(motivation::table1_locality(scale)),
        "fig11" => Ok(overall::fig11_shallow(scale)),
        "fig12" => Ok(overall::fig12_deep(scale)),
        "fig13" => Ok(ablation::fig13_ablation(scale)),
        "fig14" => Ok(ablation::fig14_missrate(scale)),
        "fig15" => Ok(ablation::fig15_gather_time(scale)),
        "fig16" => Ok(ablation::fig16_pregather(scale)),
        "fig17" => Ok(ablation::fig17_merging(scale)),
        "fig18" => Ok(ablation::fig18_merge_selection(scale)),
        "fig19" => Ok(overall::fig19_large_graph(scale)),
        "fig20" => Ok(sensitivity::fig20_gpu_util(scale)),
        "fig21" => Ok(overall::fig21_fullbatch(scale)),
        "fig22" => Ok(sensitivity::fig22_batch_featdim(scale)),
        "fig23" => Ok(sensitivity::fig23_fanout_machines(scale)),
        "table3" => table3::table3_accuracy(scale),
        "overlap" => Ok(overlap::overlap_sweep(scale)),
        "cachesweep" => Ok(cachesweep::cachesweep(scale)),
        "tiersweep" => Ok(tiersweep::tiersweep(scale)),
        "hetero" => Ok(hetero::hetero(scale)),
        "scale" => Ok(scalebench::scalebench(scale)),
        "serve" => servebench::servebench(scale),
        _ => Err(format!(
            "unknown experiment '{id}'; known: {}",
            ALL_EXPERIMENTS.join(", ")
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_renders_and_saves() {
        let mut r = Report::new("figXX", "demo");
        let mut t = Table::new(["a", "b"]);
        t.row(["1", "2"]);
        r.section("caption", t);
        r.note("a note");
        let s = r.render();
        assert!(s.contains("# figXX — demo"));
        assert!(s.contains("caption"));
        assert!(s.contains("a note"));
        let dir = std::env::temp_dir().join("hopgnn-report-test");
        r.save(&dir).unwrap();
        assert!(dir.join("figXX.md").exists());
    }

    #[test]
    fn unknown_experiment_rejected() {
        assert!(run_experiment("nope", Scale::quick()).is_err());
    }

    #[test]
    fn id_resolution_is_fail_fast_and_dedupes() {
        let ids: Vec<String> = ["overlap", "fig11", "overlap"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert_eq!(
            resolve_experiment_ids(&ids).unwrap(),
            vec!["overlap", "fig11"],
            "duplicates collapse, order preserved"
        );
        let bad: Vec<String> = ["overlap", "nope", "alsonope", "nope"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let e = resolve_experiment_ids(&bad).unwrap_err();
        assert!(e.contains("'nope', 'alsonope'"), "{e}");
        assert!(e.contains("known ids"), "{e}");
        assert!(e.contains("cachesweep"), "lists the valid ids: {e}");
        assert!(e.contains("serve"), "lists the serve experiment: {e}");
        assert!(resolve_experiment_ids(&[]).unwrap().is_empty());
    }

    #[test]
    fn report_json_roundtrips() {
        let mut r = Report::new("figJSON", "json demo");
        let mut t = Table::new(["k", "v"]);
        t.row(["x", "1"]);
        r.section("cap", t);
        r.note("n1");
        let text = json::write(&r.to_json(), true);
        let v = json::parse(&text).expect("report JSON must parse");
        assert_eq!(v.path("id").and_then(Value::as_str), Some("figJSON"));
        let sections = v.path("sections").and_then(Value::as_arr).unwrap();
        assert_eq!(sections.len(), 1);
        assert_eq!(
            sections[0].path("headers").and_then(Value::as_arr).unwrap().len(),
            2
        );
        let dir = std::env::temp_dir().join("hopgnn-report-json-test");
        r.save_json(&dir).unwrap();
        assert!(dir.join("figJSON.json").exists());
    }
}
