//! §3 motivation experiments: Fig 4 (breakdown), Fig 5 (alpha ratio),
//! Fig 7 (naive-FC vs model-centric data volume), Table 1 (locality).

use super::{Report, Scale};
use crate::cluster::{ModelFamily, TransferKind};
use crate::config::RunConfig;
use super::memo;
use crate::coordinator::StrategySpec;
use crate::graph::datasets::Dataset;
use crate::partition::{partition, PartitionAlgo};
use crate::sampler::{sample_micrograph, SampleConfig, SamplerKind, Subgraph};
use crate::util::rng::Rng;
use crate::util::table::{fmt_bytes, Table};

fn base_cfg(scale: Scale, dataset: &str, model: ModelFamily) -> RunConfig {
    let mut cfg = RunConfig {
        dataset: dataset.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        ..Default::default()
    };
    if model.default_layers() > 3 {
        cfg.fanout = 2;
        cfg.vmax = RunConfig::full_sim_vmax(model.default_layers(), 2);
        cfg.hidden = 64;
    }
    cfg
}

/// Fig 4: DGL time breakdown — remote gather should consume 44-83%.
pub fn fig04_breakdown(scale: Scale) -> Report {
    let mut r = Report::new("fig04", "DGL training-time breakdown (paper: gather 44-83%)");
    let mut t = Table::new([
        "dataset", "model", "sample%", "gather%", "compute%", "sync%",
    ]);
    let datasets = if scale.quick {
        vec!["arxiv-s"]
    } else {
        vec!["arxiv-s", "products-s", "uk-s"]
    };
    for ds in datasets {
        for model in [ModelFamily::Gcn, ModelFamily::Sage, ModelFamily::Gat] {
            let cfg = base_cfg(scale, ds, model);
            let m = memo::run(&cfg, StrategySpec::dgl());
            let total = (m.time_sample + m.time_gather + m.time_compute
                + m.time_migrate
                + m.time_sync)
                .max(1e-12);
            t.row([
                ds.to_string(),
                model.name().to_string(),
                format!("{:.1}", m.time_sample / total * 100.0),
                format!("{:.1}", m.time_gather / total * 100.0),
                format!("{:.1}", m.time_compute / total * 100.0),
                format!("{:.1}", m.time_sync / total * 100.0),
            ]);
        }
    }
    r.section("time breakdown per phase (% of server time)", t);
    r.note("paper Fig 4: gather 44-83% of training time, sample+compute ~11% avg");
    r
}

/// Fig 5: alpha = remote bytes fetched per iteration / model bytes.
pub fn fig05_alpha(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig05",
        "alpha ratio: fetched data volume / model size (paper: 13.4-2368)",
    );
    let mut t = Table::new(["model", "layers", "hidden", "alpha", "log2"]);
    let d = memo::dataset("products-s");
    // (family, layers, hidden, fanout). The depth trend needs a FIXED
    // fanout (the paper's Fig 5 point: subgraph size — hence alpha —
    // grows with layer count, DeeperGCN-112 reaching 2368).
    let rows: Vec<(ModelFamily, usize, usize, usize)> = vec![
        (ModelFamily::Gcn, 2, 128, 4),
        (ModelFamily::Gcn, 3, 128, 4),
        (ModelFamily::Gcn, 5, 128, 4),
        (ModelFamily::DeepGcn, 7, 64, 4),
        (ModelFamily::Film, 10, 64, 4),
        (ModelFamily::Gcn, 3, 16, 10),
        (ModelFamily::Gcn, 3, 128, 10),
        (ModelFamily::Sage, 3, 16, 10),
        (ModelFamily::Sage, 3, 128, 10),
        (ModelFamily::Gat, 3, 128, 10),
    ];
    for (family, layers, hidden, fanout) in rows {
        let mut cfg = base_cfg(scale, "products-s", family);
        cfg.layers = layers;
        cfg.hidden = hidden;
        cfg.fanout = fanout;
        cfg.vmax = RunConfig::full_sim_vmax(layers, fanout);
        cfg.epochs = 1;
        let m = memo::run(&cfg, StrategySpec::dgl());
        let feat_dim = d.feat_dim;
        let shape = cfg.model_shape(feat_dim, d.classes);
        let per_iter = m.bytes(TransferKind::Feature) as f64
            / m.iterations.max(1) as f64;
        let alpha = per_iter / shape.param_bytes() as f64;
        t.row([
            format!("{}(fanout {fanout})", family.name()),
            layers.to_string(),
            hidden.to_string(),
            format!("{alpha:.1}"),
            format!("{:.1}", alpha.log2()),
        ]);
    }
    r.section("alpha per model variant", t);
    r.note("paper Fig 5: alpha in [13.4, 2368.1]; grows with depth, shrinks with hidden dim");
    r
}

/// Fig 7: naive feature-centric can move MORE data than model-centric.
pub fn fig07_naive_vs_mc(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig07",
        "transferred bytes: model-centric vs naive feature-centric (paper: naive up to 2.59x worse)",
    );
    let mut t = Table::new([
        "dataset", "model", "MC bytes", "Naive bytes", "naive/mc",
    ]);
    let datasets = if scale.quick {
        vec!["arxiv-s"]
    } else {
        vec!["arxiv-s", "products-s", "uk-s", "in-s"]
    };
    let mut worst: f64 = 0.0;
    for ds in datasets {
        for model in [ModelFamily::Gcn, ModelFamily::Gat] {
            let cfg = base_cfg(scale, ds, model);
            let mc = memo::run(&cfg, StrategySpec::dgl());
            let nv = memo::run(&cfg, StrategySpec::naive());
            let ratio = nv.total_bytes() as f64 / mc.total_bytes().max(1) as f64;
            worst = worst.max(ratio);
            t.row([
                ds.to_string(),
                model.name().to_string(),
                fmt_bytes(mc.total_bytes()),
                fmt_bytes(nv.total_bytes()),
                format!("{ratio:.2}x"),
            ]);
        }
    }
    r.section("per-epoch transferred bytes", t);
    r.note(format!(
        "worst naive/mc ratio observed: {worst:.2}x (paper: up to 2.59x)"
    ));
    r
}

/// Table 1: micrograph locality R_micro vs subgraph locality R_sub.
pub fn table1_locality(scale: Scale) -> Report {
    let mut r = Report::new(
        "table1",
        "micrograph vs subgraph locality (paper Table 1)",
    );
    let server_counts: Vec<usize> = if scale.quick {
        vec![2, 4]
    } else {
        vec![2, 4, 8, 16]
    };
    // (dataset, partitioner) pairs as in the paper: METIS on the small
    // pair, BGL-style heuristic on the large pair
    let setups: Vec<(&str, PartitionAlgo)> = if scale.quick {
        vec![("arxiv-s", PartitionAlgo::MetisLike)]
    } else {
        vec![
            ("arxiv-s", PartitionAlgo::MetisLike),
            ("products-s", PartitionAlgo::MetisLike),
            ("uk-s", PartitionAlgo::Heuristic),
            ("in-s", PartitionAlgo::Heuristic),
        ]
    };
    for kind in [SamplerKind::NodeWise, SamplerKind::LayerWise] {
        let mut t = Table::new([
            "dataset", "partition", "#S", "R_micro 2L%", "R_micro 10L%",
            "R_sub 2L%",
        ]);
        for &(ds, algo) in &setups {
            let d = memo::dataset(ds);
            for &s in &server_counts {
                let p = partition(&d.graph, s, algo, 7);
                let (rm2, rs2) = locality_of(&d, &p, 2, kind, 64);
                let (rm10, _) = locality_of(&d, &p, 10, kind, 64);
                t.row([
                    ds.to_string(),
                    algo.name().to_string(),
                    s.to_string(),
                    format!("{:.0}", rm2 * 100.0),
                    format!("{:.0}", rm10 * 100.0),
                    format!("{:.0}", rs2 * 100.0),
                ]);
            }
        }
        let caption = match kind {
            SamplerKind::NodeWise => "node-wise sampling",
            SamplerKind::LayerWise => "layer-wise sampling",
        };
        r.section(caption, t);
    }
    r.note("paper Table 1: R_micro >> R_sub, gap grows with #S (1.59x at 2 servers to 10.6x at 16)");
    r
}

fn locality_of(
    d: &Dataset,
    p: &crate::partition::Partition,
    layers: usize,
    kind: SamplerKind,
    n_samples: usize,
) -> (f64, f64) {
    let cfg = SampleConfig {
        layers,
        fanout: if layers > 2 { 2 } else { 10 },
        vmax: 256,
        kind,
    };
    let mut rng = Rng::new(91);
    let mut mgs = Vec::with_capacity(n_samples);
    for _ in 0..n_samples {
        let root = d.train_vertices[rng.below(d.train_vertices.len())];
        mgs.push(sample_micrograph(&d.graph, root, &cfg, &mut rng));
    }
    let r_micro =
        mgs.iter().map(|m| m.locality(p)).sum::<f64>() / mgs.len() as f64;
    let sub = Subgraph::union_of(&mgs);
    (r_micro, sub.locality(p))
}
