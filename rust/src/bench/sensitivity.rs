//! Sensitivity experiments: Fig 20 (GPU utilization), Fig 22 (batch size
//! & feature dim), Fig 23 (fanout & machine count).

use super::{Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use super::memo;
use crate::coordinator::StrategySpec;
use crate::util::table::{fmt_secs, Table};

fn cfg_for(scale: Scale, ds: &str, model: ModelFamily) -> RunConfig {
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        ..Default::default()
    }
}

/// Fig 20: GPU busy-fraction proxy (paper: HopGNN keeps the GPU busy 52%
/// of the time vs 13% / 18% for DGL / P3).
pub fn fig20_gpu_util(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig20",
        "GPU busy fraction (paper: HopGNN 52% vs DGL 13% / P3 18%)",
    );
    let ds = if scale.quick { "products-s" } else { "uk-s" };
    let _ = memo::dataset(ds); // warm the cache
    let cfg = cfg_for(scale, ds, ModelFamily::Gat);
    let mut t = Table::new(["system", "busy %", "epoch"]);
    for kind in [StrategySpec::dgl(), StrategySpec::p3(), StrategySpec::hopgnn()] {
        let m = memo::run(&cfg, kind);
        t.row([
            kind.name(),
            format!("{:.1}", m.gpu_busy_fraction * 100.0),
            fmt_secs(m.epoch_time),
        ]);
    }
    r.section(format!("GAT on {ds}"), t);
    r.note("busy = fraction of wall time the simulated GPU spends in compute (idle = waiting on gather/migrate/sync)");
    r
}

/// Fig 22a/b: batch-size and feature-dimension sweeps (GCN on Products).
pub fn fig22_batch_featdim(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig22",
        "sensitivity: batch size (paper: 2.2-2.8x) and feature dim (paper: 2.1-2.9x)",
    );

    let mut t = Table::new(["batch", "DGL", "HopGNN", "speedup"]);
    let batches: Vec<usize> = if scale.quick {
        vec![256, 1024]
    } else {
        vec![256, 512, 1024, 2048, 4096]
    };
    for &b in &batches {
        let mut cfg = cfg_for(scale, "products-s", ModelFamily::Gcn);
        cfg.batch_size = b;
        let dgl = memo::run(&cfg, StrategySpec::dgl());
        let hop = memo::run(&cfg, StrategySpec::hopgnn());
        t.row([
            b.to_string(),
            fmt_secs(dgl.epoch_time),
            fmt_secs(hop.epoch_time),
            format!("{:.2}x", dgl.epoch_time / hop.epoch_time),
        ]);
    }
    r.section("(a) batch-size sweep, GCN on products-s", t);

    let mut t = Table::new(["feat dim", "DGL", "HopGNN", "speedup"]);
    let dims: Vec<usize> = if scale.quick {
        vec![100, 400]
    } else {
        vec![50, 100, 200, 400, 600]
    };
    for &fd in &dims {
        let mut cfg = cfg_for(scale, "products-s", ModelFamily::Gcn);
        cfg.feat_dim_override = Some(fd);
        let dgl = memo::run(&cfg, StrategySpec::dgl());
        let hop = memo::run(&cfg, StrategySpec::hopgnn());
        t.row([
            fd.to_string(),
            fmt_secs(dgl.epoch_time),
            fmt_secs(hop.epoch_time),
            format!("{:.2}x", dgl.epoch_time / hop.epoch_time),
        ]);
    }
    r.section("(b) feature-dimension sweep", t);
    r.note("paper: speedup grows with feature dim (gather fraction rises 36.8% -> 72%)");
    r
}

/// Fig 23a/b: fanout sweep and machine-count sweep.
pub fn fig23_fanout_machines(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig23",
        "sensitivity: fanout (paper: ~2.3x avg) and #machines (paper: 1.69x at 2 -> 2.55x at 6)",
    );

    let mut t = Table::new(["fanout", "DGL", "HopGNN", "speedup"]);
    let fanouts: Vec<usize> = if scale.quick {
        vec![5, 10]
    } else {
        vec![5, 10, 20, 40]
    };
    for &f in &fanouts {
        let mut cfg = cfg_for(scale, "products-s", ModelFamily::Gcn);
        cfg.fanout = f;
        cfg.vmax = (1 + f + f * f).min(512).next_power_of_two();
        let dgl = memo::run(&cfg, StrategySpec::dgl());
        let hop = memo::run(&cfg, StrategySpec::hopgnn());
        t.row([
            f.to_string(),
            fmt_secs(dgl.epoch_time),
            fmt_secs(hop.epoch_time),
            format!("{:.2}x", dgl.epoch_time / hop.epoch_time),
        ]);
    }
    r.section("(a) fanout sweep, GCN on products-s", t);

    let mut t = Table::new(["#machines", "DGL", "HopGNN", "speedup"]);
    let machines: Vec<usize> = if scale.quick {
        vec![2, 4]
    } else {
        vec![2, 3, 4, 5, 6]
    };
    for &n in &machines {
        let mut cfg = cfg_for(scale, "products-s", ModelFamily::Gcn);
        cfg.num_servers = n;
        // weak scaling, as in the paper: per-server batch share fixed
        cfg.batch_size = (scale.batch / 4) * n;
        let dgl = memo::run(&cfg, StrategySpec::dgl());
        let hop = memo::run(&cfg, StrategySpec::hopgnn());
        t.row([
            n.to_string(),
            fmt_secs(dgl.epoch_time),
            fmt_secs(hop.epoch_time),
            format!("{:.2}x", dgl.epoch_time / hop.epoch_time),
        ]);
    }
    r.section("(b) machine-count sweep", t);
    r.note("paper: HopGNN's advantage grows with scale (more servers = worse DGL locality)");
    r
}
