//! Ablation experiments: Fig 13 (+MG/+PG/All), Fig 14 (miss rate),
//! Fig 15 (gather time), Fig 16 (pre-gathering), Fig 17 (merging
//! trajectory), Fig 18 (merge selection vs random).
//!
//! The grid-shaped figures (13/14/15/16/18) are dataset × model ×
//! strategy products on the sweep engine ([`super::sweep`]); Fig 17
//! needs the controller's per-epoch history, so it drives the strategy
//! directly.

use super::sweep::{Axis, SweepSpec};
use super::{memo, Report, Scale};
use crate::cluster::ModelFamily;
use crate::config::RunConfig;
use crate::coordinator::hopgnn::HopGnn;
use crate::coordinator::{SimEnv, Strategy, StrategySpec};
use crate::metrics::EpochMetrics;
use crate::util::table::{fmt_secs, Table};

fn cfg_for(scale: Scale, ds: &str, model: ModelFamily) -> RunConfig {
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        ..Default::default()
    }
}

/// Model axis over config patches: `model = <family>` resets the layer
/// count to the family default, and `vmax` is re-derived from that
/// depth exactly as [`cfg_for`] does (for the 3-layer families swept
/// today the values coincide; deep families would silently keep a
/// 3-layer vmax cap without this patch).
fn model_axis(models: &[ModelFamily]) -> Axis {
    Axis::patches(
        "model",
        models
            .iter()
            .map(|m| {
                (
                    m.name().to_string(),
                    vec![
                        ("model".to_string(), m.name().to_string()),
                        (
                            "vmax".to_string(),
                            RunConfig::full_sim_vmax(m.default_layers(), 10)
                                .to_string(),
                        ),
                    ],
                )
            })
            .collect(),
    )
}

/// Fig 13: each technique's incremental speedup over DGL.
pub fn fig13_ablation(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig13",
        "incremental techniques vs DGL (paper: +MG biggest, then +PG, then merging)",
    );
    let datasets = if scale.quick {
        vec!["products-s"]
    } else {
        vec!["products-s", "uk-s"]
    };
    let models = [ModelFamily::Gcn, ModelFamily::Sage, ModelFamily::Gat];
    let steps = [
        StrategySpec::dgl(),
        StrategySpec::hopgnn_mg(),
        StrategySpec::hopgnn_mg_pg(),
        StrategySpec::hopgnn(),
    ];
    let grid = SweepSpec::new(
        cfg_for(scale, datasets[0], ModelFamily::Gcn),
        StrategySpec::hopgnn(),
    )
    .axis(Axis::key("dataset", &datasets))
    .axis(model_axis(&models))
    .axis(Axis::strategies(&steps))
    .run()
    .expect("fig13 grid is statically valid");
    let mut t = Table::new([
        "dataset", "model", "DGL", "+MG", "+PG", "All", "All speedup",
    ]);
    for (di, ds) in datasets.iter().enumerate() {
        for (mi, model) in models.iter().enumerate() {
            let dgl = grid.metrics(&[di, mi, 0]);
            let mg = grid.metrics(&[di, mi, 1]);
            let pg = grid.metrics(&[di, mi, 2]);
            let all = grid.metrics(&[di, mi, 3]);
            t.row([
                ds.to_string(),
                model.name().to_string(),
                fmt_secs(dgl.epoch_time),
                fmt_secs(mg.epoch_time),
                fmt_secs(pg.epoch_time),
                fmt_secs(all.epoch_time),
                format!("{:.2}x", dgl.epoch_time / all.epoch_time),
            ]);
        }
    }
    r.section("epoch time as techniques stack", t);
    r.note("paper Fig 13: up to 2.14x (Products) / 2.72x (UK) for All vs DGL");
    r
}

/// Fig 14: feature-gathering miss rates, DGL vs +MG.
pub fn fig14_missrate(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig14",
        "remote-feature miss rate (paper: 76.5% avg -> 23.3% avg)",
    );
    let mut t = Table::new(["dataset", "DGL miss%", "+MG miss%"]);
    let datasets = if scale.quick {
        vec!["arxiv-s", "products-s"]
    } else {
        vec!["arxiv-s", "products-s", "uk-s", "in-s"]
    };
    let grid = SweepSpec::new(
        cfg_for(scale, datasets[0], ModelFamily::Gcn),
        StrategySpec::hopgnn(),
    )
    .axis(Axis::key("dataset", &datasets))
    .axis(Axis::strategies(&[
        StrategySpec::dgl(),
        StrategySpec::hopgnn_mg(),
    ]))
    .run()
    .expect("fig14 grid is statically valid");
    let (mut dgl_sum, mut mg_sum, mut n) = (0.0, 0.0, 0);
    for (di, ds) in datasets.iter().enumerate() {
        let dgl = grid.metrics(&[di, 0]);
        let mg = grid.metrics(&[di, 1]);
        dgl_sum += dgl.miss_rate();
        mg_sum += mg.miss_rate();
        n += 1;
        t.row([
            ds.to_string(),
            format!("{:.1}", dgl.miss_rate() * 100.0),
            format!("{:.1}", mg.miss_rate() * 100.0),
        ]);
    }
    r.section("miss rate by dataset", t);
    r.note(format!(
        "averages: DGL {:.1}% vs +MG {:.1}% (paper: 76.5% vs 23.3%)",
        dgl_sum / n as f64 * 100.0,
        mg_sum / n as f64 * 100.0
    ));
    r
}

/// Fig 15: remote feature gathering time with/without MG (Products).
pub fn fig15_gather_time(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig15",
        "remote gather time, DGL vs +MG (paper: 2.3x reduction on avg)",
    );
    let mut t = Table::new(["model", "DGL gather", "+MG gather", "reduction"]);
    let models = [ModelFamily::Gcn, ModelFamily::Sage, ModelFamily::Gat];
    let grid = SweepSpec::new(
        cfg_for(scale, "products-s", ModelFamily::Gcn),
        StrategySpec::hopgnn(),
    )
    .axis(model_axis(&models))
    .axis(Axis::strategies(&[
        StrategySpec::dgl(),
        StrategySpec::hopgnn_mg(),
    ]))
    .run()
    .expect("fig15 grid is statically valid");
    for (mi, model) in models.iter().enumerate() {
        let dgl = grid.metrics(&[mi, 0]);
        let mg = grid.metrics(&[mi, 1]);
        t.row([
            model.name().to_string(),
            fmt_secs(dgl.time_gather),
            fmt_secs(mg.time_gather),
            format!("{:.2}x", dgl.time_gather / mg.time_gather.max(1e-12)),
        ]);
    }
    r.section("per-epoch gather time on products-s", t);
    r
}

/// Fig 16: pre-gathering reduces remote requests & transferred vertices.
pub fn fig16_pregather(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig16",
        "pre-gathering effect (paper: requests -1.9x, misses -1.4x)",
    );
    let mut t = Table::new([
        "dataset", "metric", "+MG", "+PG", "reduction",
    ]);
    let datasets = if scale.quick {
        vec!["products-s"]
    } else {
        vec!["products-s", "uk-s"]
    };
    let grid = SweepSpec::new(
        cfg_for(scale, datasets[0], ModelFamily::Gcn),
        StrategySpec::hopgnn(),
    )
    .axis(Axis::key("dataset", &datasets))
    .axis(Axis::strategies(&[
        StrategySpec::hopgnn_mg(),
        StrategySpec::hopgnn_mg_pg(),
    ]))
    .run()
    .expect("fig16 grid is statically valid");
    for (di, ds) in datasets.iter().enumerate() {
        let mg = grid.metrics(&[di, 0]);
        let pg = grid.metrics(&[di, 1]);
        t.row([
            ds.to_string(),
            "remote requests".into(),
            mg.remote_requests.to_string(),
            pg.remote_requests.to_string(),
            format!(
                "{:.2}x",
                mg.remote_requests as f64 / pg.remote_requests.max(1) as f64
            ),
        ]);
        t.row([
            ds.to_string(),
            "remote vertices".into(),
            mg.remote_vertices.to_string(),
            pg.remote_vertices.to_string(),
            format!(
                "{:.2}x",
                mg.remote_vertices as f64 / pg.remote_vertices.max(1) as f64
            ),
        ]);
    }
    r.section("per-epoch remote fetch counters", t);
    r
}

/// The paper's software stack (python DGL + PyTorch distributed + gRPC)
/// pays multi-millisecond per-time-step orchestration overheads — the
/// very costs merging (§5.3) trades against locality. Our default cost
/// model reflects a leaner Rust runtime where those overheads are small
/// (and the controller correctly refuses to merge); these two
/// experiments use the paper-stack constants so the §5.3 dynamics are
/// visible. Documented in EXPERIMENTS.md.
fn pytorch_stack_costs(cfg: &mut RunConfig) {
    cfg.cost.t_launch = 0.5e-3;
    cfg.cost.t_sync = 6.0e-3;
}

/// Fig 17: merging trajectory — epoch time & time steps per epoch.
/// (Trajectory experiment: needs per-epoch history, so it drives the
/// strategy directly instead of going through the sweep engine.)
pub fn fig17_merging(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig17",
        "micrograph merging trajectory (paper: 4 -> 3 -> 2 steps, settles at 3)",
    );
    let d = memo::dataset("products-s");
    let mut cfg = cfg_for(scale, "products-s", ModelFamily::Gat);
    pytorch_stack_costs(&mut cfg);
    cfg.epochs = if scale.quick { 4 } else { 6 };
    let mut env = SimEnv::new(d, cfg.clone());
    let mut strat = HopGnn::full();
    let epochs: Vec<EpochMetrics> = strat.run(&mut env, cfg.epochs);
    let mut t = Table::new(["epoch", "time steps/iter", "epoch time"]);
    for (i, e) in epochs.iter().enumerate() {
        t.row([
            i.to_string(),
            format!("{:.0}", e.time_steps_per_iter),
            fmt_secs(e.epoch_time),
        ]);
    }
    r.section("GAT on products-s, 4 servers", t);
    r.note("the controller merges while epoch time improves, then reverts once and freezes (§5.3)");
    r
}

/// Fig 18: merge-step selection — min-load vs random, as a dataset ×
/// selection grid (steady state = the controller's frozen last epoch,
/// which is what the memoized runner reports for adapting specs).
pub fn fig18_merge_selection(scale: Scale) -> Report {
    let mut r = Report::new(
        "fig18",
        "merge selection scheme (paper: min-load beats random 1.4-1.9x)",
    );
    let datasets = if scale.quick {
        vec!["products-s"]
    } else {
        vec!["products-s", "in-s"]
    };
    let mut base = cfg_for(scale, datasets[0], ModelFamily::Gcn);
    pytorch_stack_costs(&mut base);
    base.epochs = if scale.quick { 4 } else { 6 };
    let grid = SweepSpec::new(base, StrategySpec::hopgnn())
        .axis(Axis::key("dataset", &datasets))
        .axis(Axis::strategies(&[
            StrategySpec::hopgnn(),
            StrategySpec::hopgnn_rd(),
        ]))
        .run()
        .expect("fig18 grid is statically valid");
    let mut t = Table::new(["dataset", "MinLoad", "Random(RD)", "ratio"]);
    for (di, ds) in datasets.iter().enumerate() {
        let min_time = grid.metrics(&[di, 0]).epoch_time;
        let rd_time = grid.metrics(&[di, 1]).epoch_time;
        t.row([
            ds.to_string(),
            fmt_secs(min_time),
            fmt_secs(rd_time),
            format!("{:.2}x", rd_time / min_time),
        ]);
    }
    r.section("steady-state epoch time by selection scheme", t);
    r.note("random merging unbalances per-step load across servers (paper Fig 18b)");
    r
}
