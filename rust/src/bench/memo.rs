//! Process-wide memoization for the experiment harness: loading a
//! dataset and partitioning a multi-million-edge graph are seconds-scale
//! one-time costs that dozens of experiment configurations share.
//!
//! (Formerly `bench/cache.rs` — renamed so the harness-side memo tables
//! cannot be confused with the simulated per-server feature cache,
//! `crate::featstore::cache`.)
//!
//! Locking is **per key**, not per table: the global `Mutex` only
//! guards the `HashMap` of entry cells and is held for a handful of
//! instructions, while the seconds-scale `load` / `partition` work runs
//! under each key's own `OnceLock`. Two parallel sweep cells (the
//! `--jobs` worker pool, `util::pool`) therefore load *distinct*
//! datasets concurrently, while racing requests for the *same* key
//! block on that key alone and the expensive computation still runs
//! exactly once. (The previous design held the table mutex across the
//! whole load, which would have serialized every parallel cell.)
//!
//! Sweep cells now execute on budgeted pool runners (`--jobs` splits
//! one thread budget between cell runners and each cell's epoch
//! lanes; see `crate::bench::sweep` and `crate::util::pool`), so the
//! per-key locking here may also be contended by a cell runner while
//! its sibling's lane workers are busy — the same rule applies:
//! distinct keys never serialize each other.

use crate::config::RunConfig;
use crate::coordinator::{SimEnv, StrategySpec};
use crate::graph::datasets::{load, Dataset};
use crate::metrics::EpochMetrics;
use crate::partition::{partition, Partition, PartitionAlgo};
use crate::sampler::SamplerKind;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One dataset slot: leaked so the initialized value is `&'static`.
type DatasetEntry = &'static OnceLock<Dataset>;

fn dataset_cache() -> &'static Mutex<HashMap<String, DatasetEntry>> {
    static CACHE: OnceLock<Mutex<HashMap<String, DatasetEntry>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (once) and lease a dataset for the process lifetime.
/// Concurrent callers with the same name block on this key's entry
/// (the load runs once); callers with different names proceed in
/// parallel.
pub fn dataset(name: &str) -> &'static Dataset {
    let entry: DatasetEntry = {
        let mut cache = dataset_cache().lock().unwrap();
        match cache.get(name) {
            Some(e) => e,
            None => {
                let e: DatasetEntry = Box::leak(Box::new(OnceLock::new()));
                cache.insert(name.to_string(), e);
                e
            }
        }
    };
    // table lock released; only same-key callers wait here
    entry.get_or_init(|| load(name))
}

type PartKey = (String, usize, &'static str, u64);
type PartitionEntry = Arc<OnceLock<Partition>>;

fn partition_cache() -> &'static Mutex<HashMap<PartKey, PartitionEntry>> {
    static CACHE: OnceLock<Mutex<HashMap<PartKey, PartitionEntry>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Partition (once per key) and clone out. Same per-key locking
/// discipline as [`dataset`]: the table mutex never outlives the entry
/// lookup, so distinct keys partition concurrently.
pub fn partition_for(
    d: &Dataset,
    num_parts: usize,
    algo: PartitionAlgo,
    seed: u64,
) -> Partition {
    let key = (d.name.to_string(), num_parts, algo.name(), seed);
    let entry: PartitionEntry = {
        let mut cache = partition_cache().lock().unwrap();
        Arc::clone(
            cache
                .entry(key)
                .or_insert_with(|| Arc::new(OnceLock::new())),
        )
    };
    entry
        .get_or_init(|| partition(&d.graph, num_parts, algo, seed))
        .clone()
}

// ---------------------------------------------------------------------
// Epoch-sample memo: the third memo tier. A strategy's per-epoch
// sampling stream is fully determined by inputs *orthogonal* to the
// axes sweeps usually vary (fabric topology, cache policy/size,
// overlap, lane parallelism only change how the sampled work is
// *priced*). Sweep cells therefore record each epoch's sampled
// micrographs once — as a flat tape of per-root-group vertex lists —
// and every other cell with the same [`SampleKey`] replays the tape via
// a cheap `Arc` clone instead of re-running the sampler. Same per-key
// entry-lock discipline as the dataset/partition tiers above.
// ---------------------------------------------------------------------

/// One root group's sampled result: the flattened micrograph vertices
/// of every root in the group (sampling order, duplicates preserved —
/// byte-identical to flattening the equivalent `Vec<Micrograph>`) plus
/// the summed edge count. Exactly what the strategy schedule builders
/// consume; summed vertices is `verts.len()`.
#[derive(Clone, Debug, Default)]
pub struct SampleGroup {
    pub verts: Vec<u32>,
    pub edges: u64,
}

/// One epoch's sampling stream: every root group, in schedule order.
#[derive(Clone, Debug, Default)]
pub struct EpochTape {
    pub groups: Vec<SampleGroup>,
}

impl EpochTape {
    /// Approximate heap footprint (budget accounting).
    pub fn bytes(&self) -> u64 {
        self.groups
            .iter()
            .map(|g| 4 * g.verts.len() as u64 + 48)
            .sum()
    }
}

/// Identity of one epoch's deterministic sampling stream. Everything
/// that shapes *which* vertices are sampled and in *what order* is in
/// here; everything that only prices the sampled work (fabric, cache,
/// overlap, parallel lanes) deliberately is not.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SampleKey {
    /// Address of the (process-lifetime, [`dataset`]-leased) dataset.
    /// Only stable for leaked instances — which is why
    /// `RunConfig::memo_samples` is set by [`run`] alone.
    dataset: usize,
    num_servers: usize,
    partition: PartitionAlgo,
    sampler: SamplerKind,
    seed: u64,
    batch_size: usize,
    /// `usize::MAX` encodes "no iteration cap".
    max_iterations: usize,
    layers: usize,
    fanout: usize,
    vmax: usize,
    /// Strategy sampling-stream salt (the `rng.fork` base).
    salt: u64,
    epoch: u64,
    /// [`crate::coordinator::merge::Schedule::fingerprint`] of the
    /// merge schedule shaping the sampling order (0 if schedule-free).
    schedule: u64,
}

impl SampleKey {
    pub fn for_epoch(
        env: &SimEnv,
        salt: u64,
        epoch: u64,
        schedule: u64,
    ) -> Self {
        let cfg = &env.cfg;
        Self {
            dataset: env.dataset as *const Dataset as usize,
            num_servers: cfg.num_servers,
            partition: cfg.partition_algo,
            sampler: cfg.sampler,
            seed: cfg.seed,
            batch_size: cfg.batch_size,
            max_iterations: cfg.max_iterations.unwrap_or(usize::MAX),
            layers: cfg.layers,
            fanout: cfg.fanout,
            vmax: cfg.vmax,
            salt,
            epoch,
            schedule,
        }
    }
}

/// Per-key tape cell: set exactly once by the first cell to finish
/// recording; replayed by everyone else through an `Arc` clone.
pub type TapeEntry = Arc<OnceLock<Arc<EpochTape>>>;

fn tape_cache() -> &'static Mutex<HashMap<SampleKey, TapeEntry>> {
    static CACHE: OnceLock<Mutex<HashMap<SampleKey, TapeEntry>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Committed tape bytes across the process (admission control only —
/// never decremented; tapes live for the process like the other tiers).
static TAPE_BYTES: AtomicU64 = AtomicU64::new(0);

/// Stop admitting *new* tape entries past this footprint. Existing
/// entries keep replaying; cells that miss simply sample live.
pub const TAPE_BUDGET_BYTES: u64 = 256 << 20;

/// Look up (or admit) the tape cell for `key`. `None` means the memo
/// is over budget and has no entry for this key — sample live, record
/// nothing. Same locking shape as [`dataset`]/[`partition_for`]: the
/// table mutex is held only for the lookup, so distinct keys record
/// concurrently and same-key racers share one cell.
pub fn epoch_tape_entry(key: SampleKey) -> Option<TapeEntry> {
    let mut cache = tape_cache().lock().unwrap();
    if let Some(e) = cache.get(&key) {
        return Some(Arc::clone(e));
    }
    if TAPE_BYTES.load(Ordering::Relaxed) >= TAPE_BUDGET_BYTES {
        return None;
    }
    let e: TapeEntry = Arc::new(OnceLock::new());
    cache.insert(key, Arc::clone(&e));
    Some(e)
}

/// Publish a recorded tape into its cell. First committer wins (and is
/// charged to the budget); a same-key racer's duplicate — identical by
/// construction — is dropped.
pub fn commit_tape(entry: &TapeEntry, tape: EpochTape) {
    let bytes = tape.bytes();
    if entry.set(Arc::new(tape)).is_ok() {
        TAPE_BYTES.fetch_add(bytes, Ordering::Relaxed);
    }
}

/// Cached-run variant of `coordinator::run_strategy`: same semantics,
/// but dataset and partition come from the process-wide caches, and
/// epoch sampling streams are shared across cells through the
/// epoch-sample memo (`memo_samples`) — every metric stays bit-identical
/// to the uncached path (`tests/scratch_parity.rs`).
pub fn run(cfg: &RunConfig, spec: StrategySpec) -> EpochMetrics {
    let d = dataset(&cfg.dataset);
    let mut cfg = cfg.clone();
    cfg.memo_samples = true;
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let part = partition_for(
        d,
        cfg.num_servers,
        cfg.partition_algo,
        cfg.seed ^ 0x9A27,
    );
    let epochs = cfg.epochs;
    let mut env = SimEnv::with_partition(d, cfg, part);
    let mut strat = spec.build();
    let per_epoch = strat.run(&mut env, epochs);
    // HopGNN adapts its schedule across epochs (merging probe); report
    // the final (frozen) epoch as steady state, like the paper's
    // "remainder of the training" framing in Fig 17.
    let steady = if per_epoch.len() > 2 && spec.adapts_across_epochs() {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cache_returns_same_instance() {
        let a = dataset("arxiv-s") as *const Dataset;
        let b = dataset("arxiv-s") as *const Dataset;
        assert_eq!(a, b);
    }

    #[test]
    fn partition_cache_hits() {
        let d = dataset("arxiv-s");
        let p1 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        let p2 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        assert_eq!(p1.part, p2.part);
    }

    #[test]
    fn concurrent_same_key_yields_one_instance() {
        // racing threads on one key must agree on the leaked instance
        let ptrs: Vec<*const Dataset> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| dataset("arxiv-s") as *const Dataset)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "{ptrs:?}");
    }

    fn tape_key(salt: u64, epoch: u64) -> SampleKey {
        SampleKey {
            dataset: 0xDEAD_0000, // synthetic: entry/commit tests only
            num_servers: 4,
            partition: PartitionAlgo::MetisLike,
            sampler: SamplerKind::NodeWise,
            seed: 42,
            batch_size: 64,
            max_iterations: 4,
            layers: 3,
            fanout: 10,
            vmax: 128,
            salt,
            epoch,
            schedule: 7,
        }
    }

    #[test]
    fn same_tape_key_commits_exactly_once() {
        // racing recorders on one key: all share the entry cell, only
        // the first commit lands, and every replayer sees that instance
        let key = tape_key(0x111, 0);
        let tapes: Vec<*const EpochTape> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4u32)
                .map(|i| {
                    scope.spawn(move || {
                        let entry = epoch_tape_entry(key).expect("entry");
                        let mut tape = EpochTape::default();
                        tape.groups.push(SampleGroup {
                            verts: vec![i; 8],
                            edges: u64::from(i),
                        });
                        commit_tape(&entry, tape);
                        Arc::as_ptr(entry.get().expect("committed"))
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(
            tapes.windows(2).all(|w| w[0] == w[1]),
            "all threads must agree on one committed tape: {tapes:?}"
        );
        // the winning tape is internally consistent (one group, its
        // own thread's payload — not a torn mix)
        let entry = epoch_tape_entry(key).expect("entry");
        let tape = entry.get().expect("still committed");
        assert_eq!(tape.groups.len(), 1);
        let g = &tape.groups[0];
        assert_eq!(g.verts.len(), 8);
        assert!(g.verts.iter().all(|&v| u64::from(v) == g.edges));
    }

    #[test]
    fn distinct_tape_keys_load_concurrently() {
        let entries: Vec<TapeEntry> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4u64)
                .map(|e| {
                    scope.spawn(move || {
                        let entry =
                            epoch_tape_entry(tape_key(0x222, e)).unwrap();
                        commit_tape(&entry, EpochTape::default());
                        entry
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        // distinct keys are distinct cells
        for i in 0..entries.len() {
            for j in i + 1..entries.len() {
                assert!(
                    !Arc::ptr_eq(&entries[i], &entries[j]),
                    "keys {i}/{j} must not share a cell"
                );
            }
        }
        // re-requesting a key hits the same cell
        let again = epoch_tape_entry(tape_key(0x222, 2)).unwrap();
        assert!(Arc::ptr_eq(&again, &entries[2]));
    }

    #[test]
    fn concurrent_distinct_partition_keys_do_not_deadlock() {
        let d = dataset("arxiv-s");
        let parts: Vec<Partition> = std::thread::scope(|scope| {
            let handles: Vec<_> = (1..=4u64)
                .map(|seed| {
                    scope.spawn(move || {
                        partition_for(d, 4, PartitionAlgo::Hash, seed)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(parts.len(), 4);
        // distinct seeds are distinct cache entries, computed
        // independently; same seed re-requested hits the same entry
        let again = partition_for(d, 4, PartitionAlgo::Hash, 1);
        assert_eq!(again.part, parts[0].part);
    }
}
