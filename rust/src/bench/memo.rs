//! Process-wide memoization for the experiment harness: loading a
//! dataset and partitioning a multi-million-edge graph are seconds-scale
//! one-time costs that dozens of experiment configurations share.
//!
//! (Formerly `bench/cache.rs` — renamed so the harness-side memo tables
//! cannot be confused with the simulated per-server feature cache,
//! `crate::featstore::cache`.)

use crate::config::RunConfig;
use crate::coordinator::{SimEnv, StrategySpec};
use crate::graph::datasets::{load, Dataset};
use crate::metrics::EpochMetrics;
use crate::partition::{partition, Partition, PartitionAlgo};
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

fn dataset_cache() -> &'static Mutex<HashMap<String, &'static Dataset>> {
    static CACHE: OnceLock<Mutex<HashMap<String, &'static Dataset>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (once) and lease a dataset for the process lifetime.
pub fn dataset(name: &str) -> &'static Dataset {
    let mut cache = dataset_cache().lock().unwrap();
    if let Some(d) = cache.get(name) {
        return d;
    }
    let d: &'static Dataset = Box::leak(Box::new(load(name)));
    cache.insert(name.to_string(), d);
    d
}

type PartKey = (String, usize, &'static str, u64);

fn partition_cache() -> &'static Mutex<HashMap<PartKey, Partition>> {
    static CACHE: OnceLock<Mutex<HashMap<PartKey, Partition>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Partition (once per key) and clone out.
pub fn partition_for(
    d: &Dataset,
    num_parts: usize,
    algo: PartitionAlgo,
    seed: u64,
) -> Partition {
    let key = (d.name.to_string(), num_parts, algo.name(), seed);
    let mut cache = partition_cache().lock().unwrap();
    if let Some(p) = cache.get(&key) {
        return p.clone();
    }
    let p = partition(&d.graph, num_parts, algo, seed);
    cache.insert(key, p.clone());
    p
}

/// Cached-run variant of `coordinator::run_strategy`: same semantics,
/// but dataset and partition come from the process-wide caches.
pub fn run(cfg: &RunConfig, spec: StrategySpec) -> EpochMetrics {
    let d = dataset(&cfg.dataset);
    let mut cfg = cfg.clone();
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let part = partition_for(
        d,
        cfg.num_servers,
        cfg.partition_algo,
        cfg.seed ^ 0x9A27,
    );
    let epochs = cfg.epochs;
    let mut env = SimEnv::with_partition(d, cfg, part);
    let mut strat = spec.build();
    let per_epoch = strat.run(&mut env, epochs);
    // HopGNN adapts its schedule across epochs (merging probe); report
    // the final (frozen) epoch as steady state, like the paper's
    // "remainder of the training" framing in Fig 17.
    let steady = if per_epoch.len() > 2 && spec.adapts_across_epochs() {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cache_returns_same_instance() {
        let a = dataset("arxiv-s") as *const Dataset;
        let b = dataset("arxiv-s") as *const Dataset;
        assert_eq!(a, b);
    }

    #[test]
    fn partition_cache_hits() {
        let d = dataset("arxiv-s");
        let p1 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        let p2 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        assert_eq!(p1.part, p2.part);
    }
}
