//! Process-wide memoization for the experiment harness: loading a
//! dataset and partitioning a multi-million-edge graph are seconds-scale
//! one-time costs that dozens of experiment configurations share.
//!
//! (Formerly `bench/cache.rs` — renamed so the harness-side memo tables
//! cannot be confused with the simulated per-server feature cache,
//! `crate::featstore::cache`.)
//!
//! Locking is **per key**, not per table: the global `Mutex` only
//! guards the `HashMap` of entry cells and is held for a handful of
//! instructions, while the seconds-scale `load` / `partition` work runs
//! under each key's own `OnceLock`. Two parallel sweep cells (the
//! `--jobs` worker pool, `util::pool`) therefore load *distinct*
//! datasets concurrently, while racing requests for the *same* key
//! block on that key alone and the expensive computation still runs
//! exactly once. (The previous design held the table mutex across the
//! whole load, which would have serialized every parallel cell.)

use crate::config::RunConfig;
use crate::coordinator::{SimEnv, StrategySpec};
use crate::graph::datasets::{load, Dataset};
use crate::metrics::EpochMetrics;
use crate::partition::{partition, Partition, PartitionAlgo};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// One dataset slot: leaked so the initialized value is `&'static`.
type DatasetEntry = &'static OnceLock<Dataset>;

fn dataset_cache() -> &'static Mutex<HashMap<String, DatasetEntry>> {
    static CACHE: OnceLock<Mutex<HashMap<String, DatasetEntry>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Load (once) and lease a dataset for the process lifetime.
/// Concurrent callers with the same name block on this key's entry
/// (the load runs once); callers with different names proceed in
/// parallel.
pub fn dataset(name: &str) -> &'static Dataset {
    let entry: DatasetEntry = {
        let mut cache = dataset_cache().lock().unwrap();
        match cache.get(name) {
            Some(e) => e,
            None => {
                let e: DatasetEntry = Box::leak(Box::new(OnceLock::new()));
                cache.insert(name.to_string(), e);
                e
            }
        }
    };
    // table lock released; only same-key callers wait here
    entry.get_or_init(|| load(name))
}

type PartKey = (String, usize, &'static str, u64);
type PartitionEntry = Arc<OnceLock<Partition>>;

fn partition_cache() -> &'static Mutex<HashMap<PartKey, PartitionEntry>> {
    static CACHE: OnceLock<Mutex<HashMap<PartKey, PartitionEntry>>> =
        OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Partition (once per key) and clone out. Same per-key locking
/// discipline as [`dataset`]: the table mutex never outlives the entry
/// lookup, so distinct keys partition concurrently.
pub fn partition_for(
    d: &Dataset,
    num_parts: usize,
    algo: PartitionAlgo,
    seed: u64,
) -> Partition {
    let key = (d.name.to_string(), num_parts, algo.name(), seed);
    let entry: PartitionEntry = {
        let mut cache = partition_cache().lock().unwrap();
        Arc::clone(
            cache
                .entry(key)
                .or_insert_with(|| Arc::new(OnceLock::new())),
        )
    };
    entry
        .get_or_init(|| partition(&d.graph, num_parts, algo, seed))
        .clone()
}

/// Cached-run variant of `coordinator::run_strategy`: same semantics,
/// but dataset and partition come from the process-wide caches.
pub fn run(cfg: &RunConfig, spec: StrategySpec) -> EpochMetrics {
    let d = dataset(&cfg.dataset);
    let mut cfg = cfg.clone();
    if let Some(pa) = spec.preferred_partition() {
        cfg.partition_algo = pa;
    }
    let part = partition_for(
        d,
        cfg.num_servers,
        cfg.partition_algo,
        cfg.seed ^ 0x9A27,
    );
    let epochs = cfg.epochs;
    let mut env = SimEnv::with_partition(d, cfg, part);
    let mut strat = spec.build();
    let per_epoch = strat.run(&mut env, epochs);
    // HopGNN adapts its schedule across epochs (merging probe); report
    // the final (frozen) epoch as steady state, like the paper's
    // "remainder of the training" framing in Fig 17.
    let steady = if per_epoch.len() > 2 && spec.adapts_across_epochs() {
        &per_epoch[per_epoch.len() - 1..]
    } else {
        &per_epoch[..]
    };
    EpochMetrics::average_of(steady)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_cache_returns_same_instance() {
        let a = dataset("arxiv-s") as *const Dataset;
        let b = dataset("arxiv-s") as *const Dataset;
        assert_eq!(a, b);
    }

    #[test]
    fn partition_cache_hits() {
        let d = dataset("arxiv-s");
        let p1 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        let p2 = partition_for(d, 4, PartitionAlgo::Hash, 1);
        assert_eq!(p1.part, p2.part);
    }

    #[test]
    fn concurrent_same_key_yields_one_instance() {
        // racing threads on one key must agree on the leaked instance
        let ptrs: Vec<*const Dataset> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| dataset("arxiv-s") as *const Dataset)
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert!(ptrs.windows(2).all(|w| w[0] == w[1]), "{ptrs:?}");
    }

    #[test]
    fn concurrent_distinct_partition_keys_do_not_deadlock() {
        let d = dataset("arxiv-s");
        let parts: Vec<Partition> = std::thread::scope(|scope| {
            let handles: Vec<_> = (1..=4u64)
                .map(|seed| {
                    scope.spawn(move || {
                        partition_for(d, 4, PartitionAlgo::Hash, seed)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(parts.len(), 4);
        // distinct seeds are distinct cache entries, computed
        // independently; same seed re-requested hits the same entry
        let again = partition_for(d, 4, PartitionAlgo::Hash, 1);
        assert_eq!(again.part, parts[0].part);
    }
}
