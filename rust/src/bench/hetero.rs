//! Heterogeneous-fabric sweep: where does the feature-centric gap
//! widen when the cluster stops being uniform?
//!
//! Sweeps topology × strategy × overlap over the named fabrics
//! (`uniform`, `rack:2`, `hetero-mix`, `straggler:0`) and reports epoch
//! time, overlap gain, feature bytes, and each system's speedup over
//! DGL per fabric. The paper's evaluation runs entirely on one uniform
//! 10 GbE switch; this experiment opens the axis the fabric layer
//! exists for — oversubscribed racks tax DGL's cross-rack feature
//! gathers harder than HopGNN's redistributed local sampling, and a
//! straggler taxes every barrier-synchronized step.
//!
//! The second section isolates HopGNN's merge controller: the paper's
//! min-load selection (fabric-oblivious) vs the fabric-aware mode
//! (`--strategy hopgnn+fa`), which weights per-worker micrograph counts
//! by observed lane compute times and re-places merged groups on fast
//! servers. Under `straggler:0` the fabric-aware merge must not lose
//! to the oblivious one — asserted by this module's tests.
//!
//! Both sections are fabric × strategy (× overlap) grids on the sweep
//! engine ([`super::sweep`]).

use super::sweep::{Axis, SweepSpec};
use super::{Report, Scale};
use crate::cluster::{FabricSpec, ModelFamily, TransferKind};
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::util::table::{fmt_bytes, fmt_secs, Table};

/// The swept topologies, in presentation order.
pub const FABRICS: [FabricSpec; 4] = [
    FabricSpec::Uniform,
    FabricSpec::Rack { racks: 2 },
    FabricSpec::HeteroMix,
    FabricSpec::Straggler { server: 0 },
];

/// Strategies in the per-fabric sweep (DGL first: the speedup
/// baseline).
pub const SWEEP_STRATEGIES: [StrategySpec; 4] = [
    StrategySpec::dgl(),
    StrategySpec::p3(),
    StrategySpec::hopgnn_mg_pg(),
    StrategySpec::hopgnn(),
];

fn cfg_for(
    scale: Scale,
    ds: &str,
    fabric: FabricSpec,
    overlap: bool,
) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        fabric,
        overlap,
        ..Default::default()
    }
}

/// Merge-comparison config: more epochs than the sweep so both merge
/// controllers can probe to convergence before the steady epoch is
/// reported.
fn merge_cfg(scale: Scale, ds: &str, fabric: FabricSpec) -> RunConfig {
    RunConfig {
        epochs: scale.epochs.max(6),
        ..cfg_for(scale, ds, fabric, true)
    }
}

/// The `hetero` experiment: epoch time per (fabric, strategy, overlap)
/// plus the fabric-aware vs fabric-oblivious merge comparison.
pub fn hetero(scale: Scale) -> Report {
    let mut r = Report::new(
        "hetero",
        "heterogeneous fabrics: epoch time per topology x strategy x \
         overlap",
    );
    let ds = if scale.quick { "arxiv-s" } else { "products-s" };
    let grid = SweepSpec::new(
        cfg_for(scale, ds, FabricSpec::Uniform, false),
        StrategySpec::hopgnn(),
    )
    .axis(Axis::fabrics(&FABRICS))
    .axis(Axis::strategies(&SWEEP_STRATEGIES))
    .axis(Axis::overlap(&[false, true]))
    .run()
    .expect("hetero grid is statically valid");
    for (fi, fabric) in FABRICS.iter().enumerate() {
        let mut t = Table::new([
            "system",
            "serial",
            "overlapped",
            "overlap gain",
            "feat moved",
            "vs DGL",
        ]);
        // DGL is SWEEP_STRATEGIES[0]: its serial epoch is the baseline
        let dgl_serial = grid.metrics(&[fi, 0, 0]).epoch_time;
        for (ki, spec) in SWEEP_STRATEGIES.iter().enumerate() {
            let serial = grid.metrics(&[fi, ki, 0]);
            let over = grid.metrics(&[fi, ki, 1]);
            t.row([
                spec.name(),
                fmt_secs(serial.epoch_time),
                fmt_secs(over.epoch_time),
                format!("{:.2}x", serial.epoch_time / over.epoch_time),
                fmt_bytes(serial.bytes(TransferKind::Feature)),
                format!("{:.2}x", dgl_serial / serial.epoch_time),
            ]);
        }
        r.section(
            format!("fabric {} (GCN on {ds}, 4 servers)", fabric.name()),
            t,
        );
    }

    // fabric-aware vs fabric-oblivious merging (overlap on, steady
    // epoch after the controllers converge)
    let merge_grid = SweepSpec::new(
        merge_cfg(scale, ds, FabricSpec::Uniform),
        StrategySpec::hopgnn(),
    )
    .axis(Axis::fabrics(&FABRICS))
    .axis(Axis::strategies(&[
        StrategySpec::hopgnn(),
        StrategySpec::hopgnn_fa(),
    ]))
    .run()
    .expect("merge grid is statically valid");
    let mut t = Table::new([
        "fabric",
        "HopGNN (min-load)",
        "steps",
        "HopGNN-FA",
        "FA steps",
        "FA gain",
    ]);
    for (fi, fabric) in FABRICS.iter().enumerate() {
        let ob = merge_grid.metrics(&[fi, 0]);
        let fa = merge_grid.metrics(&[fi, 1]);
        t.row([
            fabric.name(),
            fmt_secs(ob.epoch_time),
            format!("{:.1}", ob.time_steps_per_iter),
            fmt_secs(fa.epoch_time),
            format!("{:.1}", fa.time_steps_per_iter),
            format!("{:.2}x", ob.epoch_time / fa.epoch_time),
        ]);
    }
    r.section(
        "merging under heterogeneity: min-load vs fabric-aware \
         (overlap on, steady epoch)",
        t,
    );
    r.note(
        "fabrics: rack:2 = two racks behind a 4:1 oversubscribed spine; \
         hetero-mix = the upper half of the servers has 4x slower NICs; \
         straggler:0 = server 0 has 4x slower links and half-speed \
         compute",
    );
    r.note(
        "vs DGL = DGL serial epoch / system serial epoch on the same \
         fabric — the feature-centric gap per topology",
    );
    r.note(
        "FA gain = min-load steady epoch / fabric-aware steady epoch: \
         the fabric-aware controller weights per-worker micrograph \
         counts by observed lane compute times and re-places merged \
         groups on fast servers, so it load-balances away from the \
         straggler",
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::memo;

    fn tiny_scale() -> Scale {
        Scale {
            epochs: 2,
            max_iterations: Some(2),
            batch: 128,
            quick: true,
        }
    }

    #[test]
    fn report_renders_every_fabric_and_strategy() {
        let r = hetero(tiny_scale());
        let s = r.render();
        for fabric in FABRICS {
            assert!(s.contains(&fabric.name()), "{s}");
        }
        for spec in SWEEP_STRATEGIES {
            assert!(s.contains(&spec.name()), "{s}");
        }
        assert!(s.contains("HopGNN-FA"), "{s}");
    }

    #[test]
    fn non_uniform_fabrics_slow_the_gather_bound_baseline() {
        let scale = tiny_scale();
        let uni = memo::run(
            &cfg_for(scale, "arxiv-s", FabricSpec::Uniform, false),
            StrategySpec::dgl(),
        );
        for fabric in [
            FabricSpec::Rack { racks: 2 },
            FabricSpec::HeteroMix,
            FabricSpec::Straggler { server: 0 },
        ] {
            let het = memo::run(
                &cfg_for(scale, "arxiv-s", fabric, false),
                StrategySpec::dgl(),
            );
            assert!(
                het.epoch_time > uni.epoch_time,
                "{}: {} !> uniform {}",
                fabric.name(),
                het.epoch_time,
                uni.epoch_time
            );
            // byte counts are topology-invariant: the fabric changes
            // when time passes, never what moves
            assert_eq!(het.total_bytes(), uni.total_bytes());
        }
    }

    #[test]
    fn fabric_aware_merge_beats_oblivious_under_straggler() {
        // the tentpole acceptance: with one straggler server, weighting
        // the merge by observed lane times must not lose to min-load,
        // and the steady epoch should actually improve
        let scale = Scale {
            epochs: 6,
            max_iterations: Some(3),
            batch: 256,
            quick: true,
        };
        let fabric = FabricSpec::Straggler { server: 0 };
        let ob = memo::run(
            &merge_cfg(scale, "arxiv-s", fabric),
            StrategySpec::hopgnn(),
        );
        let fa = memo::run(
            &merge_cfg(scale, "arxiv-s", fabric),
            StrategySpec::hopgnn_fa(),
        );
        // 1% slack absorbs micrograph sampling noise once the two
        // schedules diverge; the expected gap is far larger (the
        // oblivious round-robin redistribution piles merged groups
        // onto the straggler and freezes early)
        assert!(
            fa.epoch_time <= ob.epoch_time * 1.01,
            "fabric-aware merge lost to min-load under a straggler: \
             {} > {}",
            fa.epoch_time,
            ob.epoch_time
        );
        // and on the uniform fabric FA stays competitive with min-load
        // (same selection, balanced placement)
        let uni_ob = memo::run(
            &merge_cfg(scale, "arxiv-s", FabricSpec::Uniform),
            StrategySpec::hopgnn(),
        );
        let uni_fa = memo::run(
            &merge_cfg(scale, "arxiv-s", FabricSpec::Uniform),
            StrategySpec::hopgnn_fa(),
        );
        assert!(
            uni_fa.epoch_time <= uni_ob.epoch_time * 1.05,
            "FA regressed on the uniform fabric: {} vs {}",
            uni_fa.epoch_time,
            uni_ob.epoch_time
        );
    }
}
