//! Cache sweep: what does the feature-cache tier buy, per policy, per
//! capacity, per strategy — on top of the `overlap` scenario?
//!
//! Runs the communication-bound fixed-schedule strategies (DGL's
//! per-step gather, LO's redistributed local gather, HopGNN +PG's
//! merged pre-gather — three different gather emission styles) with
//! the driver's overlap mode on, sweeping every
//! [`CachePolicy`] across a capacity ladder from 0 (the locked parity
//! configuration) to "holds the working set". Adaptive-schedule
//! strategies are excluded on purpose: the merge controller reacts to
//! epoch times, so its request stream would change across capacities
//! and hit rates would not be comparable column-to-column.
//!
//! Declared as a policy × strategy × capacity grid on the sweep engine
//! ([`super::sweep`]).
//!
//! The acceptance property — hit rate monotonically non-decreasing in
//! capacity for every policy — is asserted by this module's tests: LRU
//! has the stack-inclusion property (fixed-size rows), and the static
//! policies pin supersets as capacity grows.

use super::sweep::{Axis, SweepSpec};
use super::{memo, Report, Scale};
use crate::cluster::{ModelFamily, TransferKind};
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::featstore::cache::{ALL_CACHE_POLICIES, CachePolicy};
use crate::metrics::EpochMetrics;
use crate::util::table::{fmt_bytes, fmt_secs, Table};

/// Fixed-schedule strategies whose gather streams are capacity-
/// invariant (comparable hit rates).
pub const SWEEP_STRATEGIES: [StrategySpec; 3] = [
    StrategySpec::dgl(),
    StrategySpec::locality_opt(),
    StrategySpec::hopgnn_mg_pg(),
];

/// Capacity ladder in MiB (0 = parity configuration).
pub fn capacities_mb(scale: Scale) -> Vec<usize> {
    if scale.quick {
        vec![0, 2, 8, 32]
    } else {
        vec![0, 16, 64, 256]
    }
}

fn cfg_for(scale: Scale, ds: &str, policy: CachePolicy, mb: usize) -> RunConfig {
    let model = ModelFamily::Gcn;
    RunConfig {
        dataset: ds.into(),
        model,
        layers: model.default_layers(),
        batch_size: scale.batch,
        epochs: scale.epochs,
        max_iterations: scale.max_iterations,
        vmax: RunConfig::full_sim_vmax(model.default_layers(), 10),
        fanout: 10,
        overlap: true,
        cache_policy: policy,
        cache_mb: mb,
        ..Default::default()
    }
}

/// One sweep cell: (policy, capacity, strategy) -> averaged epoch.
pub fn sweep_cell(
    scale: Scale,
    ds: &str,
    policy: CachePolicy,
    mb: usize,
    spec: StrategySpec,
) -> EpochMetrics {
    memo::run(&cfg_for(scale, ds, policy, mb), spec)
}

/// The `cachesweep` experiment: hit rate / bytes saved / epoch time per
/// (policy, capacity, strategy) over the overlap scenario.
pub fn cachesweep(scale: Scale) -> Report {
    let mut r = Report::new(
        "cachesweep",
        "feature cache: hit rate and epoch time vs capacity, per policy",
    );
    let ds = if scale.quick { "arxiv-s" } else { "products-s" };
    let caps = capacities_mb(scale);
    let grid =
        SweepSpec::new(cfg_for(scale, ds, CachePolicy::Lru, 0), StrategySpec::dgl())
            .axis(Axis::cache_policies(&ALL_CACHE_POLICIES))
            .axis(Axis::strategies(&SWEEP_STRATEGIES))
            .axis(Axis::cache_capacities_mb(&caps))
            .run()
            .expect("cachesweep grid is statically valid");
    for (pi, policy) in ALL_CACHE_POLICIES.iter().enumerate() {
        let mut t = Table::new([
            "system",
            "capacity",
            "hit rate",
            "feat moved",
            "bytes saved",
            "epoch",
        ]);
        for (ki, spec) in SWEEP_STRATEGIES.iter().enumerate() {
            let mut prev_rate = -1.0f64;
            for (ci, &mb) in caps.iter().enumerate() {
                let m = grid.metrics(&[pi, ki, ci]);
                let rate = m.cache_hit_rate();
                debug_assert!(
                    rate + 1e-12 >= prev_rate,
                    "{} {} hit rate regressed at {mb} MiB",
                    policy.name(),
                    spec.name()
                );
                prev_rate = rate;
                t.row([
                    spec.name(),
                    format!("{mb} MiB"),
                    format!("{:.1}%", rate * 100.0),
                    fmt_bytes(m.bytes(TransferKind::Feature)),
                    fmt_bytes(m.cache_hit_bytes),
                    fmt_secs(m.epoch_time),
                ]);
            }
        }
        r.section(
            format!(
                "policy {} (GCN on {ds}, 4 servers, overlap on)",
                policy.name()
            ),
            t,
        );
    }
    r.note(
        "hit rate = cache hits / (hits + misses) over remote feature \
         requests; 0 MiB is the parity configuration (cache path active, \
         nothing admitted) locked bit-identical to the uncached driver by \
         tests/cache_parity.rs",
    );
    r.note(
        "bytes saved = feature bytes served from the cache instead of the \
         network; feat moved + bytes saved is capacity-invariant per \
         strategy (byte conservation)",
    );
    r.note(
        "adaptive-schedule strategies (HopGNN full, RD) are excluded: \
         their merge controllers react to epoch time, so request streams \
         would differ across capacities",
    );
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_scale() -> Scale {
        Scale {
            epochs: 2,
            max_iterations: Some(2),
            batch: 128,
            quick: true,
        }
    }

    #[test]
    fn report_renders_every_policy() {
        let r = cachesweep(tiny_scale());
        let s = r.render();
        for policy in ALL_CACHE_POLICIES {
            assert!(s.contains(policy.name()), "{s}");
        }
        assert!(s.contains("hit rate"), "{s}");
    }

    #[test]
    fn hit_rate_monotone_in_capacity_for_every_policy() {
        // the cachesweep acceptance criterion, asserted release-mode too
        let scale = tiny_scale();
        for policy in ALL_CACHE_POLICIES {
            for spec in SWEEP_STRATEGIES {
                let mut prev = -1.0f64;
                for &mb in &capacities_mb(scale) {
                    let m = sweep_cell(scale, "arxiv-s", policy, mb, spec);
                    let rate = m.cache_hit_rate();
                    assert!(
                        rate + 1e-12 >= prev,
                        "{}/{}: hit rate fell from {prev} to {rate} at \
                         {mb} MiB",
                        policy.name(),
                        spec.name()
                    );
                    prev = rate;
                }
                assert!(
                    prev > 0.0,
                    "{}/{}: largest capacity never hit",
                    policy.name(),
                    spec.name()
                );
            }
        }
    }

    #[test]
    fn byte_conservation_across_capacities() {
        let scale = tiny_scale();
        let spec = StrategySpec::dgl();
        let baseline =
            sweep_cell(scale, "arxiv-s", CachePolicy::Lru, 0, spec);
        let requested = baseline.cache_hit_bytes + baseline.cache_miss_bytes;
        for &mb in &capacities_mb(scale)[1..] {
            let m = sweep_cell(scale, "arxiv-s", CachePolicy::Lru, mb, spec);
            assert_eq!(
                m.cache_hit_bytes + m.cache_miss_bytes,
                requested,
                "requested bytes must be capacity-invariant"
            );
            assert_eq!(m.cache_miss_bytes, m.bytes(TransferKind::Feature));
        }
    }
}
