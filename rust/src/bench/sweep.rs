//! Declarative sweep engine: experiments as cartesian grids of named
//! axes instead of hand-rolled nested loops.
//!
//! A [`SweepSpec`] is a base [`RunConfig`] + default [`StrategySpec`] +
//! a list of [`Axis`]es. Each axis value is either a strategy spec or a
//! batch of `key = value` config patches (the same keys
//! [`RunConfig::set`] accepts), so *anything* the config can express is
//! sweepable — fabrics, cache policies, capacities, overlap, datasets,
//! models, cost constants. [`SweepSpec::run`] expands the full product
//! (validating every cell *before* running any), executes each cell
//! through the memoized runner ([`super::memo`]), and returns a
//! [`SweepGrid`] the experiment renders into its [`super::Report`] —
//! or, for the `bench sweep` CLI path, via the generic
//! [`SweepGrid::table`].
//!
//! The grid-shaped experiments (`hetero`, `cachesweep`, `overlap`,
//! `scale`, and the ablation figures) are all built on this engine;
//! only trajectory experiments that need per-epoch history (Fig 17)
//! still drive strategies directly.
//!
//! # Parallel execution (`--jobs`)
//!
//! Grid cells are independent, so [`SweepSpec::run`] executes them on a
//! scoped worker pool ([`crate::util::pool`]). `--jobs N` is a *total
//! thread budget*, not just a cell-worker count: with `C` cells,
//! `min(N, C)` runners execute cells concurrently and each runner's
//! epoch drivers get a lane allowance of `N / min(N, C)` threads
//! ([`crate::util::pool::LaneAllowanceGuard`], installed inside the
//! cell closure on whichever thread runs it). The split depends only
//! on the budget and the cell count, so nested cell x lane parallelism
//! never oversubscribes the budget (`tests/pool_budget.rs`) and
//! `--jobs 1` vs `--jobs N` — with or without `parallel_lanes` —
//! produce bit-identical grids and reports, locked by
//! `tests/sweep_parallel.rs`. The budget comes from [`SweepSpec::jobs`]
//! when set, else the process-wide [`crate::util::pool::thread_budget`]
//! (wired to the CLI `--jobs` flags; `0` = available parallelism).
//! Only [`SweepCell::wall_secs`] (host wall-clock, reported by the
//! `scale` experiment) varies with scheduling.

use super::memo;
use crate::cluster::FabricSpec;
use crate::config::RunConfig;
use crate::coordinator::StrategySpec;
use crate::featstore::cache::CachePolicy;
use crate::featstore::tier::TierSpec;
use crate::graph::datasets;
use crate::metrics::EpochMetrics;
use crate::util::pool;
use crate::util::table::{fmt_bytes, fmt_secs, Table};

/// One point on an axis: a strategy, or a labeled batch of config
/// patches applied through [`RunConfig::set`].
#[derive(Clone)]
pub enum AxisValue {
    /// Selects the strategy for the cell (overrides the sweep default).
    Strategy(StrategySpec),
    /// Applies `key = value` patches to the cell's config.
    Patch {
        label: String,
        kv: Vec<(String, String)>,
    },
}

impl AxisValue {
    /// Display label for grid lookups and the generic table.
    pub fn label(&self) -> String {
        match self {
            Self::Strategy(s) => s.name(),
            Self::Patch { label, .. } => label.clone(),
        }
    }
}

/// A named list of sweep points; the grid is the product of all axes.
#[derive(Clone)]
pub struct Axis {
    pub name: String,
    pub values: Vec<AxisValue>,
}

impl Axis {
    pub fn new(name: impl Into<String>, values: Vec<AxisValue>) -> Self {
        Self {
            name: name.into(),
            values,
        }
    }

    /// Strategy axis: one cell per spec.
    pub fn strategies(specs: &[StrategySpec]) -> Self {
        Self::new(
            "strategy",
            specs.iter().map(|&s| AxisValue::Strategy(s)).collect(),
        )
    }

    /// Generic single-key axis: label == value (e.g. a `dataset` axis).
    pub fn key(key: &str, values: &[&str]) -> Self {
        Self::new(
            key,
            values
                .iter()
                .map(|v| AxisValue::Patch {
                    label: (*v).to_string(),
                    kv: vec![(key.to_string(), (*v).to_string())],
                })
                .collect(),
        )
    }

    /// Fabric-topology axis over named [`FabricSpec`]s.
    pub fn fabrics(specs: &[FabricSpec]) -> Self {
        Self::new(
            "fabric",
            specs
                .iter()
                .map(|f| AxisValue::Patch {
                    label: f.name(),
                    kv: vec![("fabric".to_string(), f.name())],
                })
                .collect(),
        )
    }

    /// Overlap axis (`serial` / `overlap` cells).
    pub fn overlap(values: &[bool]) -> Self {
        Self::new(
            "overlap",
            values
                .iter()
                .map(|&b| AxisValue::Patch {
                    label: if b { "overlap" } else { "serial" }.to_string(),
                    kv: vec![("overlap".to_string(), b.to_string())],
                })
                .collect(),
        )
    }

    /// Feature tier-stack axis over parsed [`TierSpec`]s (one cell per
    /// stack, labeled by the canonical spec spelling).
    pub fn tiers(specs: &[TierSpec]) -> Self {
        Self::new(
            "tiers",
            specs
                .iter()
                .map(|t| AxisValue::Patch {
                    label: t.name(),
                    kv: vec![("tiers".to_string(), t.name())],
                })
                .collect(),
        )
    }

    /// Feature-cache policy axis.
    pub fn cache_policies(policies: &[CachePolicy]) -> Self {
        Self::new(
            "cache",
            policies
                .iter()
                .map(|p| AxisValue::Patch {
                    label: p.name().to_string(),
                    kv: vec![("cache".to_string(), p.name().to_string())],
                })
                .collect(),
        )
    }

    /// Feature-cache capacity ladder (MiB per server).
    pub fn cache_capacities_mb(caps: &[usize]) -> Self {
        Self::new(
            "cache_mb",
            caps.iter()
                .map(|&mb| AxisValue::Patch {
                    label: format!("{mb} MiB"),
                    kv: vec![("cache_mb".to_string(), mb.to_string())],
                })
                .collect(),
        )
    }

    /// Fully general patch axis: named values, each a list of
    /// `key = value` settings.
    pub fn patches(
        name: impl Into<String>,
        values: Vec<(String, Vec<(String, String)>)>,
    ) -> Self {
        Self::new(
            name,
            values
                .into_iter()
                .map(|(label, kv)| AxisValue::Patch { label, kv })
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn label(&self, i: usize) -> String {
        self.values[i].label()
    }
}

/// One expanded (not yet executed) cell: grid index, strategy, config.
pub type ExpandedCell = (Vec<usize>, StrategySpec, RunConfig);

/// A declarative experiment: base config, default strategy, axes.
pub struct SweepSpec {
    pub base: RunConfig,
    pub strategy: StrategySpec,
    pub axes: Vec<Axis>,
    /// Thread budget for [`Self::run`] (`None` = the process-wide
    /// [`crate::util::pool::thread_budget`]; `Some(0)` = auto).
    pub jobs: Option<usize>,
}

impl SweepSpec {
    pub fn new(base: RunConfig, strategy: StrategySpec) -> Self {
        Self {
            base,
            strategy,
            axes: Vec::new(),
            jobs: None,
        }
    }

    /// Append an axis (builder style). Later axes vary fastest.
    pub fn axis(mut self, axis: Axis) -> Self {
        self.axes.push(axis);
        self
    }

    /// Pin this sweep's total thread budget — cell runners x epoch
    /// lanes (builder style; `0` = all cores). Unset falls back to the
    /// process-wide [`crate::util::pool::thread_budget`].
    pub fn jobs(mut self, jobs: usize) -> Self {
        self.jobs = Some(jobs);
        self
    }

    /// Cells in the full product.
    pub fn num_cells(&self) -> usize {
        self.axes.iter().map(Axis::len).product()
    }

    /// Expand the cartesian grid into (index, strategy, config) cells in
    /// row-major order (last axis fastest), validating every strategy
    /// spec, config patch, and dataset name — a bad cell fails the
    /// whole sweep here, before anything has run. A cell's strategy is
    /// resolved as: strategy-axis value, else the config's `strategy =`
    /// field (base or patched), else [`SweepSpec::strategy`].
    pub fn expand(&self) -> Result<Vec<ExpandedCell>, String> {
        for ax in &self.axes {
            if ax.is_empty() {
                return Err(format!("sweep axis '{}' has no values", ax.name));
            }
        }
        self.strategy
            .validate()
            .map_err(|e| format!("sweep base strategy: {e}"))?;
        let total = self.num_cells();
        let mut cells = Vec::with_capacity(total);
        let mut index = vec![0usize; self.axes.len()];
        for _ in 0..total {
            let mut cfg = self.base.clone();
            let mut axis_strategy = None;
            for (ax, &i) in self.axes.iter().zip(&index) {
                match &ax.values[i] {
                    AxisValue::Strategy(s) => {
                        s.validate().map_err(|e| {
                            format!("sweep axis '{}' value '{s}': {e}", ax.name)
                        })?;
                        axis_strategy = Some(*s);
                    }
                    AxisValue::Patch { label, kv } => {
                        for (k, v) in kv {
                            cfg.set(k, v).map_err(|e| {
                                format!(
                                    "sweep axis '{}' value '{label}': {e}",
                                    ax.name
                                )
                            })?;
                        }
                    }
                }
            }
            // the runner loads datasets by name and panics on unknown
            // ones; catch that here so the fail-fast promise holds for
            // the dataset axis too (named suite entries and the
            // `synth:` grammar both validate without loading)
            datasets::validate_name(&cfg.dataset)
                .map_err(|e| format!("sweep cell: {e}"))?;
            // strategy resolution: a strategy axis wins, then a
            // `strategy =` config patch, then the sweep default
            let strategy =
                axis_strategy.or(cfg.strategy).unwrap_or(self.strategy);
            strategy.validate().map_err(|e| {
                format!("sweep cell strategy '{strategy}': {e}")
            })?;
            cells.push((index.clone(), strategy, cfg));
            // odometer: advance the last axis first
            for d in (0..index.len()).rev() {
                index[d] += 1;
                if index[d] < self.axes[d].len() {
                    break;
                }
                index[d] = 0;
            }
        }
        Ok(cells)
    }

    /// Expand and execute every cell through [`memo::run`], on the
    /// worker pool when more than one job is configured. Datasets and
    /// partitions load through the memo's per-key entry locks, so
    /// cells over distinct datasets load concurrently while identical
    /// keys still load exactly once. Cell results land in deterministic
    /// row-major grid order whatever the worker interleaving.
    pub fn run(&self) -> Result<SweepGrid, String> {
        let expanded = self.expand()?;
        let budget = pool::resolve_jobs(
            self.jobs.unwrap_or_else(pool::thread_budget),
        );
        // deterministic budget split: every cell runner gets the same
        // lane allowance, a pure function of (budget, cell count) —
        // never of which worker picks up which cell
        let runners = budget.min(expanded.len()).max(1);
        let lane_share = budget / runners;
        let cells = pool::run_indexed(expanded.len(), runners, |i| {
            let _lanes = pool::LaneAllowanceGuard::set(lane_share);
            let (index, strategy, cfg) = &expanded[i];
            let t0 = std::time::Instant::now();
            let metrics = memo::run(cfg, *strategy);
            SweepCell {
                index: index.clone(),
                strategy: *strategy,
                cfg: cfg.clone(),
                metrics,
                wall_secs: t0.elapsed().as_secs_f64(),
            }
        });
        Ok(SweepGrid {
            axes: self.axes.clone(),
            cells,
        })
    }
}

/// One executed grid point.
pub struct SweepCell {
    /// Position along each axis (same order as [`SweepGrid::axes`]).
    pub index: Vec<usize>,
    pub strategy: StrategySpec,
    pub cfg: RunConfig,
    pub metrics: EpochMetrics,
    /// Host wall-clock spent executing this cell (including any
    /// first-touch dataset/partition load the cell won the race for).
    /// The one non-deterministic field: the `scale` experiment reports
    /// it as simulated-seconds-per-wall-second; the parity-locked
    /// reports never render it.
    pub wall_secs: f64,
}

/// The executed product grid, indexable by per-axis positions.
pub struct SweepGrid {
    pub axes: Vec<Axis>,
    /// Row-major over the axes (last axis fastest).
    pub cells: Vec<SweepCell>,
}

impl SweepGrid {
    /// The cell at the given per-axis positions.
    pub fn get(&self, index: &[usize]) -> &SweepCell {
        assert_eq!(
            index.len(),
            self.axes.len(),
            "sweep index rank mismatch"
        );
        let mut flat = 0usize;
        for (d, &i) in index.iter().enumerate() {
            assert!(
                i < self.axes[d].len(),
                "axis '{}': index {i} out of range",
                self.axes[d].name
            );
            flat = flat * self.axes[d].len() + i;
        }
        &self.cells[flat]
    }

    /// Shorthand for `get(index).metrics`.
    pub fn metrics(&self, index: &[usize]) -> &EpochMetrics {
        &self.get(index).metrics
    }

    /// Generic rendering for the `bench sweep` CLI: one row per cell
    /// with the axis labels and the headline metrics.
    pub fn table(&self) -> Table {
        let has_strategy_axis = self
            .axes
            .iter()
            .any(|a| matches!(a.values.first(), Some(AxisValue::Strategy(_))));
        let mut headers: Vec<String> = Vec::new();
        if !has_strategy_axis {
            headers.push("strategy".to_string());
        }
        headers.extend(self.axes.iter().map(|a| a.name.clone()));
        for h in [
            "epoch",
            "feat moved",
            "total moved",
            "hit rate",
            "steps/iter",
            "dropped roots",
        ] {
            headers.push(h.to_string());
        }
        let mut t = Table::new(headers);
        for cell in &self.cells {
            let m = &cell.metrics;
            let mut row: Vec<String> = Vec::new();
            if !has_strategy_axis {
                row.push(cell.strategy.name());
            }
            for (d, &i) in cell.index.iter().enumerate() {
                row.push(self.axes[d].label(i));
            }
            row.push(fmt_secs(m.epoch_time));
            row.push(fmt_bytes(
                m.bytes(crate::cluster::TransferKind::Feature),
            ));
            row.push(fmt_bytes(m.total_bytes()));
            row.push(format!("{:.1}%", m.cache_hit_rate() * 100.0));
            row.push(format!("{:.1}", m.time_steps_per_iter));
            row.push(m.dropped_roots.to_string());
            t.row(row);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_base() -> RunConfig {
        RunConfig {
            dataset: "arxiv-s".into(),
            batch_size: 128,
            epochs: 1,
            max_iterations: Some(1),
            ..Default::default()
        }
    }

    #[test]
    fn expansion_is_row_major_and_patches_apply() {
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::strategies(&[
                StrategySpec::dgl(),
                StrategySpec::hopgnn(),
            ]))
            .axis(Axis::overlap(&[false, true]));
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 4);
        // last axis fastest: (dgl, serial), (dgl, overlap), (hop, ...)
        assert_eq!(cells[0].0, vec![0, 0]);
        assert_eq!(cells[1].0, vec![0, 1]);
        assert_eq!(cells[2].0, vec![1, 0]);
        assert!(!cells[0].2.overlap);
        assert!(cells[1].2.overlap);
        assert_eq!(cells[2].1, StrategySpec::hopgnn());
        assert_eq!(cells[0].1, StrategySpec::dgl());
    }

    #[test]
    fn bad_cells_fail_the_whole_sweep_before_running() {
        // invalid strategy spec in an axis
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl()).axis(
            Axis::strategies(&[StrategySpec::dgl().pregather(true)]),
        );
        let e = spec.expand().unwrap_err();
        assert!(e.contains("micrograph"), "{e}");
        // invalid config patch
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl()).axis(
            Axis::key("fabric", &["mesh"]),
        );
        let e = spec.expand().unwrap_err();
        assert!(e.contains("fabric"), "{e}");
        // unknown dataset (the runner would panic; expand must catch it)
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::key("dataset", &["arxiv-s", "prodcts-s"]));
        let e = spec.expand().unwrap_err();
        assert!(e.contains("unknown dataset 'prodcts-s'"), "{e}");
        // empty axis
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::strategies(&[]));
        assert!(spec.expand().unwrap_err().contains("no values"));
    }

    #[test]
    fn strategy_config_patches_select_the_cell_strategy() {
        // `strategy = <spec>` works as a patch axis (and in the base
        // config), losing only to an explicit strategy axis
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::key("strategy", &["p3", "hopgnn-merge"]));
        let cells = spec.expand().unwrap();
        assert_eq!(cells[0].1, StrategySpec::p3());
        assert_eq!(cells[1].1, StrategySpec::hopgnn_mg_pg());
        // base-config strategy beats the sweep default
        let mut base = tiny_base();
        base.strategy = Some(StrategySpec::locality_opt());
        let cells = SweepSpec::new(base, StrategySpec::dgl())
            .expand()
            .unwrap();
        assert_eq!(cells[0].1, StrategySpec::locality_opt());
        // ...but an explicit strategy axis wins over the patch
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::key("strategy", &["p3"]))
            .axis(Axis::strategies(&[StrategySpec::naive()]));
        let cells = spec.expand().unwrap();
        assert_eq!(cells[0].1, StrategySpec::naive());
    }

    #[test]
    fn executed_grid_matches_direct_memo_runs() {
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::strategies(&[
                StrategySpec::dgl(),
                StrategySpec::hopgnn_mg_pg(),
            ]))
            .axis(Axis::overlap(&[false, true]));
        let grid = spec.run().unwrap();
        assert_eq!(grid.cells.len(), 4);
        for (si, strat) in
            [StrategySpec::dgl(), StrategySpec::hopgnn_mg_pg()]
                .into_iter()
                .enumerate()
        {
            for (oi, overlap) in [false, true].into_iter().enumerate() {
                let direct = memo::run(
                    &RunConfig {
                        overlap,
                        ..tiny_base()
                    },
                    strat,
                );
                let cell = grid.get(&[si, oi]);
                assert_eq!(cell.strategy, strat);
                assert_eq!(
                    cell.metrics.epoch_time.to_bits(),
                    direct.epoch_time.to_bits(),
                    "{strat} overlap={overlap}"
                );
                assert_eq!(
                    cell.metrics.total_bytes(),
                    direct.total_bytes()
                );
            }
        }
    }

    #[test]
    fn generic_table_renders_every_cell() {
        let grid = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .axis(Axis::fabrics(&[
                FabricSpec::Uniform,
                FabricSpec::Straggler { server: 0 },
            ]))
            .run()
            .unwrap();
        let s = grid.table().render();
        assert!(s.contains("uniform"), "{s}");
        assert!(s.contains("straggler:0"), "{s}");
        // no strategy axis: the default strategy column is prepended
        assert!(s.contains("DGL"), "{s}");
        // dropped-root accounting is always surfaced, even when zero
        assert!(s.contains("dropped roots"), "{s}");
    }

    #[test]
    fn tiers_axis_patches_the_stack_per_cell() {
        let spec = SweepSpec::new(tiny_base(), StrategySpec::dgl()).axis(
            Axis::tiers(&[
                TierSpec::remote_only(),
                TierSpec::parse("hbm:1m:lru+dram:4m:lru+remote").unwrap(),
            ]),
        );
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].2.tiers, Some(TierSpec::remote_only()));
        assert_eq!(
            cells[1].2.tiers,
            Some(TierSpec::parse("hbm:1m:lru+dram:4m:lru+remote").unwrap())
        );
        // labels are the canonical spec spellings
        assert_eq!(spec.axes[0].label(0), "remote");
        assert_eq!(spec.axes[0].label(1), "hbm:1m:lru+dram:4m:lru+remote");
    }

    #[test]
    fn jobs_do_not_change_cell_metrics() {
        // the full grid-level lock lives in tests/sweep_parallel.rs;
        // this is the quick in-module smoke of the same property
        let spec = || {
            SweepSpec::new(tiny_base(), StrategySpec::dgl())
                .axis(Axis::strategies(&[
                    StrategySpec::dgl(),
                    StrategySpec::hopgnn(),
                ]))
                .axis(Axis::overlap(&[false, true]))
        };
        let a = spec().jobs(1).run().unwrap();
        let b = spec().jobs(4).run().unwrap();
        assert_eq!(a.cells.len(), b.cells.len());
        for (ca, cb) in a.cells.iter().zip(&b.cells) {
            assert_eq!(ca.index, cb.index, "grid order must be stable");
            assert_eq!(ca.strategy, cb.strategy);
            assert_eq!(
                ca.metrics.epoch_time.to_bits(),
                cb.metrics.epoch_time.to_bits()
            );
            assert_eq!(ca.metrics.total_bytes(), cb.metrics.total_bytes());
        }
    }

    #[test]
    fn zero_axes_is_a_single_cell() {
        let grid = SweepSpec::new(tiny_base(), StrategySpec::dgl())
            .run()
            .unwrap();
        assert_eq!(grid.cells.len(), 1);
        assert!(grid.metrics(&[]).epoch_time > 0.0);
    }
}
