//! Per-request latency accounting for the serving engine.
//!
//! [`ServeMetrics`] folds every completed request into streaming
//! aggregates: end-to-end latency decomposed into queue / gather /
//! compute, tail quantiles via the P² estimator
//! ([`crate::util::stats::P2Quantile`] — O(1) space, allocation-free,
//! validated against exact sort-based quantiles by
//! `tests/serve_parity.rs`), sustained QPS over the stream makespan,
//! and the transport-layer [`EpochMetrics`] (bytes moved, per-tier hit
//! contribution) the request batches accumulated on the way.
//!
//! A serve report is only *valid* if every offered request was served:
//! [`ServeMetrics::validate`] fails on dropped or unaccounted requests
//! instead of letting a truncated run masquerade as a fast one.

use crate::metrics::EpochMetrics;
use crate::util::stats::P2Quantile;
use crate::util::table::{fmt_secs, Table};

/// Streaming aggregates over one serving run.
#[derive(Clone, Debug)]
pub struct ServeMetrics {
    /// Requests the workload generator offered.
    pub offered: u64,
    /// Requests that completed service.
    pub served: u64,
    /// Requests rejected by the bounded admission queue.
    pub dropped: u64,
    /// Micro-batches executed (served / batches = mean batch size).
    pub batches: u64,
    /// Component latency sums across served requests (seconds).
    pub sum_queue: f64,
    pub sum_gather: f64,
    pub sum_compute: f64,
    pub sum_total: f64,
    /// Worst end-to-end latency observed.
    pub max_total: f64,
    /// Completion time of the last request (run wall time in simulated
    /// seconds) — the denominator of sustained QPS.
    pub makespan: f64,
    /// Transport-layer accounting accumulated by the request batches
    /// (bytes by kind, cache/tier hits — the per-tier hit contribution).
    pub transport: EpochMetrics,
    p50: P2Quantile,
    p95: P2Quantile,
    p99: P2Quantile,
}

impl Default for ServeMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self {
            offered: 0,
            served: 0,
            dropped: 0,
            batches: 0,
            sum_queue: 0.0,
            sum_gather: 0.0,
            sum_compute: 0.0,
            sum_total: 0.0,
            max_total: 0.0,
            makespan: 0.0,
            transport: EpochMetrics::default(),
            p50: P2Quantile::new(0.50),
            p95: P2Quantile::new(0.95),
            p99: P2Quantile::new(0.99),
        }
    }

    /// Fold one served request in (allocation-free).
    pub fn observe(&mut self, queue: f64, gather: f64, compute: f64) {
        let total = queue + gather + compute;
        self.served += 1;
        self.sum_queue += queue;
        self.sum_gather += gather;
        self.sum_compute += compute;
        self.sum_total += total;
        self.max_total = self.max_total.max(total);
        self.p50.observe(total);
        self.p95.observe(total);
        self.p99.observe(total);
    }

    pub fn p50(&self) -> f64 {
        self.p50.value()
    }

    pub fn p95(&self) -> f64 {
        self.p95.value()
    }

    pub fn p99(&self) -> f64 {
        self.p99.value()
    }

    pub fn mean_latency(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.sum_total / self.served as f64
        }
    }

    /// Sustained throughput: served requests over the stream makespan.
    pub fn qps(&self) -> f64 {
        if self.makespan > 0.0 {
            self.served as f64 / self.makespan
        } else {
            0.0
        }
    }

    /// Mean requests coalesced per micro-batch.
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.served as f64 / self.batches as f64
        }
    }

    /// A report is valid only if every offered request was served —
    /// dropped or unaccounted requests fail instead of silently
    /// truncating the latency distribution.
    pub fn validate(&self) -> Result<(), String> {
        if self.dropped > 0 {
            return Err(format!(
                "serve run dropped {} of {} requests at the admission \
                 queue — raise --queue-cap or lower the arrival rate \
                 (a truncated run would under-report tail latency)",
                self.dropped, self.offered
            ));
        }
        if self.served != self.offered {
            return Err(format!(
                "serve run unaccounted: {} served + {} dropped != {} \
                 offered",
                self.served, self.dropped, self.offered
            ));
        }
        Ok(())
    }

    /// Order-sensitive FNV-style digest over every aggregate (counters,
    /// float bit patterns, quantile estimates). Two runs digest equal
    /// iff their accounting is bit-identical — the parity tests compare
    /// serial vs `--jobs N` runs through this.
    pub fn digest(&self) -> u64 {
        let words = [
            self.offered,
            self.served,
            self.dropped,
            self.batches,
            self.sum_queue.to_bits(),
            self.sum_gather.to_bits(),
            self.sum_compute.to_bits(),
            self.sum_total.to_bits(),
            self.max_total.to_bits(),
            self.makespan.to_bits(),
            self.p50.value().to_bits(),
            self.p95.value().to_bits(),
            self.p99.value().to_bits(),
            self.transport.total_bytes(),
            self.transport.cache_hits,
            self.transport.cache_misses,
            self.transport.remote_vertices,
            self.transport.time_gather.to_bits(),
            self.transport.time_compute.to_bits(),
        ];
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for w in words {
            h ^= w;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// One-line report in the style of [`EpochMetrics::summary`].
    pub fn summary(&self) -> String {
        format!(
            "served {}/{} in {} | p50 {} p95 {} p99 {} | mean {} max {} | {:.0} qps | {:.1} req/batch",
            self.served,
            self.offered,
            fmt_secs(self.makespan),
            fmt_secs(self.p50()),
            fmt_secs(self.p95()),
            fmt_secs(self.p99()),
            fmt_secs(self.mean_latency()),
            fmt_secs(self.max_total),
            self.qps(),
            self.mean_batch(),
        )
    }

    /// The latency decomposition as a rendered table: where an average
    /// request's time goes, plus the tail quantiles.
    pub fn latency_table(&self) -> Table {
        let n = self.served.max(1) as f64;
        let total = self.sum_total.max(1e-12);
        let mut t = Table::new(["component", "mean", "fraction"]);
        for (name, v) in [
            ("queue", self.sum_queue),
            ("gather", self.sum_gather),
            ("compute", self.sum_compute),
        ] {
            t.row([
                name.to_string(),
                fmt_secs(v / n),
                format!("{:.1}%", v / total * 100.0),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn observe_decomposes_and_validates() {
        let mut m = ServeMetrics::new();
        m.offered = 2;
        m.batches = 1;
        m.observe(1e-3, 2e-3, 3e-3);
        m.observe(2e-3, 2e-3, 3e-3);
        m.makespan = 0.5;
        assert_eq!(m.served, 2);
        assert!((m.mean_latency() - 6.5e-3).abs() < 1e-12);
        assert!((m.qps() - 4.0).abs() < 1e-12);
        assert_eq!(m.mean_batch(), 2.0);
        m.validate().expect("fully served run validates");
        let s = m.summary();
        assert!(s.contains("qps"), "{s}");
    }

    #[test]
    fn validate_rejects_dropped_and_unserved() {
        let mut m = ServeMetrics::new();
        m.offered = 10;
        m.observe(0.0, 1e-3, 1e-3);
        m.dropped = 9;
        let e = m.validate().unwrap_err();
        assert!(e.contains("dropped 9 of 10"), "{e}");
        assert!(e.contains("queue-cap"), "{e}");
        m.dropped = 0;
        let e = m.validate().unwrap_err();
        assert!(e.contains("unaccounted"), "{e}");
    }

    #[test]
    fn digest_separates_distinct_runs() {
        let mut a = ServeMetrics::new();
        let mut b = ServeMetrics::new();
        for m in [&mut a, &mut b] {
            m.offered = 1;
            m.observe(1e-3, 2e-3, 3e-3);
            m.makespan = 0.1;
        }
        assert_eq!(a.digest(), b.digest());
        b.observe(1e-3, 2e-3, 3.0001e-3);
        assert_ne!(a.digest(), b.digest());
    }

    #[test]
    fn latency_table_fractions_sum() {
        let mut m = ServeMetrics::new();
        m.observe(1.0, 2.0, 1.0);
        let s = m.latency_table().render();
        assert!(s.contains("50.0%"), "{s}");
        assert!(s.contains("queue"), "{s}");
    }
}
