//! The online serving engine: streams sampled ego-graph requests
//! through the training substrate and accounts per-request latency.
//!
//! Serving **reuses** the offline layers rather than forking them:
//! each request's ego-graph is drawn by the scratch-based sampler,
//! planned through [`FeatureStore`]/[`GatherPlan`], resolved against
//! the lane's warm [`TierStack`] (same pricing as the epoch driver's
//! `CacheFetch` op — hbm free, dram staged, ssd staged + flash read,
//! residual fetches priced per link by the [`crate::cluster::Fabric`]),
//! and computed forward-only on the destination server's compute-speed
//! multiplier.
//!
//! ## Queueing model
//!
//! One [`ServeLane`] per server owns a bounded admission queue
//! ([`ServeOpts::queue_cap`]; overflow is *dropped and reported* — a
//! serve report fails validation on drops). A micro-batch opens at the
//! first queued request, stays open for [`ServeOpts::window`] seconds
//! of stragglers (coalesced into **one** gather — the dedup the
//! training path gets from [`PregatherPlan`]), then serves up to
//! [`ServeOpts::max_batch`] requests. Per request:
//! `latency = queue (service start - arrival) + gather + compute`.
//!
//! ## Determinism
//!
//! Requests are routed to their root's home server up front, every
//! lane owns a seeded RNG derived from `(seed, server)`, and lanes
//! never communicate — so `--jobs N` execution is bit-identical to
//! serial by construction, locked by `tests/serve_parity.rs`. Tier
//! stacks persist across the whole run (the `--cache-persist`
//! semantics): early requests warm the tiers the tail is served from.
//! After warm-up a lane's request loop is allocation-free
//! (`tests/alloc_budget.rs`).

use super::metrics::ServeMetrics;
use super::workload::WorkloadSpec;
use crate::cluster::NetStats;
use crate::coordinator::SimEnv;
use crate::featstore::pregather::{PlanScratch, PregatherPlan};
use crate::featstore::tier::{TierKind, TierStack, NUM_TIER_KINDS};
use crate::featstore::{FeatureStore, GatherPlan};
use crate::metrics::EpochMetrics;
use crate::sampler::{sample_batch_into, SampleConfig, SampleScratch};
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::stamp::StampedSet;

/// Serving knobs orthogonal to the workload and cluster config.
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Micro-batching window (seconds): a batch opens at the first
    /// queued request and admits stragglers for this long. `0.0`
    /// serves immediately (no coalescing delay).
    pub window: f64,
    /// Bounded admission queue per server lane; arrivals past this
    /// are dropped (and fail the report's `validate()`).
    pub queue_cap: usize,
    /// Most requests coalesced into one micro-batch gather.
    pub max_batch: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        Self {
            window: 2e-3,
            queue_cap: 1024,
            max_batch: 32,
        }
    }
}

/// One inference request: an arrival time and the ego-graph root.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    pub time: f64,
    pub root: u32,
}

/// The full request stream, generated once up front (serially) so the
/// arrival process is independent of how many workers replay it.
pub struct ServeSchedule {
    /// All requests in arrival order.
    pub requests: Vec<Request>,
    /// Per home-server request indices (ascending in time) — the unit
    /// of lane-parallel execution.
    pub per_server: Vec<Vec<u32>>,
}

impl ServeSchedule {
    /// Draw the stream: arrival times from the workload spec, roots
    /// uniformly from the train set (the vertices a deployed model
    /// would be queried on), routed to each root's home server.
    pub fn generate(env: &SimEnv, wl: &WorkloadSpec) -> Self {
        let times = wl.arrival_times();
        let roots_pool = &env.dataset.train_vertices;
        assert!(
            !roots_pool.is_empty(),
            "dataset '{}' has no train vertices to serve",
            env.dataset.name
        );
        let mut rng =
            Rng::new(wl.seed ^ env.cfg.seed.rotate_left(17) ^ 0x5EED_0001);
        let mut requests = Vec::with_capacity(times.len());
        let mut per_server = vec![Vec::new(); env.num_servers()];
        for t in times {
            let root = roots_pool[rng.below(roots_pool.len())];
            per_server[env.partition.home(root) as usize]
                .push(requests.len() as u32);
            requests.push(Request { time: t, root });
        }
        Self {
            requests,
            per_server,
        }
    }
}

/// One served request's accounting (all times in simulated seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct Completion {
    pub arrival: f64,
    /// Wait from arrival to service start (admission + batch window).
    pub queue: f64,
    /// Sampling + feature collection (tier walk, transfers, staging).
    pub gather: f64,
    /// Forward pass on the home server's speed multiplier.
    pub compute: f64,
    /// Absolute completion time.
    pub done: f64,
}

/// A lane's reusable output buffers: completions in service order plus
/// the transport-layer accounting. Reset keeps every capacity, so a
/// warmed (lane, out) pair replays allocation-free.
pub struct LaneOut {
    pub completions: Vec<Completion>,
    pub dropped: u64,
    pub batches: u64,
    pub stats: NetStats,
    pub metrics: EpochMetrics,
}

impl LaneOut {
    pub fn new(num_servers: usize, capacity: usize) -> Self {
        Self {
            completions: Vec::with_capacity(capacity),
            dropped: 0,
            batches: 0,
            stats: NetStats::new(num_servers),
            metrics: EpochMetrics::default(),
        }
    }

    pub fn reset(&mut self) {
        self.completions.clear();
        self.dropped = 0;
        self.batches = 0;
        self.stats.reset();
        self.metrics.reset();
    }
}

/// Per-server serving state: the warm tier stack, sampler scratch, and
/// plan buffers one lane reuses across every request it serves.
pub struct ServeLane<'a> {
    env: &'a SimEnv<'a>,
    store: FeatureStore<'a>,
    stack: TierStack,
    /// Tier walk configured? (`remote`-only stacks skip it and price
    /// through the merged-gather path instead.)
    cached: bool,
    server: usize,
    opts: ServeOpts,
    scratch: SampleScratch,
    /// Single-step batch buffer feeding the tier walk / pre-gather.
    steps: Vec<Vec<u32>>,
    seen: StampedSet,
    plan: GatherPlan,
    ps: PlanScratch,
    pre: PregatherPlan,
    /// Admission queue: request indices waiting for service.
    pending: Vec<u32>,
    batch_roots: Vec<u32>,
}

impl<'a> ServeLane<'a> {
    pub fn new(env: &'a SimEnv<'a>, server: usize, opts: &ServeOpts) -> Self {
        let stack = env.build_tiers().swap_remove(server);
        Self {
            env,
            store: env.store(),
            cached: !stack.levels().is_empty(),
            stack,
            server,
            opts: *opts,
            scratch: SampleScratch::new(),
            steps: vec![Vec::new()],
            seen: StampedSet::default(),
            plan: GatherPlan::default(),
            ps: PlanScratch::default(),
            pre: PregatherPlan::default(),
            pending: Vec::with_capacity(opts.queue_cap),
            batch_roots: Vec::with_capacity(opts.max_batch),
        }
    }

    /// Serve this lane's share of the schedule into `out`. Replaying
    /// the same schedule on a warmed lane is bit-identical (the lane
    /// RNG is re-derived per run) and allocation-free.
    pub fn run(&mut self, schedule: &ServeSchedule, out: &mut LaneOut) {
        out.reset();
        self.pending.clear();
        let mine = &schedule.per_server[self.server];
        let reqs = &schedule.requests;
        let scfg = self.env.cfg.sample_config();
        let speed = self.env.fabric.compute_speed(self.server);
        let mut rng = Rng::new(
            self.env.cfg.seed
                ^ (self.server as u64 + 1)
                    .wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let mut next = 0usize;
        let mut clock = 0.0f64;
        while next < mine.len() || !self.pending.is_empty() {
            // admit everything that has arrived by now; overflow drops
            while next < mine.len()
                && reqs[mine[next] as usize].time <= clock
            {
                if self.pending.len() < self.opts.queue_cap {
                    self.pending.push(mine[next]);
                } else {
                    out.dropped += 1;
                }
                next += 1;
            }
            if self.pending.is_empty() {
                clock = reqs[mine[next] as usize].time;
                continue;
            }
            // batch opens now; stragglers inside the window coalesce
            let open = clock;
            let close = open + self.opts.window;
            while next < mine.len()
                && reqs[mine[next] as usize].time <= close
                && self.pending.len() < self.opts.queue_cap
            {
                self.pending.push(mine[next]);
                next += 1;
            }
            let start = if self.opts.window > 0.0 { close } else { open };
            let take = self.pending.len().min(self.opts.max_batch);
            self.batch_roots.clear();
            for &ri in &self.pending[..take] {
                self.batch_roots.push(reqs[ri as usize].root);
            }
            let (gather, compute) = self.price_batch(&scfg, speed, &mut rng, out);
            let done = start + gather + compute;
            for &ri in &self.pending[..take] {
                let r = &reqs[ri as usize];
                out.completions.push(Completion {
                    arrival: r.time,
                    queue: start - r.time,
                    gather,
                    compute,
                    done,
                });
            }
            out.batches += 1;
            self.pending.drain(..take);
            clock = done;
        }
    }

    /// Price one coalesced micro-batch: sample the batch's ego graphs,
    /// collect features through the warm tier stack (identical
    /// accounting to the epoch driver's `CacheFetch`) or the merged
    /// pre-gather path, and run the forward pass.
    fn price_batch(
        &mut self,
        scfg: &SampleConfig,
        speed: f64,
        rng: &mut Rng,
        out: &mut LaneOut,
    ) -> (f64, f64) {
        let cost = &self.env.cfg.cost;
        let step = &mut self.steps[0];
        step.clear();
        let sstats = sample_batch_into(
            &self.env.dataset.graph,
            &self.batch_roots,
            scfg,
            rng,
            &mut self.scratch,
            step,
        );
        let sample = cost.sample_time(sstats.vertices);
        let fetch = if self.cached {
            let deltas = self.stack.resolve_into(
                &self.store,
                self.server,
                &self.steps,
                &mut self.seen,
                &mut self.plan,
            );
            let fb = self.store.feat_bytes;
            let hits = deltas.cache_hits();
            let remote = self.plan.remote_count();
            let mut dt = self.store.sim_cost_cached(
                &self.plan,
                deltas.staged_hit_rows,
                &self.env.fabric,
                cost,
                &mut out.stats,
                &mut out.metrics,
            );
            let ssd = deltas.ssd_seconds(fb);
            if ssd > 0.0 {
                dt += ssd;
            }
            let m = &mut out.metrics;
            m.cache_hits += hits;
            m.cache_misses += remote;
            m.cache_hit_bytes += hits * fb;
            m.cache_miss_bytes += remote * fb;
            m.cache_evict_bytes += deltas.evicted_bytes;
            for k in 0..NUM_TIER_KINDS {
                m.tier_hits[k] += deltas.hits_at[k];
                m.tier_hit_bytes[k] += deltas.hits_at[k] * fb;
                m.tier_miss_bytes[k] += deltas.misses_at[k] * fb;
                m.tier_promote_bytes[k] += deltas.promote_bytes_at[k];
                m.tier_demote_bytes[k] += deltas.demote_bytes_at[k];
            }
            // residual fetches are remote-tier hits in the per-tier view
            let ri = TierKind::Remote.index();
            m.tier_hits[ri] += remote;
            m.tier_hit_bytes[ri] += remote * fb;
            dt
        } else {
            PregatherPlan::build_into(
                &self.store,
                self.server,
                &self.steps,
                &mut self.ps,
                &mut self.pre,
            );
            self.store.sim_cost(
                &self.pre.merged,
                &self.env.fabric,
                cost,
                &mut out.stats,
                &mut out.metrics,
            )
        };
        out.metrics.time_sample += sample;
        out.metrics.time_gather += fetch;
        // forward-only inference: train_flops is fwd + ~2x bwd, so the
        // forward pass is a third of the training FLOPs (the launch
        // overhead is per-dispatch, not per-FLOP, and stays whole)
        let launch = cost.launch_overhead(&self.env.shape);
        let train = cost.train_time(&self.env.shape, sstats.vertices, sstats.edges);
        let compute = ((train - launch) / 3.0 + launch) / speed;
        out.metrics.time_compute += compute;
        (sample + fetch, compute)
    }
}

/// A finished serving run: the workload served and its aggregates.
pub struct ServeReport {
    pub workload: WorkloadSpec,
    pub metrics: ServeMetrics,
}

/// Serve one workload end to end: generate the schedule, run every
/// lane (parallel up to the thread budget — bit-identical to serial),
/// and merge in deterministic server order.
pub fn serve(env: &SimEnv, wl: &WorkloadSpec, opts: &ServeOpts) -> ServeReport {
    let schedule = ServeSchedule::generate(env, wl);
    serve_schedule(env, wl, &schedule, opts)
}

/// [`serve`] over a pre-generated schedule (the bench harness reuses
/// one schedule across measured iterations).
pub fn serve_schedule(
    env: &SimEnv,
    wl: &WorkloadSpec,
    schedule: &ServeSchedule,
    opts: &ServeOpts,
) -> ServeReport {
    let n = env.num_servers();
    let workers = pool::lane_allowance().min(n);
    let outs = pool::run_indexed(n, workers, |s| {
        let mut lane = ServeLane::new(env, s, opts);
        let mut out = LaneOut::new(n, schedule.per_server[s].len());
        lane.run(schedule, &mut out);
        out
    });
    let mut sm = ServeMetrics::new();
    sm.offered = schedule.requests.len() as u64;
    let mut stats = NetStats::new(n);
    for out in &outs {
        for c in &out.completions {
            sm.observe(c.queue, c.gather, c.compute);
            sm.makespan = sm.makespan.max(c.done);
        }
        sm.dropped += out.dropped;
        sm.batches += out.batches;
        sm.transport.accumulate(&out.metrics);
        stats.merge(&out.stats);
    }
    sm.transport.absorb_net(&stats);
    sm.transport.epoch_time = sm.makespan;
    ServeReport {
        workload: *wl,
        metrics: sm,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::featstore::tier::TierSpec;
    use crate::graph::datasets::tiny_test_dataset;

    fn tiny_cfg(tiers: &str) -> RunConfig {
        RunConfig {
            num_servers: 2,
            layers: 2,
            fanout: 4,
            vmax: 32,
            tiers: Some(TierSpec::parse(tiers).expect("tier spec parses")),
            ..Default::default()
        }
    }

    fn wl(s: &str) -> WorkloadSpec {
        WorkloadSpec::parse(s).expect("workload spec parses")
    }

    #[test]
    fn serves_every_request_and_validates() {
        let d = tiny_test_dataset(31);
        let env = SimEnv::new(&d, tiny_cfg("dram:1m:lru+remote"));
        let r = serve(&env, &wl("poisson:rate=500,dur=0.2,seed=3"), &ServeOpts::default());
        let m = &r.metrics;
        assert!(m.offered > 0);
        m.validate().expect("unloaded run serves everything");
        assert_eq!(m.served, m.offered);
        assert!(m.makespan > 0.0);
        assert!(m.qps() > 0.0);
        assert!(m.p50() > 0.0 && m.p50() <= m.p99());
        assert!(m.sum_gather > 0.0 && m.sum_compute > 0.0);
        assert!(m.transport.total_bytes() > 0, "requests moved features");
    }

    #[test]
    fn warm_tiers_serve_the_tail_from_cache() {
        let d = tiny_test_dataset(32);
        let env = SimEnv::new(&d, tiny_cfg("dram:4m:lru+remote"));
        let r = serve(&env, &wl("poisson:rate=2000,dur=0.3,seed=5"), &ServeOpts::default());
        let t = &r.metrics.transport;
        assert!(
            t.cache_hits > 0,
            "persistent stacks must warm across the run"
        );
        // per-tier contribution: dram slot carries the hits
        assert_eq!(t.tier_hits[1], t.cache_hits);
    }

    #[test]
    fn batch_window_coalesces_requests() {
        let d = tiny_test_dataset(33);
        let env = SimEnv::new(&d, tiny_cfg("remote"));
        let spec = wl("poisson:rate=4000,dur=0.1,seed=7");
        let eager = serve(
            &env,
            &spec,
            &ServeOpts {
                window: 0.0,
                ..Default::default()
            },
        );
        let windowed = serve(
            &env,
            &spec,
            &ServeOpts {
                window: 5e-3,
                ..Default::default()
            },
        );
        assert!(
            windowed.metrics.batches < eager.metrics.batches,
            "a 5ms window must coalesce more than no window ({} !< {})",
            windowed.metrics.batches,
            eager.metrics.batches
        );
        assert!(windowed.metrics.mean_batch() > eager.metrics.mean_batch());
    }

    #[test]
    fn bounded_queue_drops_and_fails_validation() {
        let d = tiny_test_dataset(34);
        let env = SimEnv::new(&d, tiny_cfg("remote"));
        let r = serve(
            &env,
            &wl("bursty:rate=20000,mult=10,dwell=0.02,dur=0.2,seed=9"),
            &ServeOpts {
                window: 0.0,
                queue_cap: 1,
                max_batch: 1,
            },
        );
        let m = &r.metrics;
        assert!(m.dropped > 0, "an overloaded 1-deep queue must drop");
        assert_eq!(m.served + m.dropped, m.offered);
        let e = m.validate().unwrap_err();
        assert!(e.contains("dropped"), "{e}");
    }

    #[test]
    fn replays_are_bit_identical() {
        let d = tiny_test_dataset(35);
        let env = SimEnv::new(&d, tiny_cfg("dram:1m:lru+remote"));
        let spec = wl("diurnal:rate=800,period=0.1,depth=0.7,dur=0.2,seed=11");
        let a = serve(&env, &spec, &ServeOpts::default());
        let b = serve(&env, &spec, &ServeOpts::default());
        assert_eq!(a.metrics.digest(), b.metrics.digest());
    }
}
