//! Online inference serving: streaming request workloads over the
//! training substrate, with tail-latency and QPS accounting.
//!
//! The training side of this repo asks "how fast is an epoch?"; this
//! subsystem asks the deployed-system question — "what latency does a
//! request see at a given arrival rate?" — using the *same* sampler,
//! feature store, tier stacks, fabric pricing, and thread pool (reuse,
//! not a fork; the ROADMAP's serving item).
//!
//! * [`workload`] — seeded deterministic arrival processes (Poisson,
//!   bursty MMPP, diurnal sinusoid) behind the `--workload` spec
//!   grammar;
//! * [`engine`] — per-server serve lanes with bounded admission
//!   queues, micro-batch coalescing, and warm tier stacks persisting
//!   across the run;
//! * [`metrics`] — per-request queue/gather/compute decomposition,
//!   streaming p50/p95/p99 (P² estimator), sustained QPS, per-tier
//!   hit contribution, and fail-on-drop validation.
//!
//! Surfaced as `sim serve`, the `serve` bench experiment, and the
//! `bench sweep --workload` axis.

pub mod engine;
pub mod metrics;
pub mod workload;

pub use engine::{
    serve, serve_schedule, Completion, LaneOut, Request, ServeLane, ServeOpts,
    ServeReport, ServeSchedule,
};
pub use metrics::ServeMetrics;
pub use workload::{ArrivalKind, WorkloadSpec, WORKLOAD_FORMS};
