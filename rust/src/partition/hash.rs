//! Random hash partitioning — P³'s scheme (§2 of the P³ paper). Perfectly
//! balanced in expectation, zero locality by construction.

use super::Partition;
use crate::graph::CsrGraph;

#[inline]
fn mix(v: u64) -> u64 {
    // fmix64 from MurmurHash3
    let mut h = v;
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^= h >> 33;
    h = h.wrapping_mul(0xc4ceb9fe1a85ec53);
    h ^= h >> 33;
    h
}

pub fn partition(graph: &CsrGraph, num_parts: usize, seed: u64) -> Partition {
    let part = (0..graph.num_vertices() as u64)
        .map(|v| (mix(v ^ seed) % num_parts as u64) as u32)
        .collect();
    Partition { part, num_parts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::rmat_graph;

    #[test]
    fn balanced_in_expectation() {
        let g = rmat_graph(12, 20_000, 1);
        let p = partition(&g, 8, 99);
        let sizes = p.sizes();
        let mean = g.num_vertices() as f64 / 8.0;
        for s in sizes {
            assert!((s as f64 - mean).abs() / mean < 0.15, "size {s} vs {mean}");
        }
    }

    #[test]
    fn seed_changes_assignment() {
        let g = rmat_graph(8, 1000, 1);
        let a = partition(&g, 4, 1);
        let b = partition(&g, 4, 2);
        assert_ne!(a.part, b.part);
    }

    #[test]
    fn deterministic() {
        let g = rmat_graph(8, 1000, 1);
        assert_eq!(partition(&g, 4, 7).part, partition(&g, 4, 7).part);
    }
}
