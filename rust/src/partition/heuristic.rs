//! BFS block-growing partitioner — stands in for the streaming heuristics
//! (BGL, ByteGNN) the paper uses when METIS runs out of memory on the
//! large graphs. Grows `num_parts` regions breadth-first from spread-out
//! seeds with a hard size cap, then assigns stragglers to the smallest
//! adjacent part.

use super::Partition;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;
use std::collections::VecDeque;

pub fn partition(graph: &CsrGraph, num_parts: usize, seed: u64) -> Partition {
    let n = graph.num_vertices();
    let cap = n.div_ceil(num_parts);
    let mut part = vec![u32::MAX; n];
    let mut sizes = vec![0usize; num_parts];
    let mut rng = Rng::new(seed);

    // Spread seeds: random start, then each next seed is the unassigned
    // vertex farthest (in hops) from all previous seeds — approximated by
    // one BFS sweep per seed (k-center style).
    let mut dist = vec![u32::MAX; n];
    let mut queues: Vec<VecDeque<u32>> = (0..num_parts).map(|_| VecDeque::new()).collect();
    let first = rng.below(n) as u32;
    seed_region(graph, first, 0, &mut part, &mut sizes, &mut queues, &mut dist);
    for p in 1..num_parts {
        // farthest unassigned vertex by current BFS distances
        let far = (0..n as u32)
            .filter(|&v| part[v as usize] == u32::MAX)
            .max_by_key(|&v| dist[v as usize].min(n as u32))
            .unwrap_or_else(|| rng.below(n) as u32);
        seed_region(graph, far, p as u32, &mut part, &mut sizes, &mut queues, &mut dist);
    }

    // Round-robin BFS growth with size caps.
    let mut active = true;
    while active {
        active = false;
        for p in 0..num_parts {
            if sizes[p] >= cap {
                queues[p].clear();
                continue;
            }
            // take one frontier vertex per round to keep regions balanced
            while let Some(v) = queues[p].pop_front() {
                let mut grew = false;
                for &u in graph.neighbors(v) {
                    if part[u as usize] == u32::MAX && sizes[p] < cap {
                        part[u as usize] = p as u32;
                        sizes[p] += 1;
                        dist[u as usize] = dist[v as usize].saturating_add(1);
                        queues[p].push_back(u);
                        grew = true;
                    }
                }
                if grew {
                    active = true;
                    break;
                }
            }
        }
    }

    // Stragglers (isolated / capped-out regions): smallest part.
    for v in 0..n {
        if part[v] == u32::MAX {
            let p = (0..num_parts).min_by_key(|&p| sizes[p]).unwrap();
            part[v] = p as u32;
            sizes[p] += 1;
        }
    }

    Partition { part, num_parts }
}

fn seed_region(
    graph: &CsrGraph,
    v: u32,
    p: u32,
    part: &mut [u32],
    sizes: &mut [usize],
    queues: &mut [VecDeque<u32>],
    dist: &mut [u32],
) {
    if part[v as usize] != u32::MAX {
        return;
    }
    part[v as usize] = p;
    sizes[p as usize] += 1;
    dist[v as usize] = 0;
    queues[p as usize].push_back(v);
    // quick bounded BFS to refresh distances for farthest-seed selection
    let mut q = VecDeque::from([v]);
    while let Some(u) = q.pop_front() {
        let local_dist = dist[u as usize] + 1;
        if local_dist > 6 {
            break; // bounded sweep is enough for seed spreading
        }
        for &w in graph.neighbors(u) {
            if dist[w as usize] > local_dist {
                dist[w as usize] = local_dist;
                q.push_back(w);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};

    #[test]
    fn respects_cap_and_covers() {
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 1000,
            num_edges: 6000,
            num_communities: 10,
            seed: 2,
            ..Default::default()
        })
        .graph;
        let p = partition(&g, 4, 3);
        p.validate().unwrap();
        let cap = 250 + 1;
        for s in p.sizes() {
            assert!(s <= cap + 250 / 4, "size {s}"); // stragglers may spill a bit
        }
    }

    #[test]
    fn contiguous_regions_cut_less_than_random() {
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 2000,
            num_edges: 14_000,
            num_communities: 16,
            seed: 4,
            ..Default::default()
        })
        .graph;
        let heur = partition(&g, 4, 5).edge_cut_fraction(&g);
        let hash = super::super::hash::partition(&g, 4, 5).edge_cut_fraction(&g);
        assert!(heur < hash, "heur {heur} hash {hash}");
    }
}
