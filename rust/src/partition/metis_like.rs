//! Multilevel k-way edge-cut partitioner (METIS-style).
//!
//! Three phases, exactly the METIS recipe (Karypis & Kumar '98):
//!   1. **Coarsen** — repeated heavy-edge matching collapses matched pairs
//!      into super-vertices (edge weights accumulate) until the graph is
//!      small (<= `COARSE_TARGET` vertices).
//!   2. **Initial partition** — greedy BFS region growing on the coarsest
//!      graph, weighted by vertex (cluster) sizes.
//!   3. **Uncoarsen + refine** — project the partition back level by
//!      level, running boundary Kernighan–Lin-style greedy moves under a
//!      balance constraint at each level.
//!
//! Not a bit-for-bit METIS clone, but the same objective (min edge cut,
//! balanced parts) and the same structure — which is all HopGNN's
//! micrograph-locality argument needs (DESIGN.md §2).

use super::Partition;
use crate::graph::CsrGraph;
use crate::util::rng::Rng;
use std::collections::HashMap;

const MAX_LEVELS: usize = 24;
const BALANCE_TOL: f64 = 1.08;
const INIT_RESTARTS: usize = 4;

/// Weighted graph used internally across coarsening levels.
struct WGraph {
    /// adjacency: per vertex, (neighbor, edge weight)
    adj: Vec<Vec<(u32, u64)>>,
    /// vertex weight = number of original vertices collapsed into it
    vwgt: Vec<u64>,
}

impl WGraph {
    fn from_csr(g: &CsrGraph) -> Self {
        let n = g.num_vertices();
        let mut adj = Vec::with_capacity(n);
        for v in 0..n as u32 {
            adj.push(g.neighbors(v).iter().map(|&u| (u, 1u64)).collect());
        }
        Self {
            adj,
            vwgt: vec![1; n],
        }
    }

    fn len(&self) -> usize {
        self.adj.len()
    }
}

pub fn partition(graph: &CsrGraph, num_parts: usize, seed: u64) -> Partition {
    let n = graph.num_vertices();
    if num_parts <= 1 || n <= num_parts {
        return Partition {
            part: vec![0; n],
            num_parts: num_parts.max(1),
        };
    }
    let mut rng = Rng::new(seed);

    // ---- coarsening ----
    // Coarsen until the graph is small relative to the part count (so the
    // initial split sees super-vertices ≈ communities, the property the
    // multilevel scheme depends on).
    let coarse_target = (num_parts * 32).max(128);
    let mut levels: Vec<WGraph> = vec![WGraph::from_csr(graph)];
    let mut maps: Vec<Vec<u32>> = Vec::new(); // fine vertex -> coarse vertex
    while levels.last().unwrap().len() > coarse_target && maps.len() < MAX_LEVELS {
        let cur = levels.last().unwrap();
        let (coarse, map) = coarsen(cur, &mut rng);
        let stalled = coarse.len() as f64 > cur.len() as f64 * 0.95;
        levels.push(coarse);
        maps.push(map);
        if stalled {
            break; // matching stalled (e.g. star graphs)
        }
    }

    // ---- initial partition on coarsest (best of several restarts) ----
    let coarsest = levels.last().unwrap();
    let mut part = initial_partition(coarsest, num_parts, &mut rng);
    refine(coarsest, &mut part, num_parts, 8);
    let mut best_cut = cut_weight(coarsest, &part);
    for _ in 1..INIT_RESTARTS {
        let mut cand = initial_partition(coarsest, num_parts, &mut rng);
        refine(coarsest, &mut cand, num_parts, 8);
        let c = cut_weight(coarsest, &cand);
        if c < best_cut {
            best_cut = c;
            part = cand;
        }
    }

    // ---- uncoarsen + refine ----
    for level in (0..maps.len()).rev() {
        let fine = &levels[level];
        let map = &maps[level];
        part = map.iter().map(|&c| part[c as usize]).collect();
        refine(fine, &mut part, num_parts, 3);
    }

    Partition {
        part,
        num_parts,
    }
}

/// Total weight of cut edges (internal objective for restart selection).
fn cut_weight(g: &WGraph, part: &[u32]) -> u64 {
    let mut cut = 0u64;
    for (v, adj) in g.adj.iter().enumerate() {
        for &(u, w) in adj {
            if part[v] != part[u as usize] {
                cut += w;
            }
        }
    }
    cut / 2
}

/// Heavy-edge matching: visit vertices in random order, match each
/// unmatched vertex with its unmatched neighbor of maximum edge weight.
fn coarsen(g: &WGraph, rng: &mut Rng) -> (WGraph, Vec<u32>) {
    let n = g.len();
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut matched = vec![u32::MAX; n];
    let mut coarse_id = vec![u32::MAX; n];
    let mut next_id = 0u32;
    for &v in &order {
        if matched[v as usize] != u32::MAX {
            continue;
        }
        let mut best: Option<(u32, u64)> = None;
        for &(u, w) in &g.adj[v as usize] {
            if matched[u as usize] == u32::MAX && u != v {
                if best.map(|(_, bw)| w > bw).unwrap_or(true) {
                    best = Some((u, w));
                }
            }
        }
        match best {
            Some((u, _)) => {
                matched[v as usize] = u;
                matched[u as usize] = v;
                coarse_id[v as usize] = next_id;
                coarse_id[u as usize] = next_id;
            }
            None => {
                matched[v as usize] = v;
                coarse_id[v as usize] = next_id;
            }
        }
        next_id += 1;
    }

    let cn = next_id as usize;
    let mut vwgt = vec![0u64; cn];
    for v in 0..n {
        vwgt[coarse_id[v] as usize] += g.vwgt[v];
    }
    let mut adj: Vec<Vec<(u32, u64)>> = vec![Vec::new(); cn];
    // accumulate coarse edges from fine edges
    let mut edge_acc: HashMap<(u32, u32), u64> = HashMap::new();
    for v in 0..n {
        let cv = coarse_id[v];
        for &(u, w) in &g.adj[v] {
            let cu = coarse_id[u as usize];
            if cu != cv {
                let key = if cv < cu { (cv, cu) } else { (cu, cv) };
                *edge_acc.entry(key).or_insert(0) += w;
            }
        }
    }
    // sort for determinism: HashMap iteration order varies per instance,
    // and downstream heavy-edge matching is order-sensitive
    let mut sorted: Vec<((u32, u32), u64)> = edge_acc.into_iter().collect();
    sorted.sort_unstable_by_key(|&(k, _)| k);
    for ((a, b), w) in sorted {
        // each fine edge visited twice (symmetric adjacency) -> halve
        adj[a as usize].push((b, w / 2));
        adj[b as usize].push((a, w / 2));
    }
    (WGraph { adj, vwgt }, coarse_id)
}

/// Greedy weighted BFS region growing for the initial k-way split.
fn initial_partition(g: &WGraph, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = g.len();
    let total_w: u64 = g.vwgt.iter().sum();
    let target = total_w as f64 / k as f64;
    let mut part = vec![u32::MAX; n];
    let mut weights = vec![0u64; k];
    let mut frontier: Vec<Vec<u32>> = vec![Vec::new(); k];
    for p in 0..k {
        // random unassigned seed
        for _ in 0..n {
            let v = rng.below(n) as u32;
            if part[v as usize] == u32::MAX {
                part[v as usize] = p as u32;
                weights[p] += g.vwgt[v as usize];
                frontier[p].push(v);
                break;
            }
        }
    }
    let cap_w = (target * BALANCE_TOL) as u64;
    let mut remaining: usize = part.iter().filter(|&&p| p == u32::MAX).count();
    while remaining > 0 {
        let mut progressed = false;
        for p in 0..k {
            if let Some(v) = frontier[p].pop() {
                for &(u, _) in &g.adj[v as usize] {
                    // strict per-addition cap: super-vertices must not
                    // overshoot the balance bound
                    if part[u as usize] == u32::MAX
                        && weights[p] + g.vwgt[u as usize] <= cap_w
                    {
                        part[u as usize] = p as u32;
                        weights[p] += g.vwgt[u as usize];
                        frontier[p].push(u);
                        remaining -= 1;
                        progressed = true;
                    }
                }
            }
        }
        if !progressed {
            // disconnected leftovers: lightest part
            for v in 0..n {
                if part[v] == u32::MAX {
                    let p = (0..k).min_by_key(|&p| weights[p]).unwrap();
                    part[v] = p as u32;
                    weights[p] += g.vwgt[v];
                    frontier[p].push(v as u32);
                    remaining -= 1;
                }
            }
        }
    }
    part
}

/// Greedy boundary refinement: move boundary vertices to the neighboring
/// part with maximum cut gain, subject to the balance constraint.
fn refine(g: &WGraph, part: &mut [u32], k: usize, passes: usize) {
    let n = g.len();
    let total_w: u64 = g.vwgt.iter().sum();
    let cap = (total_w as f64 / k as f64 * BALANCE_TOL) as u64;
    let mut weights = vec![0u64; k];
    for v in 0..n {
        weights[part[v] as usize] += g.vwgt[v];
    }
    let mut conn = vec![0u64; k]; // scratch: connectivity of v to each part
    for _ in 0..passes {
        let mut moved = 0usize;
        for v in 0..n {
            if g.adj[v].is_empty() {
                continue;
            }
            conn.iter_mut().for_each(|c| *c = 0);
            for &(u, w) in &g.adj[v] {
                conn[part[u as usize] as usize] += w;
            }
            let cur = part[v] as usize;
            let (mut best_p, mut best_gain) = (cur, 0i64);
            for p in 0..k {
                if p == cur {
                    continue;
                }
                let gain = conn[p] as i64 - conn[cur] as i64;
                if gain > best_gain && weights[p] + g.vwgt[v] <= cap {
                    best_gain = gain;
                    best_p = p;
                }
            }
            if best_p != cur {
                weights[cur] -= g.vwgt[v];
                weights[best_p] += g.vwgt[v];
                part[v] = best_p as u32;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};

    #[test]
    fn recovers_planted_communities() {
        // 8 well-separated communities, 4 parts: cut should be small
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 1600,
            num_edges: 12_000,
            num_communities: 8,
            p_intra: 0.95,
            seed: 10,
            ..Default::default()
        })
        .graph;
        let p = partition(&g, 4, 1);
        let cut = p.edge_cut_fraction(&g);
        assert!(cut < 0.15, "cut {cut}");
        assert!(p.balance() < 1.25, "balance {}", p.balance());
    }

    #[test]
    fn handles_tiny_graphs() {
        let g = CsrGraph::from_edges(3, &[(0, 1), (1, 2)]);
        let p = partition(&g, 2, 1);
        p.validate().unwrap();
        let p1 = partition(&g, 8, 1); // more parts than vertices
        p1.validate().unwrap();
    }

    #[test]
    fn handles_disconnected_graph() {
        let mut edges = Vec::new();
        for i in 0..50u32 {
            edges.push((i * 2, i * 2 + 1)); // 50 disjoint dumbbells
        }
        let g = CsrGraph::from_edges(100, &edges);
        let p = partition(&g, 4, 2);
        p.validate().unwrap();
        assert!(p.balance() < 1.5, "balance {}", p.balance());
    }

    #[test]
    fn coarsening_preserves_total_vertex_weight() {
        let g = community_graph(&CommunityGraphSpec {
            num_vertices: 3000,
            num_edges: 20_000,
            seed: 3,
            ..Default::default()
        })
        .graph;
        let wg = WGraph::from_csr(&g);
        let mut rng = Rng::new(1);
        let (coarse, map) = coarsen(&wg, &mut rng);
        assert!(coarse.len() < wg.len());
        assert_eq!(coarse.vwgt.iter().sum::<u64>(), 3000);
        assert!(map.iter().all(|&c| (c as usize) < coarse.len()));
    }
}
