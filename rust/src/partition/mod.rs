//! Graph partitioning: assigns every vertex (its features + adjacency) a
//! home server. The paper's locality argument (§4, Table 1) rests on
//! partitioners that co-locate neighbors; three algorithms are provided:
//!
//! * [`metis_like`] — multilevel edge-cut minimizer (stands in for METIS,
//!   used by DGL; same objective: min cut, balanced parts).
//! * [`heuristic`]  — BFS block growing (stands in for the BGL-style
//!   heuristic the paper uses on graphs too big for METIS).
//! * [`hash`]       — random hash partitioning (what P³ uses; the
//!   no-locality baseline).

pub mod hash;
pub mod heuristic;
pub mod metis_like;

use crate::graph::CsrGraph;

/// A k-way vertex partition.
#[derive(Clone, Debug)]
pub struct Partition {
    /// part[v] = home server of vertex v.
    pub part: Vec<u32>,
    pub num_parts: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PartitionAlgo {
    MetisLike,
    Heuristic,
    Hash,
}

impl PartitionAlgo {
    pub fn from_str(s: &str) -> Option<Self> {
        match s {
            "metis" | "metis-like" => Some(Self::MetisLike),
            "heuristic" | "bfs" => Some(Self::Heuristic),
            "hash" | "random" => Some(Self::Hash),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::MetisLike => "metis",
            Self::Heuristic => "heuristic",
            Self::Hash => "hash",
        }
    }
}

pub fn partition(
    graph: &CsrGraph,
    num_parts: usize,
    algo: PartitionAlgo,
    seed: u64,
) -> Partition {
    match algo {
        PartitionAlgo::MetisLike => metis_like::partition(graph, num_parts, seed),
        PartitionAlgo::Heuristic => heuristic::partition(graph, num_parts, seed),
        PartitionAlgo::Hash => hash::partition(graph, num_parts, seed),
    }
}

impl Partition {
    #[inline]
    pub fn home(&self, v: u32) -> u32 {
        self.part[v as usize]
    }

    /// Vertices per part.
    pub fn sizes(&self) -> Vec<usize> {
        let mut sizes = vec![0usize; self.num_parts];
        for &p in &self.part {
            sizes[p as usize] += 1;
        }
        sizes
    }

    /// Fraction of edges crossing parts (the METIS objective).
    pub fn edge_cut_fraction(&self, graph: &CsrGraph) -> f64 {
        let mut cut = 0usize;
        let mut total = 0usize;
        for (u, v) in graph.edges() {
            total += 1;
            if self.home(u) != self.home(v) {
                cut += 1;
            }
        }
        if total == 0 {
            0.0
        } else {
            cut as f64 / total as f64
        }
    }

    /// Max part size over mean part size (1.0 == perfectly balanced).
    pub fn balance(&self) -> f64 {
        let sizes = self.sizes();
        let mean = self.part.len() as f64 / self.num_parts as f64;
        sizes.iter().cloned().fold(0usize, usize::max) as f64 / mean
    }

    /// Sanity: every vertex assigned to a valid part.
    pub fn validate(&self) -> Result<(), String> {
        for (v, &p) in self.part.iter().enumerate() {
            if p as usize >= self.num_parts {
                return Err(format!("vertex {v} in invalid part {p}"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generator::{community_graph, CommunityGraphSpec};
    use crate::util::prop;

    fn test_graph(seed: u64) -> CsrGraph {
        community_graph(&CommunityGraphSpec {
            num_vertices: 1200,
            num_edges: 8000,
            num_communities: 12,
            seed,
            ..Default::default()
        })
        .graph
    }

    #[test]
    fn all_algos_produce_valid_balanced_partitions() {
        let g = test_graph(5);
        for algo in [
            PartitionAlgo::MetisLike,
            PartitionAlgo::Heuristic,
            PartitionAlgo::Hash,
        ] {
            for k in [2usize, 4, 8] {
                let p = partition(&g, k, algo, 7);
                p.validate().unwrap();
                assert_eq!(p.part.len(), g.num_vertices());
                assert!(
                    p.balance() < 1.35,
                    "{:?} k={k} imbalance {}",
                    algo,
                    p.balance()
                );
                // every part non-empty
                assert!(p.sizes().iter().all(|&s| s > 0), "{algo:?} k={k}");
            }
        }
    }

    #[test]
    fn locality_ranking_metis_beats_hash() {
        let g = test_graph(6);
        let cut_metis =
            partition(&g, 4, PartitionAlgo::MetisLike, 7).edge_cut_fraction(&g);
        let cut_heur =
            partition(&g, 4, PartitionAlgo::Heuristic, 7).edge_cut_fraction(&g);
        let cut_hash =
            partition(&g, 4, PartitionAlgo::Hash, 7).edge_cut_fraction(&g);
        assert!(
            cut_metis < cut_hash * 0.6,
            "metis {cut_metis} vs hash {cut_hash}"
        );
        assert!(
            cut_heur < cut_hash * 0.9,
            "heuristic {cut_heur} vs hash {cut_hash}"
        );
    }

    #[test]
    fn prop_partition_covers_all_vertices() {
        prop::check(
            "partition-covers",
            12,
            |r| (r.range(50, 400), r.next_u64()),
            |&(n, seed)| {
                let g = community_graph(&CommunityGraphSpec {
                    num_vertices: n,
                    num_edges: n * 6,
                    num_communities: 8,
                    seed,
                    ..Default::default()
                })
                .graph;
                for algo in [
                    PartitionAlgo::MetisLike,
                    PartitionAlgo::Heuristic,
                    PartitionAlgo::Hash,
                ] {
                    let p = partition(&g, 4, algo, seed);
                    p.validate().map_err(|e| format!("{algo:?}: {e}"))?;
                    if p.part.len() != n {
                        return Err(format!("{algo:?}: wrong length"));
                    }
                    if p.balance() > 1.6 {
                        return Err(format!(
                            "{algo:?}: imbalance {}",
                            p.balance()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
