//! PJRT runtime: load AOT artifacts (HLO text) and execute them from the
//! Rust training path. Python only runs at `make artifacts` time.

pub mod engine;
pub mod manifest;
pub mod optimizer;
pub mod tensor;

pub use engine::{Engine, StepOutput};
pub use manifest::{ArtifactSpec, Manifest};
pub use optimizer::{Adam, ParamSet, Sgd};
pub use tensor::BatchBuffers;
