//! Tensor assembly: pack micrograph batches into the dense buffers the
//! AOT artifacts consume. This is the L3 hot path for real training —
//! zero allocations per batch after warm-up (buffers are reused).

use crate::graph::datasets::Dataset;
use crate::runtime::manifest::ArtifactSpec;
use crate::sampler::Micrograph;

/// Reusable staging buffers for one artifact's input shapes.
pub struct BatchBuffers {
    pub batch: usize,
    pub layers: usize,
    pub vmax: usize,
    pub feat_dim: usize,
    /// [B, L, V, V] row-major
    pub adj: Vec<f32>,
    /// [B, V, F]
    pub x: Vec<f32>,
    /// [B]
    pub labels: Vec<i32>,
}

impl BatchBuffers {
    pub fn for_artifact(spec: &ArtifactSpec) -> Self {
        Self::new(spec.batch, spec.layers, spec.vmax, spec.feat_dim)
    }

    pub fn new(
        batch: usize,
        layers: usize,
        vmax: usize,
        feat_dim: usize,
    ) -> Self {
        Self {
            batch,
            layers,
            vmax,
            feat_dim,
            adj: vec![0.0; batch * layers * vmax * vmax],
            x: vec![0.0; batch * vmax * feat_dim],
            labels: vec![0; batch],
        }
    }

    /// Pack up to `batch` micrographs. Unused batch slots are zeroed
    /// (zero adjacency + zero features + label 0 → they contribute a
    /// constant loss term; the trainer scales gradients by the real
    /// count). Returns how many were packed.
    pub fn pack(&mut self, mgs: &[Micrograph], dataset: &Dataset) -> usize {
        let n = mgs.len().min(self.batch);
        self.adj.iter_mut().for_each(|v| *v = 0.0);
        self.x.iter_mut().for_each(|v| *v = 0.0);
        self.labels.iter_mut().for_each(|v| *v = 0);
        let adj_stride = self.layers * self.vmax * self.vmax;
        let x_stride = self.vmax * self.feat_dim;
        for (b, mg) in mgs.iter().take(n).enumerate() {
            mg.fill_dense_adj(
                self.vmax,
                &mut self.adj[b * adj_stride..(b + 1) * adj_stride],
            );
            for (i, &v) in mg.vertices.iter().take(self.vmax).enumerate() {
                let off = b * x_stride + i * self.feat_dim;
                dataset.write_features(
                    v,
                    &mut self.x[off..off + self.feat_dim],
                );
            }
            self.labels[b] = dataset.labels[mg.root as usize] as i32;
        }
        n
    }

    pub fn adj_dims(&self) -> [usize; 4] {
        [self.batch, self.layers, self.vmax, self.vmax]
    }

    pub fn x_dims(&self) -> [usize; 3] {
        [self.batch, self.vmax, self.feat_dim]
    }
}

/// Reinterpret a f32 slice as bytes (little-endian host layout — PJRT CPU
/// shares the host byte order).
pub fn f32_bytes(xs: &[f32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    }
}

pub fn i32_bytes(xs: &[i32]) -> &[u8] {
    unsafe {
        std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::datasets::tiny_test_dataset;
    use crate::sampler::{sample_micrograph, SampleConfig, SamplerKind};
    use crate::util::rng::Rng;

    fn sample_some(d: &Dataset, n: usize) -> Vec<Micrograph> {
        let cfg = SampleConfig {
            layers: 2,
            fanout: 3,
            vmax: 16,
            kind: SamplerKind::NodeWise,
        };
        let mut rng = Rng::new(1);
        (0..n)
            .map(|i| {
                sample_micrograph(
                    &d.graph,
                    (i * 17) as u32 % 400,
                    &cfg,
                    &mut rng,
                )
            })
            .collect()
    }

    #[test]
    fn pack_fills_roots_and_zeroes_padding() {
        let d = tiny_test_dataset(80);
        let mgs = sample_some(&d, 3);
        let mut buf = BatchBuffers::new(4, 2, 16, d.feat_dim);
        let n = buf.pack(&mgs, &d);
        assert_eq!(n, 3);
        // root features at vertex slot 0 of each batch entry are nonzero
        for b in 0..3 {
            let off = b * 16 * d.feat_dim;
            let row = &buf.x[off..off + d.feat_dim];
            assert!(row.iter().any(|&v| v != 0.0), "root features zero");
            assert_eq!(buf.labels[b], d.labels[mgs[b].root as usize] as i32);
        }
        // slot 3 (unused) fully zero
        let off = 3 * 16 * d.feat_dim;
        assert!(buf.x[off..off + 16 * d.feat_dim].iter().all(|&v| v == 0.0));
        assert!(buf.adj[3 * 2 * 256..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pack_is_reusable() {
        let d = tiny_test_dataset(81);
        let mgs1 = sample_some(&d, 4);
        let mgs2 = sample_some(&d, 2);
        let mut buf = BatchBuffers::new(4, 2, 16, d.feat_dim);
        buf.pack(&mgs1, &d);
        let adj_after_1 = buf.adj.clone();
        buf.pack(&mgs2, &d);
        buf.pack(&mgs1, &d);
        assert_eq!(buf.adj, adj_after_1, "repack must be deterministic");
    }

    #[test]
    fn adjacency_has_self_loops_on_diagonal() {
        let d = tiny_test_dataset(82);
        let mgs = sample_some(&d, 1);
        let mut buf = BatchBuffers::new(1, 2, 16, d.feat_dim);
        buf.pack(&mgs, &d);
        // root self-loop present at layer 0 and 1, position (0,0)
        assert_eq!(buf.adj[0], 1.0);
        assert_eq!(buf.adj[16 * 16], 1.0);
    }

    #[test]
    fn byte_views_alias_data() {
        let xs = [1.0f32, -2.0];
        let b = f32_bytes(&xs);
        assert_eq!(b.len(), 8);
        assert_eq!(f32::from_le_bytes(b[0..4].try_into().unwrap()), 1.0);
    }
}
