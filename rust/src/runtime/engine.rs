//! PJRT execution engine: loads `artifacts/*.hlo.txt`, compiles them on
//! the CPU PJRT client, and runs train/predict steps from the L3 hot
//! path. Python is never invoked — the HLO text is the only interface.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.
//!
//! The real engine needs the vendored `xla` crate and is gated behind
//! the `pjrt` cargo feature; the default build ships an API-compatible
//! stub so the simulator, benches, and tests stay self-contained (the
//! tier-1 gate runs with zero external dependencies).

use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::optimizer::ParamSet;
use crate::runtime::tensor::BatchBuffers;
use crate::util::error::Result;

/// Output of one train step.
pub struct StepOutput {
    pub loss: f32,
    pub correct: i32,
    pub grads: Vec<Vec<f32>>,
}

#[cfg(not(feature = "pjrt"))]
pub use stub::Engine;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

/// Default build: no PJRT. `Engine::load` fails with a clear message;
/// everything that only *plans* training (samplers, batch packing, the
/// whole simulator) keeps working.
#[cfg(not(feature = "pjrt"))]
mod stub {
    use super::*;

    pub struct Engine {
        pub spec: ArtifactSpec,
        /// Wall time of the most recent train_step (for calibration).
        pub last_step_secs: f64,
    }

    const UNAVAILABLE: &str = "PJRT runtime unavailable: this binary was \
         built without the `pjrt` feature (the vendored `xla` crate is \
         not part of the dependency-free build)";

    impl Engine {
        pub fn load(_spec: &ArtifactSpec) -> Result<Self> {
            Err(crate::err!("{UNAVAILABLE}"))
        }

        pub fn train_step(
            &mut self,
            _params: &ParamSet,
            _batch: &BatchBuffers,
        ) -> Result<StepOutput> {
            Err(crate::err!("{UNAVAILABLE}"))
        }

        pub fn train_step_b(
            &mut self,
            _params: &ParamSet,
            _batch: &BatchBuffers,
        ) -> Result<StepOutput> {
            Err(crate::err!("{UNAVAILABLE}"))
        }

        pub fn predict(
            &self,
            _params: &ParamSet,
            _batch: &BatchBuffers,
        ) -> Result<Vec<f32>> {
            Err(crate::err!("{UNAVAILABLE}"))
        }

        pub fn predict_b(
            &self,
            _params: &ParamSet,
            _batch: &BatchBuffers,
        ) -> Result<Vec<f32>> {
            Err(crate::err!("{UNAVAILABLE}"))
        }

        pub fn platform(&self) -> String {
            "none (pjrt feature disabled)".to_string()
        }
    }
}

/// One compiled artifact (train + predict executables).
#[cfg(feature = "pjrt")]
mod pjrt {
    use super::*;
    use crate::runtime::tensor::{f32_bytes, i32_bytes};
    use crate::util::error::{Context, Error};
    use std::time::Instant;

    impl From<xla::Error> for Error {
        fn from(e: xla::Error) -> Self {
            Error::msg(format!("{e}"))
        }
    }

    pub struct Engine {
        pub spec: ArtifactSpec,
        client: xla::PjRtClient,
        train_exe: xla::PjRtLoadedExecutable,
        predict_exe: xla::PjRtLoadedExecutable,
        /// Wall time of the most recent train_step (for cost calibration).
        pub last_step_secs: f64,
    }

    impl Engine {
        /// Compile both executables for an artifact. Compilation happens
        /// once per process (seconds); execution is then microseconds-to-
        /// milliseconds per batch.
        pub fn load(spec: &ArtifactSpec) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let train_exe =
                compile(&client, spec.train_hlo.to_str().unwrap())
                    .with_context(|| format!("compiling {}", spec.name))?;
            let predict_exe =
                compile(&client, spec.predict_hlo.to_str().unwrap())
                    .with_context(|| {
                        format!("compiling {} predict", spec.name)
                    })?;
            Ok(Self {
                spec: spec.clone(),
                client,
                train_exe,
                predict_exe,
                last_step_secs: 0.0,
            })
        }

        /// Execute one train step: returns loss, correct count, and per-
        /// parameter gradients (manifest order).
        pub fn train_step(
            &mut self,
            params: &ParamSet,
            batch: &BatchBuffers,
        ) -> Result<StepOutput> {
            let args = self.build_args(params, batch, true)?;
            let t0 = Instant::now();
            let result = self.train_exe.execute::<xla::Literal>(&args)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            self.last_step_secs = t0.elapsed().as_secs_f64();
            crate::ensure!(
                tuple.len() == 2 + self.spec.params.len(),
                "train output arity {} != {}",
                tuple.len(),
                2 + self.spec.params.len()
            );
            let loss: f32 = tuple[0].get_first_element()?;
            let correct: i32 = tuple[1].get_first_element()?;
            let mut grads = Vec::with_capacity(self.spec.params.len());
            for (i, p) in self.spec.params.iter().enumerate() {
                let g = tuple[2 + i].to_vec::<f32>()?;
                crate::ensure!(g.len() == p.len(), "grad {} size", p.name);
                grads.push(g);
            }
            Ok(StepOutput {
                loss,
                correct,
                grads,
            })
        }

        /// `train_step` variant that stages inputs as PjRtBuffers and runs
        /// `execute_b`. The vendored xla crate's `execute` (Literal path)
        /// leaks the device-side input buffers it creates internally
        /// (~input-size bytes per call, fatal over thousands of steps);
        /// buffers we create ourselves are dropped deterministically.
        pub fn train_step_b(
            &mut self,
            params: &ParamSet,
            batch: &BatchBuffers,
        ) -> Result<StepOutput> {
            let bufs = self.build_buffers(params, batch, true)?;
            let t0 = Instant::now();
            let result =
                self.train_exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            self.last_step_secs = t0.elapsed().as_secs_f64();
            drop(result);
            drop(bufs);
            crate::ensure!(
                tuple.len() == 2 + self.spec.params.len(),
                "train output arity {} != {}",
                tuple.len(),
                2 + self.spec.params.len()
            );
            let loss: f32 = tuple[0].get_first_element()?;
            let correct: i32 = tuple[1].get_first_element()?;
            let mut grads = Vec::with_capacity(self.spec.params.len());
            for (i, p) in self.spec.params.iter().enumerate() {
                let g = tuple[2 + i].to_vec::<f32>()?;
                crate::ensure!(g.len() == p.len(), "grad {} size", p.name);
                grads.push(g);
            }
            Ok(StepOutput {
                loss,
                correct,
                grads,
            })
        }

        /// Predict via `execute_b` (leak-free input path, see
        /// train_step_b).
        pub fn predict_b(
            &self,
            params: &ParamSet,
            batch: &BatchBuffers,
        ) -> Result<Vec<f32>> {
            let bufs = self.build_buffers(params, batch, false)?;
            let result =
                self.predict_exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            Ok(tuple[0].to_vec::<f32>()?)
        }

        fn build_buffers(
            &self,
            params: &ParamSet,
            batch: &BatchBuffers,
            with_labels: bool,
        ) -> Result<Vec<xla::PjRtBuffer>> {
            crate::ensure!(
                params.tensors.len() == self.spec.params.len(),
                "param arity mismatch"
            );
            let mut bufs = Vec::with_capacity(params.tensors.len() + 3);
            for (t, p) in params.tensors.iter().zip(&self.spec.params) {
                bufs.push(self.client.buffer_from_host_buffer::<f32>(
                    t, &p.shape, None,
                )?);
            }
            bufs.push(self.client.buffer_from_host_buffer::<f32>(
                &batch.adj,
                &batch.adj_dims(),
                None,
            )?);
            bufs.push(self.client.buffer_from_host_buffer::<f32>(
                &batch.x,
                &batch.x_dims(),
                None,
            )?);
            if with_labels {
                bufs.push(self.client.buffer_from_host_buffer::<i32>(
                    &batch.labels,
                    &[batch.batch],
                    None,
                )?);
            }
            Ok(bufs)
        }

        /// Root logits [B, C] for accuracy evaluation.
        pub fn predict(
            &self,
            params: &ParamSet,
            batch: &BatchBuffers,
        ) -> Result<Vec<f32>> {
            let args = self.build_args(params, batch, false)?;
            let result = self.predict_exe.execute::<xla::Literal>(&args)?;
            let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
            Ok(tuple[0].to_vec::<f32>()?)
        }

        fn build_args(
            &self,
            params: &ParamSet,
            batch: &BatchBuffers,
            with_labels: bool,
        ) -> Result<Vec<xla::Literal>> {
            crate::ensure!(
                params.tensors.len() == self.spec.params.len(),
                "param arity mismatch"
            );
            let mut args = Vec::with_capacity(params.tensors.len() + 3);
            for (t, p) in params.tensors.iter().zip(&self.spec.params) {
                args.push(
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::F32,
                        &p.shape,
                        f32_bytes(t),
                    )?,
                );
            }
            args.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &batch.adj_dims(),
                f32_bytes(&batch.adj),
            )?);
            args.push(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                &batch.x_dims(),
                f32_bytes(&batch.x),
            )?);
            if with_labels {
                args.push(
                    xla::Literal::create_from_shape_and_untyped_data(
                        xla::ElementType::S32,
                        &[batch.batch],
                        i32_bytes(&batch.labels),
                    )?,
                );
            }
            Ok(args)
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }

    fn compile(
        client: &xla::PjRtClient,
        path: &str,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }
}

// Engine tests live in rust/tests/numeric_parity.rs (they need built
// artifacts plus the `pjrt` feature, which `make artifacts` prepares
// before `cargo test --features pjrt` runs).
