//! Parameter state + optimizers, operating on flat f32 vectors in the
//! manifest's parameter order. The optimizer lives in Rust (L3): the AOT
//! artifacts return gradients; accumulation (HopGNN §5.1), averaging
//! across models, and the update all happen here.

use crate::runtime::manifest::ArtifactSpec;
use crate::util::rng::Rng;

/// Flat parameter vectors in manifest order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub tensors: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Glorot-uniform weights (2-D), zero biases (1-D) — matching the
    /// python `init_params` scheme.
    pub fn init(spec: &ArtifactSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let tensors = spec
            .params
            .iter()
            .map(|p| {
                if p.shape.len() == 2 {
                    let lim = (6.0 / (p.shape[0] + p.shape[1]) as f64).sqrt();
                    (0..p.len())
                        .map(|_| rng.f32_range(-(lim as f32), lim as f32))
                        .collect()
                } else {
                    vec![0.0; p.len()]
                }
            })
            .collect();
        Self { tensors }
    }

    pub fn zeros_like(&self) -> Self {
        Self {
            tensors: self.tensors.iter().map(|t| vec![0.0; t.len()]).collect(),
        }
    }

    pub fn total_len(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Accumulate `other` into self (gradient accumulation across
    /// micrograph time steps).
    pub fn add_assign(&mut self, other: &ParamSet) {
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    /// Accumulate from raw gradient slices (zero-copy from PJRT output).
    pub fn add_from_slices(&mut self, grads: &[Vec<f32>]) {
        for (a, b) in self.tensors.iter_mut().zip(grads) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    pub fn scale(&mut self, s: f32) {
        for t in self.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x *= s;
            }
        }
    }

    pub fn zero(&mut self) {
        for t in self.tensors.iter_mut() {
            for x in t.iter_mut() {
                *x = 0.0;
            }
        }
    }

    /// Global L2 norm (for grad-norm logging / clipping).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }
}

/// Adam optimizer (Kingma & Ba) over a ParamSet.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: ParamSet,
    v: ParamSet,
    t: i32,
}

impl Adam {
    pub fn new(params: &ParamSet, lr: f32) -> Self {
        Self {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: params.zeros_like(),
            v: params.zeros_like(),
            t: 0,
        }
    }

    pub fn step(&mut self, params: &mut ParamSet, grads: &ParamSet) {
        self.t += 1;
        let b1c = 1.0 - self.beta1.powi(self.t);
        let b2c = 1.0 - self.beta2.powi(self.t);
        for ((p, g), (m, v)) in params
            .tensors
            .iter_mut()
            .zip(&grads.tensors)
            .zip(self.m.tensors.iter_mut().zip(self.v.tensors.iter_mut()))
        {
            for ((p, &g), (m, v)) in p
                .iter_mut()
                .zip(g.iter())
                .zip(m.iter_mut().zip(v.iter_mut()))
            {
                *m = self.beta1 * *m + (1.0 - self.beta1) * g;
                *v = self.beta2 * *v + (1.0 - self.beta2) * g * g;
                let mh = *m / b1c;
                let vh = *v / b2c;
                *p -= self.lr * mh / (vh.sqrt() + self.eps);
            }
        }
    }
}

/// Plain SGD (used by tests and the quickstart example).
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn step(&self, params: &mut ParamSet, grads: &ParamSet) {
        for (p, g) in params.tensors.iter_mut().zip(&grads.tensors) {
            for (p, &g) in p.iter_mut().zip(g.iter()) {
                *p -= self.lr * g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ArtifactSpec, ParamSpec};
    use std::path::PathBuf;

    fn spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            model: "gcn".into(),
            layers: 1,
            feat_dim: 4,
            hidden: 4,
            classes: 2,
            vmax: 8,
            batch: 2,
            param_count: 20,
            params: vec![
                ParamSpec {
                    name: "w0".into(),
                    shape: vec![4, 4],
                },
                ParamSpec {
                    name: "b0".into(),
                    shape: vec![4],
                },
            ],
            train_hlo: PathBuf::new(),
            predict_hlo: PathBuf::new(),
        }
    }

    #[test]
    fn init_glorot_weights_zero_biases() {
        let p = ParamSet::init(&spec(), 3);
        assert_eq!(p.tensors.len(), 2);
        assert_eq!(p.total_len(), 20);
        let lim = (6.0f64 / 8.0).sqrt() as f32;
        assert!(p.tensors[0].iter().all(|&x| x.abs() <= lim && x != 0.0));
        assert!(p.tensors[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn accumulate_and_scale() {
        let mut a = ParamSet::init(&spec(), 1);
        a.zero();
        let mut g = a.zeros_like();
        g.tensors[0][0] = 2.0;
        a.add_assign(&g);
        a.add_assign(&g);
        assert_eq!(a.tensors[0][0], 4.0);
        a.scale(0.25);
        assert_eq!(a.tensors[0][0], 1.0);
    }

    #[test]
    fn adam_minimizes_quadratic() {
        // min f(p) = 0.5 * p^2 — gradient p; Adam should drive p -> 0
        let mut params = ParamSet {
            tensors: vec![vec![5.0f32]],
        };
        let mut adam = Adam::new(&params, 0.1);
        for _ in 0..200 {
            let grads = ParamSet {
                tensors: vec![vec![params.tensors[0][0]]],
            };
            adam.step(&mut params, &grads);
        }
        assert!(params.tensors[0][0].abs() < 0.1,
                "p = {}", params.tensors[0][0]);
    }

    #[test]
    fn sgd_step_direction() {
        let mut params = ParamSet {
            tensors: vec![vec![1.0f32]],
        };
        Sgd { lr: 0.5 }.step(
            &mut params,
            &ParamSet {
                tensors: vec![vec![2.0f32]],
            },
        );
        assert_eq!(params.tensors[0][0], 0.0);
    }

    #[test]
    fn l2_norm() {
        let p = ParamSet {
            tensors: vec![vec![3.0], vec![4.0]],
        };
        assert!((p.l2_norm() - 5.0).abs() < 1e-9);
    }
}
