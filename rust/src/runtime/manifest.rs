//! Artifact manifest: the Rust<->python ABI, produced by
//! `python/compile/aot.py` as `artifacts/manifest.json`.
//!
//! Input order of every `*.train.hlo.txt`: params (in `params` order),
//! then `adj [B, L, V, V] f32`, `x [B, V, F] f32`, `labels [B] i32`.
//! Output tuple: `(loss f32[], correct i32[], grads...)` with grads in
//! the same order as params.

use crate::util::json;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub model: String,
    pub layers: usize,
    pub feat_dim: usize,
    pub hidden: usize,
    pub classes: usize,
    pub vmax: usize,
    pub batch: usize,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub train_hlo: PathBuf,
    pub predict_hlo: PathBuf,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            )
        })?;
        Self::parse(&text, dir)
    }

    /// Default artifact directory: $HOPGNN_ARTIFACTS or ./artifacts.
    pub fn load_default() -> Result<Self, String> {
        let dir = std::env::var("HOPGNN_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Self::load(dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self, String> {
        let v = json::parse(text).map_err(|e| e.to_string())?;
        let arts = v
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or("manifest missing 'artifacts'")?;
        let mut artifacts = Vec::new();
        for a in arts {
            let s = |k: &str| -> Result<String, String> {
                a.get(k)
                    .and_then(|x| x.as_str())
                    .map(|x| x.to_string())
                    .ok_or_else(|| format!("artifact missing '{k}'"))
            };
            let u = |k: &str| -> Result<usize, String> {
                a.get(k)
                    .and_then(|x| x.as_usize())
                    .ok_or_else(|| format!("artifact missing '{k}'"))
            };
            let mut params = Vec::new();
            for p in a
                .get("params")
                .and_then(|x| x.as_arr())
                .ok_or("artifact missing 'params'")?
            {
                let name = p
                    .get("name")
                    .and_then(|x| x.as_str())
                    .ok_or("param missing name")?
                    .to_string();
                let shape = p
                    .get("shape")
                    .and_then(|x| x.as_arr())
                    .ok_or("param missing shape")?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect();
                params.push(ParamSpec { name, shape });
            }
            artifacts.push(ArtifactSpec {
                name: s("name")?,
                model: s("model")?,
                layers: u("layers")?,
                feat_dim: u("feat_dim")?,
                hidden: u("hidden")?,
                classes: u("classes")?,
                vmax: u("vmax")?,
                batch: u("batch")?,
                param_count: u("param_count")?,
                params,
                train_hlo: dir.join(s("train_hlo")?),
                predict_hlo: dir.join(s("predict_hlo")?),
            });
        }
        Ok(Self { artifacts, dir })
    }

    /// Find an artifact matching (model, hidden, feat_dim); layers must
    /// match the model's default.
    pub fn find(&self, model: &str, hidden: usize, feat_dim: usize)
                -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| {
            a.model == model && a.hidden == hidden && a.feat_dim == feat_dim
        })
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

impl ArtifactSpec {
    /// Total f32 scalars across all parameters.
    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "version": 1,
        "artifacts": [{
            "name": "gcn_l3_h128_f128_v128_b8",
            "model": "gcn", "layers": 3, "feat_dim": 128, "hidden": 128,
            "classes": 10, "vmax": 128, "batch": 8, "param_count": 34314,
            "params": [
                {"name": "w0", "shape": [128, 128]},
                {"name": "b0", "shape": [128]}
            ],
            "train_hlo": "gcn.train.hlo.txt",
            "predict_hlo": "gcn.predict.hlo.txt"
        }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.model, "gcn");
        assert_eq!(a.params[0].shape, vec![128, 128]);
        assert_eq!(a.total_params(), 128 * 128 + 128);
        assert_eq!(a.train_hlo, PathBuf::from("/tmp/a/gcn.train.hlo.txt"));
    }

    #[test]
    fn find_by_shape() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert!(m.find("gcn", 128, 128).is_some());
        assert!(m.find("gcn", 16, 128).is_none());
        assert!(m.by_name("gcn_l3_h128_f128_v128_b8").is_some());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}", PathBuf::new()).is_err());
        assert!(Manifest::parse("not json", PathBuf::new()).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // integration smoke: only runs when `make artifacts` has been run
        if let Ok(m) = Manifest::load("artifacts") {
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert!(a.total_params() == a.param_count,
                        "{}: param mismatch", a.name);
            }
        }
    }
}
